"""repro.parallel — meshes, sharding rules, remat, and distributed steps."""
from .remat import POLICIES, wrap_remat

__all__ = ["POLICIES", "wrap_remat"]
