"""Pipeline parallelism over the pod axis (GPipe schedule).

When the `pod` axis is repurposed as a pipeline axis, each pod holds a
contiguous slice of the superblock stack and microbatches flow through
`ppermute` ring steps — only (mb, S, D) activations ever cross the slow
inter-pod links (vs full gradient all-reduce under pod-DP).

Expressed as a shard_map manual over `pod` only: the stacked layer
parameters (n_superblocks leading axis) are sharded P('pod') so each stage
receives its local slice; data/model parallelism inside a stage stays
under GSPMD auto-partitioning.

The schedule below is the forward pipeline (validated for bit-equivalence
against the sequential stack in tests/test_distribution.py); the training
integration reuses it under jax.grad — the backward of ppermute is the
reverse ppermute, which yields the standard GPipe backward schedule.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def pp_forward(mesh: Mesh, stage_body: Callable, stacked_params,
               x_micro: jax.Array, *, axis: str = "pod"):
    """GPipe forward.

    stage_body(local_params, x) -> x   applies this stage's layer slice
    stacked_params: pytree with leading n_superblocks axis (sharded P(axis))
    x_micro: (n_micro, mb, S, D) microbatched embeddings (replicated over
    the pipeline axis; only stage 0 consumes them)
    returns (n_micro, mb, S, D) outputs (replicated — psum'd off the last
    stage).
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    assert n_micro >= n_stages, "need >= n_stages microbatches to fill"
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def local(params_local, xs):
        stage = jax.lax.axis_index(axis)
        buf = jnp.zeros_like(xs[0])
        outs = jnp.zeros_like(xs)
        for t in range(n_micro + n_stages - 1):
            recv = jax.lax.ppermute(buf, axis, perm)
            feed = xs[t] if t < n_micro else jnp.zeros_like(xs[0])
            x_in = jnp.where(stage == 0, feed, recv)
            buf = stage_body(params_local, x_in)
            k = t - (n_stages - 1)
            if k >= 0:
                outs = outs.at[k].set(
                    jnp.where(stage == n_stages - 1, buf, outs[k]))
        # replicate the last stage's outputs to every stage
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)),
            axis)
        return outs

    return jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        axis_names={axis}, check_vma=False,
    )(stacked_params, x_micro)


def pp_stage_body(cfg, ctx, dtype):
    """Builds stage_body for a uniform-pattern decoder (one attn/ssd block
    per superblock)."""
    from repro.models import blocks as B
    from repro.models.layers import cast_tree

    pattern = cfg.block_pattern

    def body(params_local, x):
        n_local = jax.tree.leaves(params_local)[0].shape[0]

        def one(x, layer_params):
            layer_params = cast_tree(layer_params, dtype)
            for i, kind in enumerate(pattern):
                x, _, _ = B.apply_block(kind, layer_params[i], x, ctx, None)
            return x, None

        x, _ = jax.lax.scan(one, x, params_local)
        return x

    return body
