"""Cross-pod gradient compression.

The 'pod' mesh axis rides the slowest links (inter-pod DCN/ICI), and the
only traffic that must cross it in DP mode is the gradient all-reduce.
``int8_psum`` quantizes each leaf to int8 against a pod-consistent scale
(pmax) and accumulates in int16 on the wire — 2× fewer bytes than fp32
psum, exact to 1/127 relative, valid up to 258 pods (127·258 < 2¹⁵).

``make_podwise_wrapper`` lifts a (params, opt, batch, lr) -> (...) train
step into a shard_map over the pod axis only (data/model stay under GSPMD
auto-partitioning): gradients are computed per pod and combined with the
compressed psum, exposing the cross-pod collective to explicit control —
under plain jit, GSPMD owns that all-reduce and cannot compress it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P


def int8_psum(x, axis: str):
    """Compressed psum of a float tensor across ``axis``."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf)) / 127.0
    scale = jax.lax.pmax(scale, axis)
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    s = jax.lax.psum(q.astype(jnp.int16), axis)        # 2 B/elt on the wire
    return s.astype(jnp.float32) * scale


def compressed_grad_psum(grads, axis: str, n: int):
    """Mean of per-pod gradients via int8 psum."""
    return jax.tree.map(lambda g: int8_psum(g, axis) / n, grads)
