"""Activation-checkpoint (remat) policies for the scanned layer stack.

Policies (hillclimb knobs for the memory roofline term):

    none          — save everything (max memory, min recompute)
    full          — save nothing (min memory, max recompute)
    dots          — save matmul outputs (jax's dots_saveable)
    dots_no_batch — dots_with_no_batch_dims_saveable (Megatron-style
                    'selective' checkpointing: saves projections, recomputes
                    attention/softmax)
"""
from __future__ import annotations

import jax

POLICIES = ("none", "full", "dots", "dots_no_batch")


def wrap_remat(fn, policy: str):
    if policy in (None, "none"):
        return fn
    cp = jax.checkpoint_policies
    if policy == "full":
        return jax.checkpoint(fn, policy=cp.nothing_saveable,
                              static_argnums=())
    if policy == "dots":
        return jax.checkpoint(fn, policy=cp.dots_saveable)
    if policy == "dots_no_batch":
        return jax.checkpoint(fn, policy=cp.dots_with_no_batch_dims_saveable)
    raise ValueError(f"unknown remat policy {policy!r}; options {POLICIES}")
