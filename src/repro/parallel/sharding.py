"""Sharding rules: parameter, optimizer-state, batch, and cache
PartitionSpecs for every architecture on the production meshes.

Mesh axes:
    pod    — slowest links (DCN/inter-pod ICI).  Data-parallel by default;
             only gradient all-reduce crosses it (optionally compressed).
    data   — intra-pod data parallelism (+ ZeRO-1 optimizer sharding).
    model  — tensor/expert parallelism.

Rules are Megatron-style:
    attn  : wq/wk/wv column-parallel (heads on model), wo row-parallel
    ffn   : gate/up column-parallel, down row-parallel
    moe   : experts on model (EP); shared expert like ffn
    rglru : width on model
    embed : vocab-sharded; lm_head vocab-sharded (column)
    ssd   : replicated (mamba2-130m is small; TP of the mixed in_proj
            layout is not worth it — DESIGN.md §4)
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


def dp_axes(mesh: Mesh):
    """The data-parallel meta-axis: ('pod','data') on multi-pod meshes."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _div(n: int, mesh: Mesh, axis: str) -> bool:
    return n % _axis_size(mesh, axis) == 0


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

def _param_rule(path: tuple[str, ...], shape: tuple[int, ...],
                cfg: ModelConfig, mesh: Mesh,
                replicate_embed: bool = False) -> P:
    """path: names along the pytree (superblock stacking prepends a leading
    axis to every block leaf — handled by the caller offset)."""
    name = path[-1]
    parent = path[-2] if len(path) >= 2 else ""
    m = "model"

    def ok(dim_size):  # only shard when divisible
        return dim_size % _axis_size(mesh, m) == 0

    # embeddings / head
    if name == "embed":
        # replicate_embed: gathers on a sharded operand dim CHECK-fail in
        # XLA's SPMD partitioner inside partial-manual (pod-compress)
        # regions — replicated tables sidestep the bug at a memory cost
        if replicate_embed:
            return P(None, None)
        return P(m, None) if ok(shape[0]) else P()
    if name == "lm_head":
        return P(None, m) if ok(shape[1]) else P()
    if name == "frontend_proj":
        return P(None, m) if ok(shape[1]) else P()

    # attention
    if name in ("wq", "wk", "wv"):
        return P(None, m) if ok(shape[-1]) else P(None, None)
    if name in ("bq", "bk", "bv"):
        return P(m) if ok(shape[-1]) else P(None)
    if name == "wo":
        return P(m, None) if ok(shape[-2]) else P(None, None)

    # dense ffn / shared expert
    if parent in ("ffn", "shared"):
        if name in ("gate", "up"):
            return P(None, m) if ok(shape[-1]) else P(None, None)
        if name == "down":
            return P(m, None) if ok(shape[-2]) else P(None, None)

    # moe experts: EP on model
    if name in ("w_gate", "w_up", "w_down"):
        return (P(m, None, None) if ok(shape[-3]) else P(None, None, None))
    if name == "router":
        return P(None, None)

    # rglru
    if name in ("in_x", "in_gate"):
        return P(None, m) if ok(shape[-1]) else P(None, None)
    if name in ("a_gate_w", "x_gate_w"):
        return P(m, None, None) if ok(shape[-3]) else P(None, None, None)
    if name in ("a_gate_b", "x_gate_b"):
        return P(m, None) if ok(shape[-2]) else P(None, None)
    if name == "a_param":
        return P(m) if ok(shape[-1]) else P(None)
    if name == "out":
        return P(m, None) if ok(shape[-2]) else P(None, None)
    if name in ("conv_w", "conv_b") and parent != "mixer":
        pass

    # ssd (mamba2): replicate — see module docstring
    # norms, scalars, conv taps: replicate
    return P(*([None] * len(shape)))


def _path_names(kp) -> tuple[str, ...]:
    names = []
    for e in kp:
        if hasattr(e, "key"):
            names.append(str(e.key))
        elif hasattr(e, "idx"):
            names.append(f"[{e.idx}]")
    return tuple(names)


def param_pspecs(cfg: ModelConfig, params_shape: Any, mesh: Mesh,
                 *, replicate_embed: bool = False):
    """params_shape: pytree of ShapeDtypeStruct (or arrays)."""
    def rule(kp, leaf):
        names = _path_names(kp)
        shape = tuple(leaf.shape)
        # stacked superblock leaves carry a leading n_superblocks axis
        stacked = len(names) >= 1 and names[0] == "blocks"
        core_shape = shape[1:] if stacked else shape
        spec = _param_rule(tuple(n for n in names if not n.startswith("[")),
                           core_shape, cfg, mesh,
                           replicate_embed=replicate_embed)
        if stacked:
            spec = P(None, *spec)
        return spec
    return jax.tree_util.tree_map_with_path(rule, params_shape)


def zero1_specs(param_specs, params_shape, mesh: Mesh):
    """ZeRO-1: extend each spec by sharding the largest unsharded dim over
    'data' when divisible (optimizer moments + master copy only)."""
    dsize = _axis_size(mesh, "data")
    if dsize == 1:
        return param_specs

    def extend(spec: P, leaf):
        shape = tuple(leaf.shape)
        parts = list(spec) + [None] * (len(shape) - len(spec))
        # pick the largest dim that is unsharded and divisible by data
        cand = [(shape[i], i) for i in range(len(shape))
                if parts[i] is None and shape[i] % dsize == 0 and shape[i] > 1]
        if not cand:
            return spec
        _, i = max(cand)
        parts[i] = "data"
        return P(*parts)

    return jax.tree.map(extend, param_specs, params_shape)


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------

def batch_pspecs(cfg: ModelConfig, batch_shape: dict, mesh: Mesh) -> dict:
    dp = dp_axes(mesh)
    out = {}
    for k, v in batch_shape.items():
        nb = v.shape[0] if v.ndim else 1
        lead = dp if nb % int(np.prod([_axis_size(mesh, a) for a in dp])) == 0 \
            else None
        out[k] = P(lead, *([None] * (v.ndim - 1)))
    return out


def cache_pspecs(cfg: ModelConfig, cache_shape: Any, mesh: Mesh,
                 *, seq_axes: tuple = ()):
    """Decode-cache specs.  KV layout (B, Sc, K, dh) (+ leading superblock
    axis when stacked).  Batch on dp when divisible; kv-heads on model when
    divisible, else the sequence dim over ``seq_axes`` (distributed
    flash-decode handles the softmax)."""
    dp = dp_axes(mesh)
    dp_total = int(np.prod([_axis_size(mesh, a) for a in dp]))
    msize = _axis_size(mesh, "model")
    seq_total = int(np.prod([_axis_size(mesh, a) for a in seq_axes])) \
        if seq_axes else 1

    def rule(kp, leaf):
        names = _path_names(kp)
        name = names[-1]
        shape = tuple(leaf.shape)
        stacked = names[0] == "blocks"
        core = shape[1:] if stacked else shape
        if name in ("k", "v"):
            B, Sc, K, dh = core
            bspec = dp if B % dp_total == 0 and B > 1 else None
            if K % msize == 0:
                spec = P(bspec, None, "model", None)
            elif seq_axes and Sc % seq_total == 0:
                sa = tuple(a for a in seq_axes if bspec is None or a not in bspec)
                spec = P(bspec, sa, None, None)
            else:
                spec = P(bspec, None, None, None)
        elif name == "pos":
            if seq_axes and core[0] % seq_total == 0:
                spec = P(tuple(seq_axes))
            else:
                spec = P(None)
        elif name == "conv":
            B = core[0]
            bspec = dp if B % dp_total == 0 and B > 1 else None
            spec = P(bspec, *([None] * (len(core) - 1)))
        elif name in ("state", "h"):
            B = core[0]
            bspec = dp if B % dp_total == 0 and B > 1 else None
            spec = P(bspec, *([None] * (len(core) - 1)))
        elif name == "t":
            spec = P()
        else:
            spec = P(*([None] * len(core)))
        if stacked:
            spec = P(None, *spec)
        return spec

    return jax.tree_util.tree_map_with_path(rule, cache_shape)


def make_shardings(mesh: Mesh, specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def activation_constrainer(mesh: Mesh, mode: str = "dp", exclude=()):
    """Activation sharding hook threaded into the model (ctx['constrain']).

    dp     — batch-only (B on dp)
    dp_sp  — sequence parallelism: residual stream also sharded on model
             along the sequence dim (norm/elementwise regions)
    exclude — axes not mentionable (e.g. 'pod' inside a pod-manual
              shard_map region)."""
    dp = tuple(a for a in dp_axes(mesh) if a not in exclude)

    def constrain(x):
        if x.ndim < 3:
            return x
        if mode == "dp_sp":
            spec = P(dp, "model", None)
        else:
            spec = P(dp, None, None)
        try:
            # a raw PartitionSpec resolves against the *context* mesh, which
            # keeps this valid inside partial-manual shard_map regions
            return jax.lax.with_sharding_constraint(x, spec)
        except (ValueError, TypeError):
            try:
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, spec))
            except (ValueError, TypeError):
                return x
    return constrain
