"""Distributed flash-decode: single-token attention over a KV cache whose
*sequence* dimension is sharded across mesh axes.

Why: TP decode with few KV heads (GQA kv=8 on a model axis of 16, or MQA
kv=1) cannot shard heads; sharding the cache sequence instead keeps HBM
balanced and turns the softmax into a two-pass distributed reduction
(local partial max/sum + psum of exp-rescaled numerators) — flash-decoding
/ split-KV, expressed with shard_map + lax collectives instead of CUDA
split-K blocks.  Cost: one pmax + two psums of (B,H,dh)-sized tensors per
layer, vs all-gathering the whole cache under plain GSPMD.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

NEG_INF = -1e30


def _local_decode_attn(q, k, v, k_pos, q_pos, *, axes, causal, window,
                       chunk, scale):
    """Per-shard body.  q (B,1,H,dh) replicated; k/v (B,Sl,K,dh) local
    shard; k_pos (Sl,) global positions of local slots; q_pos () scalar."""
    B, _, H, dh = q.shape
    Sl, K = k.shape[1], k.shape[2]
    G = H // K
    qf = q.reshape(B, K, G, dh).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    logits = jnp.einsum("bkgd,bskd->bkgs", qf, kf) * scale   # (B,K,G,Sl)

    valid = k_pos >= 0
    if causal:
        valid &= q_pos >= k_pos
    if window:
        valid &= (q_pos - k_pos) < window
    if chunk:
        valid &= (q_pos // chunk) == (k_pos // chunk)
    logits = jnp.where(valid[None, None, None, :], logits, NEG_INF)

    m_local = jnp.max(logits, axis=-1, keepdims=True)        # (B,K,G,1)
    m_global = jax.lax.pmax(m_local, axes[0])
    for a in axes[1:]:
        m_global = jax.lax.pmax(m_global, a)
    p = jnp.exp(logits - m_global)
    p = jnp.where(valid[None, None, None, :], p, 0.0)
    num = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    den = jnp.sum(p, axis=-1, keepdims=True)                 # (B,K,G,1)
    num = jax.lax.psum(num, axes)
    den = jax.lax.psum(den, axes)
    out = num / jnp.maximum(den, 1e-30)
    return out.reshape(B, 1, H, dh).astype(q.dtype)


def seq_sharded_decode_attention(mesh: Mesh, axes: tuple, q, k, v, k_pos,
                                 q_pos, *, batch_axes=(), causal=True,
                                 window=0, chunk=0, scale=None):
    """q (B,1,H,dh); k/v (B,Sc,K,dh) with Sc sharded over ``axes``;
    k_pos (Sc,); q_pos scalar int32."""
    dh = q.shape[-1]
    scale = scale if scale is not None else dh ** -0.5
    bspec = tuple(batch_axes) if batch_axes else None
    body = functools.partial(_local_decode_attn, axes=tuple(axes),
                             causal=causal, window=window, chunk=chunk,
                             scale=scale)
    manual = set(axes) | set(batch_axes or ())
    return jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(bspec, None, None, None),
                  P(bspec, axes, None, None),
                  P(bspec, axes, None, None),
                  P(axes),
                  P()),
        out_specs=P(bspec, None, None, None),
        check_vma=False,
        axis_names=manual,
    )(q, k, v, k_pos, q_pos)
