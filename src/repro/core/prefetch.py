"""Speculative metadata prefetch pipeline for cold directory trees.

The engine hides *write* latency by deferring and fusing mutations, and
PR 3/4's namespace overlay answers namespace reads from pending state —
but only once a tree is *warm*.  The paper's model tasks (extract a tree
you just scanned, ``rm -rf`` a tree you must first enumerate) open with a
**cold metadata walk** that costed one synchronous ``readdir_plus``
roundtrip per directory, serialized by the walk's own recursion: O(dirs x
RTT), the last unpipelined metadata path in the engine.

This module closes it with a bounded breadth-first prefetch frontier:

* when a cold ``readdir``/``walk`` misses the overlay and its executed
  listing discovers subdirectories, those are enqueued on the frontier;
* the frontier drains in *batched* background reads — ONE vectored
  ``readdir_plus_vec`` backend call per batch (``LatencyBackend`` pays a
  single roundtrip), with the batch width sized from the backend's live
  RTT/bandwidth EWMAs (``bdp_bytes``, PR 4's plumbing) so one batch
  carries ~2x a bandwidth-delay product of dirents;
* results install into the ``NamespaceOverlay`` as cached listings —
  **without sealing and without counting as observations** — at LRU-cold
  recency, so speculation can never evict the hot in-use window;
* each discovered level seeds the next: the fetch pipeline runs ahead of
  the consumer, turning O(depth x RTT + dirs x RTT) cold walks into
  O(depth x RTT + dirs/B x RTT).

The pipeline is strictly **advisory**:

* batches ride the scheduler's *low-priority* ready lanes
  (``OpScheduler.submit_speculative``): they take and grant no DAG edges,
  real ops always dispatch first, and a full in-flight budget makes the
  prefetcher yield instead of blocking anyone;
* every enqueued directory holds a ``SpeculationTicket`` in the overlay;
  any racing admitted mutation that could make the fetched listing stale
  (rmdir/rename/remove_tree under the prefix, a mkdir over it, an op
  failure, rollback) cancels the ticket and the listing is dropped on
  arrival — observed semantics stay byte-identical to the unprefetched
  engine (the prefetch on/off equivalence property suite);
* fetch failures — including injected faults, which fire once per *fused*
  batch — are swallowed: nothing lands in the ledger, no region is
  condemned, the engine is never poisoned, and the consumer simply falls
  back to its per-directory sync path.

``EngineStats`` reports ``prefetch_issued`` (dirs sent in batches),
``prefetch_batches`` (vectored calls), ``prefetch_hits`` (overlay reads
answered from a speculative listing), ``prefetch_wasted`` (fetched but
uninstallable: failed, stale, or evicted at insert) and
``prefetch_cancelled`` (invalidated by racing mutations or teardown).
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass

from .backend import norm_path


@dataclass(frozen=True)
class PrefetchPolicy:
    """Knobs of the speculative prefetch pipeline (``CannyFS(prefetch=
    PrefetchPolicy(...))``; ``prefetch=False`` disables it, the default
    enables it whenever the namespace overlay is on).

    ``max_batch``/``min_batch`` bound one vectored ``readdir_plus_vec``
    call's width; with ``adaptive_batch`` and a backend that measures its
    bandwidth-delay product (``LatencyBackend.bdp_bytes``), the width is
    ~``bdp_multiplier`` x BDP worth of ``bytes_per_dirent``-sized entries
    within those bounds — the same self-tuning the write coalescer uses.
    ``max_inflight_batches`` clamps the pipeline's in-flight window (≈
    RTT x width of speculation outstanding at once) and
    ``max_outstanding`` bounds the whole frontier, so an adversarially
    wide tree cannot queue unbounded speculation."""

    enabled: bool = True
    max_batch: int = 32
    min_batch: int = 4
    adaptive_batch: bool = True
    bdp_multiplier: float = 2.0
    bytes_per_dirent: int = 256
    max_inflight_batches: int = 2
    max_outstanding: int = 4096
    warm_stat_cache: bool = True   # listings also warm the stat cache

    @classmethod
    def off(cls) -> "PrefetchPolicy":
        return cls(enabled=False)


class _BatchPayload:
    """Payload of one speculative batch op; the engine calls
    ``on_cancelled`` when poison cancels the op before it ran, so the
    tickets are released and the in-flight window reopens."""

    __slots__ = ("batch", "prefetcher")

    def __init__(self, batch, prefetcher):
        self.batch = batch              # [(path, SpeculationTicket)]
        self.prefetcher = prefetcher

    def on_cancelled(self) -> None:
        self.prefetcher._abort_batch(self.batch)


class MetadataPrefetcher:
    """The bounded BFS frontier + batch pump.  One per engine; all entry
    points are thread-safe and non-blocking.  Holds its own lock above
    the overlay's (never the reverse): overlay methods are called only
    outside ``_lock``."""

    def __init__(self, engine, policy: PrefetchPolicy):
        self.engine = engine
        self.policy = policy
        bdp = getattr(engine.backend, "bdp_bytes", None)
        self._bdp = bdp if callable(bdp) else None
        # per-op-class cost hints (CostModel protocol) outrank the scalar
        # probe: listings are sized by the "readdir" class, so a backend
        # with paginated LISTs sizes the pipeline from listing costs, not
        # from data-plane bandwidth
        cost = getattr(engine.backend, "cost_hint", None)
        self._cost = cost if callable(cost) else None
        self._lock = threading.Lock()
        self._slock = threading.Lock()     # exact counters (leaf)
        self._frontier: deque = deque()    # (path, ticket)
        self._inflight_batches = 0
        self._quiesced = 0                 # drain depth (see quiesce())
        # path -> the submitted batch op fetching it (consumer latch)
        self._inflight_paths: dict = {}

    # ------------------------------------------------------------------
    # sizing
    # ------------------------------------------------------------------

    def _bdp_bytes(self):
        """Listing-class BDP: the backend's "readdir" cost hint when it
        has one, else the legacy scalar probe, else None."""
        if self._cost is not None:
            hint = self._cost("readdir", 0)
            if hint is not None:
                return hint.bdp_bytes()
        if self._bdp is not None:
            return self._bdp()
        return None

    def batch_width(self) -> int:
        """Dirs per vectored call: ~2x the measured BDP worth of dirents
        when the backend exposes one, else the policy cap."""
        pol = self.policy
        if not pol.adaptive_batch:
            return pol.max_batch
        bdp = self._bdp_bytes()
        if not bdp:
            return pol.max_batch
        return max(pol.min_batch,
                   min(int(pol.bdp_multiplier * bdp / pol.bytes_per_dirent),
                       pol.max_batch))

    # ------------------------------------------------------------------
    # frontier
    # ------------------------------------------------------------------

    def seed(self, listing) -> None:
        """Enqueue the subdirectories discovered by one executed listing
        ``[(child_path, StatResult|None), ...]`` and pump the pipeline."""
        if self._quiesced:
            return
        ov = self.engine.overlay
        wanted = []
        for child, st in listing:
            if st is None or not st.is_dir or st.is_symlink:
                continue
            t = ov.speculation_wanted(norm_path(child))
            if t is not None:
                wanted.append((t.path, t))
        if not wanted:
            return
        overflow = []
        with self._lock:
            room = self.policy.max_outstanding - len(self._frontier)
            if room < len(wanted):
                overflow = wanted[max(room, 0):]
                wanted = wanted[:max(room, 0)]
            self._frontier.extend(wanted)
        for _, t in overflow:
            ov.end_speculation(t)
        if overflow:
            with self._slock:
                self.engine.stats.prefetch_cancelled += len(overflow)
        self._pump()

    def seed_children(self, path: str, listing) -> None:
        """Convenience: ``seed`` with names resolved against ``path``."""
        path = norm_path(path)
        self.seed([(f"{path}/{name}" if path else name, st)
                   for name, st in listing])

    def _pump(self) -> None:
        """Issue batches while the in-flight window has room.  Never
        blocks: a declined submission (budget full / poisoned / closed)
        drops the batch and releases its tickets.  Batch hygiene: an
        *undersized* frontier is held back while another batch is still
        in flight — its installs are about to seed more of this level,
        and flushing early would fragment the level into sub-width
        roundtrips (a consumer that cannot wait sync-misses exactly as
        it would have anyway)."""
        ov = self.engine.overlay
        while True:
            with self._lock:
                if (self._quiesced or not self._frontier
                        or self._inflight_batches
                        >= self.policy.max_inflight_batches):
                    return
                width = self.batch_width()
                if (len(self._frontier) < width
                        and self._inflight_batches > 0):
                    return
                batch = []
                while self._frontier and len(batch) < width:
                    batch.append(self._frontier.popleft())
                self._inflight_batches += 1
            live = []
            dropped = 0
            for p, t in batch:
                if t.cancelled:
                    ov.end_speculation(t)
                    dropped += 1
                else:
                    live.append((p, t))
            if dropped:
                with self._slock:
                    self.engine.stats.prefetch_cancelled += dropped
            if not live:
                with self._lock:
                    self._inflight_batches -= 1
                continue
            payload = _BatchPayload(live, self)
            op = self.engine._sched.submit_speculative(
                "prefetch", tuple(p for p, _ in live),
                lambda b=live: self._run_batch(b), payload=payload)
            if op is None:      # engine busy/poisoned/closed: yield
                self._abort_batch(live)
                return
            with self._lock:
                for p, _ in live:
                    self._inflight_paths[p] = op
            with self._slock:
                st = self.engine.stats
                st.prefetch_batches += 1
                st.prefetch_issued += len(live)

    def _abort_batch(self, batch) -> None:
        ov = self.engine.overlay
        for _, t in batch:
            ov.end_speculation(t)
        with self._slock:
            self.engine.stats.prefetch_cancelled += len(batch)
        with self._lock:
            self._inflight_batches -= 1
            for p, _ in batch:
                self._inflight_paths.pop(p, None)

    # ------------------------------------------------------------------
    # the batch body (runs on an executor worker, low priority)
    # ------------------------------------------------------------------

    def _run_batch(self, batch) -> None:
        eng = self.engine
        ov = eng.overlay
        stats = eng.stats
        try:
            live = []
            cancelled = 0
            for p, t in batch:
                if t.cancelled:      # racing mutation beat the fetch
                    ov.end_speculation(t)
                    cancelled += 1
                else:
                    live.append((p, t))
            if cancelled:
                with self._slock:
                    stats.prefetch_cancelled += cancelled
            if not live:
                return
            try:
                listings = eng.backend.readdir_plus_vec(
                    [p for p, _ in live])
            except OSError:
                # advisory: an injected (or real) fault on the fused
                # batch drops it whole — no ledger entry, no poison; the
                # consumer falls back to its per-directory sync path
                for _, t in live:
                    ov.end_speculation(t)
                with self._slock:
                    stats.prefetch_wasted += len(live)
                return
            warm = (self.policy.warm_stat_cache
                    and ov.policy.prefetch)
            cache = eng.stat_cache
            for p, t in live:
                listing = listings.get(p)
                if listing is None:   # vanished/denied: per-dir advisory
                    ov.end_speculation(t)
                    with self._slock:
                        stats.prefetch_wasted += 1
                    continue
                def warm_cb(p=p, listing=listing):
                    # runs inside the overlay's install critical section:
                    # warming is atomic with the ticket re-check, so a
                    # racing op failure — which invalidates the overlay
                    # (this lock) *before* the stat cache — always clears
                    # any entry warmed here, and a cancelled batch never
                    # plants stat entries the unprefetched engine could
                    # not have held
                    warmed = 0
                    for name, stt in listing:
                        child = f"{p}/{name}" if p else name
                        if stt is not None and cache.get(child) is None:
                            cache.put(child, stt)
                            warmed += 1
                    if warmed:
                        with self._slock:
                            stats.prefetched_stats += warmed
                verdict = ov.install_speculative(
                    t, listing, warm=warm_cb if warm else None)
                if verdict == "installed":
                    if not self._quiesced:
                        self.seed_children(p, listing)
                elif verdict == "cancelled":
                    with self._slock:
                        stats.prefetch_cancelled += 1
                else:                 # "stale" | "evicted"
                    with self._slock:
                        stats.prefetch_wasted += 1
        finally:
            with self._lock:
                self._inflight_batches -= 1
                for p, _ in batch:
                    self._inflight_paths.pop(p, None)
            self._pump()

    # ------------------------------------------------------------------
    # consumer latch
    # ------------------------------------------------------------------

    def wait_for(self, path: str) -> bool:
        """A consumer missed the overlay on ``path`` while the pipeline
        already covers it: wait for the covering batch to land and
        return True (the caller re-checks the overlay — a hit costs zero
        extra roundtrips instead of a duplicate fetch).

        A path still *queued* on the frontier is **demand-promoted**: its
        entry (plus up to a batch width of queued neighbours — the
        walker's next targets) is force-issued immediately, bypassing
        the in-flight window, and the caller latches onto that batch.
        The consumer's stall then costs the same one RTT its sync miss
        would have, but warms a whole batch aligned with its position —
        this is what keeps the pipeline ahead of a depth-first walker
        on wide levels.

        Returns False when the pipeline has nothing for the path (never
        seeded, ticket cancelled, or submission declined): the caller
        takes its sync path exactly as before.  Deadlock-free: the wait
        happens on the *caller's* thread, never on a pool worker
        (fs.readdir latches before submitting its sync op)."""
        path = norm_path(path)
        batch = None
        with self._lock:
            op = self._inflight_paths.get(path)
            if op is None and not self._quiesced:
                # demand promotion: find the path's frontier entry and
                # lead a batch with it
                for i, (p, _t) in enumerate(self._frontier):
                    if p == path:
                        self._frontier.rotate(-i)
                        width = self.batch_width()
                        batch = []
                        while self._frontier and len(batch) < width:
                            batch.append(self._frontier.popleft())
                        self._inflight_batches += 1
                        break
        if batch is not None:
            live = [(p, t) for p, t in batch if not t.cancelled]
            dead = [(p, t) for p, t in batch if t.cancelled]
            if dead:
                ov = self.engine.overlay
                for _, t in dead:
                    ov.end_speculation(t)
                with self._slock:
                    self.engine.stats.prefetch_cancelled += len(dead)
            if not live:
                with self._lock:
                    self._inflight_batches -= 1
                return False
            payload = _BatchPayload(live, self)
            op = self.engine._sched.submit_speculative(
                "prefetch", tuple(p for p, _ in live),
                lambda b=live: self._run_batch(b), payload=payload)
            if op is None:
                self._abort_batch(live)
                return False
            with self._lock:
                for p, _ in live:
                    self._inflight_paths[p] = op
            with self._slock:
                st = self.engine.stats
                st.prefetch_batches += 1
                st.prefetch_issued += len(live)
        if op is None:
            return False
        sim = self.engine.sim
        if sim is not None:
            # discrete-event mode: the latch is an off-timeline wait (the
            # covering batch's completion is the wake), bracketed so the
            # event queue can advance virtual time past this consumer
            sim.wait_event(op.done)
        else:
            op.done.wait()
        return True

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------

    def quiesce(self) -> None:
        """Stop issuing and drop the frontier (tickets released) — called
        by ``engine.drain()`` so a global barrier doesn't chase a
        self-refilling pipeline; in-flight batches finish and install as
        usual.  Nested drains stack (``resume`` unwinds one level)."""
        with self._lock:
            self._quiesced += 1
            dropped = list(self._frontier)
            self._frontier.clear()
        ov = self.engine.overlay
        for _, t in dropped:
            ov.end_speculation(t)
        if dropped:
            with self._slock:
                self.engine.stats.prefetch_cancelled += len(dropped)

    def resume(self) -> None:
        with self._lock:
            self._quiesced = max(0, self._quiesced - 1)


__all__ = ["MetadataPrefetcher", "PrefetchPolicy"]
