"""Durable optimization window: the transaction spill journal (PR 9).

The paper's whole premise is that an HPC job's I/O is one transaction
whose failure "will frequently warrant the resubmission of a full job" —
but a *preempted* job loses its entire optimization window (the region
journal and the namespace-overlay delta are memory-only) and must redo
every backend op from scratch, which is exactly the resubmission cost
CannyFS exists to hide.  This module makes the window itself durable:

* ``SpillManager`` incrementally persists the transaction's region
  journal (created paths → rollback scope) and the engine's op outcomes
  (admit / done / fail per op, with per-segment checksums for writes)
  into an append-only, checksummed, epoch-stamped record log on the
  *same* backend, following the checkpoint manager's COMMIT-marker
  discipline: records buffer in memory, flush in chunks that ride the
  scheduler's LOW-PRIORITY speculative lane (durability never serializes
  the hot path), and a **cut** at every ``barrier``/observation seal
  forces the outstanding chunks down and stamps the marker.  The log is
  monotone-prefix safe: offsets are reserved at chunking time, a reader
  stops at the first gap or corrupt line, and a later cut heals an
  earlier chunk whose speculative write was dropped.

* ``CannyFS.resume(spill_dir)`` (see ``fs.py``) re-proves the window on
  a fresh mount after a kill: ``load`` parses the log into a
  ``SpillImage`` (journal, durable op outcomes, uncertain in-flight
  ops), ``repair`` resolves the uncertainty directly against the
  backend (torn COPY+DELETE renames are merge-moved, a partially
  applied bulk DELETE is re-issued, landed-but-unjournaled creates
  whose probe record proves pre-op absence are journaled so rollback
  can never leak them), and the proven delta is
  replayed into the stat cache and namespace overlay without re-walking
  the tree.  The re-executed job body then consults the image: ops
  provably durable are **elided** (mkdir/unlink/metadata) or
  **diverted** (create+write streams buffer locally and are verified
  against the recorded segment checksums at close — a mismatch falls
  back to a plain rewrite), so a resumed job redoes only the ops that
  were genuinely in flight at the kill.

Epoch discipline: every transaction attempt is one epoch.  ``begin``
opens it, ``committed`` (followed by unlinking the log) closes it, and
rollback advances the epoch without a marker — the parser keeps only the
*last* epoch opened, so records from an abandoned attempt can never
resurrect rolled-back state.

Tenancy (PR 10): a ``Tenant`` arms its own ``SpillManager`` on a
distinct ``spill_dir`` via ``Tenant._arm_spill`` — the manager lands in
the tenant's scheduler-side slot (``_TenantState.spill``), not the
engine-global ``engine.spill``, so each tenant's window journals, cuts
and resumes independently: ``Tenant.resume(spill_dir)`` re-proves ONE
tenant's window on the live shared engine (replaying only its own
prefix-scoped events) while every neighbour's window stays open.  The
fs layer reaches the right manager through the ``CannyFS._spill()``
hook; both commit AND rollback must route through it — a tenant
rollback that missed its spill's ``on_rollback`` tombstone would leave
durable claims that wrongly elide re-creates of rolled-back files.

Nothing here imports the engine or fs layers; the manager holds a
reference to its engine and duck-types the payloads, so the module sits
beside ``faults.py`` at the bottom of the core dependency graph.
"""
from __future__ import annotations

import json
import threading
import zlib
from typing import Any, Optional

from .backend import is_under, norm_path

# op kinds worth spilling: everything that mutates the backend namespace
# or data.  Reads/stats prove nothing durable and are never recorded.
SPILL_KINDS = frozenset({
    "mkdir", "create", "write", "unlink", "rmdir", "rename", "symlink",
    "link", "truncate", "fallocate", "chmod", "chown", "utimens",
    "setxattr", "removexattr", "remove_tree",
})

REMOVAL_KINDS = frozenset({"unlink", "rmdir", "remove_tree"})

# metadata ops a resumed run may elide when the recorded last-wins
# arguments match the re-executed call exactly
META_KINDS = frozenset({"chmod", "chown", "utimens", "truncate",
                        "setxattr", "removexattr", "fallocate"})

JOURNAL_FILE = "journal.log"
CUT_FILE = "CUT"


def commit_marker_ok(data: bytes, expected: int) -> bool:
    """The COMMIT-marker validation shared with the checkpoint manager:
    a marker is proof only when its *content* names the expected step —
    an empty or garbage marker (crash between create and write) is not a
    commit."""
    try:
        return int(data.decode()) == expected
    except (ValueError, UnicodeDecodeError):
        return False


# ---------------------------------------------------------------------------
# record codec: one JSON object + crc32 per line, corruption-evident
# ---------------------------------------------------------------------------

def _enc(rec: dict) -> bytes:
    body = json.dumps(rec, sort_keys=True, separators=(",", ":"))
    crc = zlib.crc32(body.encode("utf-8")) & 0xFFFFFFFF
    return f"{body}|{crc:08x}\n".encode("utf-8")


def _dec(line: bytes) -> Optional[dict]:
    try:
        text = line.decode("utf-8")
        body, sep, crc_hex = text.rpartition("|")
        if not sep or len(crc_hex) != 8:
            return None
        if zlib.crc32(body.encode("utf-8")) & 0xFFFFFFFF != int(crc_hex, 16):
            return None
        rec = json.loads(body)
        return rec if isinstance(rec, dict) else None
    except (ValueError, UnicodeDecodeError):
        return None


def _replay_kw(kind: str, rec: dict) -> dict:
    """cache_kw-shaped view of a done record, for stat-cache/overlay
    replay at resume."""
    if kind == "write":
        segs = rec.get("segs") or []
        return {"offset": 0,
                "nbytes": max((o + n for o, n, _ in segs), default=0)}
    if kind in ("truncate", "fallocate"):
        args = rec.get("args") or [0]
        return {"size": args[0]}
    if kind == "chmod":
        args = rec.get("args") or [0]
        return {"mode": args[0]}
    return {}


def _assemble(buf: list[tuple[int, bytes]]) -> bytes:
    """Materialize a diverted write stream (offset, data) into the file
    content it would produce (later writes win, holes zero-fill — the
    backends' write_at semantics)."""
    end = max((off + len(d) for off, d in buf), default=0)
    out = bytearray(end)
    for off, d in buf:
        out[off:off + len(d)] = d
    return bytes(out)


def _verify(content: bytes, segs: list) -> bool:
    """Does the recorded durable segment set prove ``content`` is already
    on the backend?  Every recorded (offset, length, crc32) must match
    the corresponding slice of ``content`` and the segments must exactly
    cover [0, len).  Overwritten segments fail the crc check and force
    the safe rewrite fallback — verification is allowed to be
    conservative, never wrong."""
    covered: list[tuple[int, int]] = []
    for off, ln, crc in segs:
        if off < 0 or off + ln > len(content):
            return False
        if zlib.crc32(content[off:off + ln]) & 0xFFFFFFFF != crc:
            return False
        covered.append((off, off + ln))
    covered.sort()
    pos = 0
    for lo, hi in covered:
        if lo > pos:
            return False
        pos = max(pos, hi)
    return pos == len(content)


# ---------------------------------------------------------------------------
# the parsed log
# ---------------------------------------------------------------------------

class SpillImage:
    """What the spill log proves about the interrupted window.

    ``events`` is the ordered stream of non-elided done records (for
    overlay/stat-cache replay); ``durable_*`` index the same facts for
    the elision queries; ``uncertain`` maps (kind, paths) of ops whose
    admit record has no matching done/fail — the in-flight set the kill
    made ambiguous, resolved by ``SpillManager.repair``."""

    def __init__(self):
        self.epoch = 0
        self.began = False
        self.committed = False
        self.journal: dict[str, bool] = {}          # path -> is_dir
        self.events: list[tuple[str, tuple, dict]] = []
        self.fails: list[tuple[str, tuple]] = []
        self.durable_dirs: set[str] = set()
        self.durable_files: dict[str, dict] = {}    # path -> {"segs": [...]}
        self.durable_meta: dict[tuple, list] = {}   # (path, kind) -> args
        self.removed: set[str] = set()
        self.uncertain: dict[tuple, int] = {}
        self.removal_uncertain: set[str] = set()
        self.probed: dict[str, bool] = {}           # path -> existed pre-op
        self.end_offset = 0
        self.nrecords = 0

    # -- construction ---------------------------------------------------

    @classmethod
    def parse(cls, raw: bytes) -> "SpillImage":
        img = cls()
        admits: dict[tuple, int] = {}
        settles: dict[tuple, int] = {}
        pos = 0
        while pos < len(raw):
            nl = raw.find(b"\n", pos)
            if nl < 0:
                break  # torn final line: monotone-prefix stop
            rec = _dec(raw[pos:nl + 1].rstrip(b"\n"))
            if rec is None:
                break  # gap or corruption: everything after is ignored
            t = rec.get("t")
            if t == "begin":
                # a new attempt supersedes everything before it
                img.__init__()
                admits, settles = {}, {}
                img.began = True
                img.epoch = int(rec.get("e", 0))
            elif not img.began or int(rec.get("e", -1)) != img.epoch:
                break  # pre-window noise or epoch mismatch: stop
            elif t == "admit":
                key = (rec["k"], tuple(rec["p"]))
                admits[key] = admits.get(key, 0) + 1
            elif t == "done":
                key = (rec["k"], tuple(rec["p"]))
                settles[key] = settles.get(key, 0) + 1
                if not rec.get("el"):
                    img._apply_done(rec["k"], tuple(rec["p"]), rec)
            elif t == "fail":
                key = (rec["k"], tuple(rec["p"]))
                settles[key] = settles.get(key, 0) + 1
                img.fails.append((rec["k"], tuple(rec["p"])))
            elif t == "jrnl":
                img.journal[rec["p"]] = bool(rec["d"])
            elif t == "pre":
                # create/write existence probe, recorded before the
                # backend call ran: last probe wins (monotone prefix —
                # a surviving later record implies all earlier survive)
                img.probed[rec["p"]] = bool(rec["x"])
            elif t == "jmv":
                src, dst = rec["s"], rec["d"]
                for p in [p for p in img.journal
                          if p == src or is_under(p, src)]:
                    img.journal[dst + p[len(src):]] = img.journal.pop(p)
            elif t == "committed":
                img.committed = True
            elif t == "rolledback":
                # the attempt's outputs are being (or have been) physically
                # removed: none of its records may be trusted again.  A
                # later ``begin`` reopens a fresh window.
                img.__init__()
                admits, settles = {}, {}
            pos = nl + 1
            img.end_offset = pos
            img.nrecords += 1
        for key, n in admits.items():
            open_n = n - settles.get(key, 0)
            if open_n > 0:
                img.uncertain[key] = open_n
                if key[0] in REMOVAL_KINDS:
                    img.removal_uncertain.update(key[1])
        return img

    def _apply_done(self, kind: str, paths: tuple, rec: dict) -> None:
        p = paths[0]
        if kind == "mkdir":
            self.durable_dirs.add(p)
            self.removed.discard(p)
        elif kind == "create":
            self.durable_files[p] = {"segs": []}
            self.removed.discard(p)
        elif kind == "write":
            segs = rec.get("segs")
            if segs is None:
                # unverifiable payload: the path can never be diverted
                self.durable_files.pop(p, None)
            else:
                self.durable_files.setdefault(p, {"segs": []})["segs"] \
                    .extend([tuple(s) for s in segs])
            self.removed.discard(p)
        elif kind in ("truncate", "fallocate"):
            # content changed behind the recorded segments: unverifiable
            self.durable_files.pop(p, None)
            if rec.get("args") is not None:
                self.durable_meta[(p, kind)] = list(rec["args"])
        elif kind in META_KINDS or kind in ("symlink", "link"):
            if rec.get("args") is not None:
                self.durable_meta[(p, kind)] = list(rec["args"])
        elif kind == "unlink":
            self.removed.add(p)
            self.durable_files.pop(p, None)
            self.durable_meta = {k: v for k, v in self.durable_meta.items()
                                 if k[0] != p}
        elif kind == "rmdir":
            self.removed.add(p)
            self.durable_dirs.discard(p)
        elif kind == "remove_tree":
            root = p
            self.purge_under(root)
            self.removed.update(paths)
        elif kind == "rename":
            src, dst = paths[0], paths[1]
            self._rekey(src, dst)
        self.events.append((kind, paths, rec))

    def _rekey(self, src: str, dst: str) -> None:
        for coll in (self.durable_files,):
            for q in [q for q in coll if q == src or is_under(q, src)]:
                coll[dst + q[len(src):]] = coll.pop(q)
        for q in [q for q in self.durable_dirs
                  if q == src or is_under(q, src)]:
            self.durable_dirs.discard(q)
            self.durable_dirs.add(dst + q[len(src):])
        for k in [k for k in self.durable_meta
                  if k[0] == src or is_under(k[0], src)]:
            args = self.durable_meta.pop(k)
            self.durable_meta[(dst + k[0][len(src):], k[1])] = args
        self.removed.add(src)
        self.removed.discard(dst)

    def purge_under(self, root: str) -> tuple:
        """Drop every durable claim at/under ``root`` and mark the set
        removed.  Returns the affected paths (root first) so resume can
        replay the removal into the caches."""
        hit = [root]
        for q in [q for q in self.durable_files
                  if q == root or is_under(q, root)]:
            self.durable_files.pop(q)
            hit.append(q)
        for q in [q for q in self.durable_dirs
                  if q == root or is_under(q, root)]:
            self.durable_dirs.discard(q)
            hit.append(q)
        for k in [k for k in self.durable_meta
                  if k[0] == root or is_under(k[0], root)]:
            self.durable_meta.pop(k)
        self.removed.update(hit)
        return tuple(dict.fromkeys(hit))

    def vouches(self, p: str) -> bool:
        """Did the interrupted run provably reach this path?  Idempotent
        re-execution tolerance (EEXIST on mkdir, ENOENT on removals) is
        scoped to vouched paths: a mount-wide tolerance would mask
        genuine errors on paths run 1 never touched — a pre-existing
        directory, a removal target the job never owned."""
        if (p in self.journal or p in self.durable_dirs
                or p in self.durable_files or p in self.removed
                or p in self.removal_uncertain or p in self.probed):
            return True
        if any(k[0] == p for k in self.durable_meta):
            return True
        if any(p in paths for _, paths in self.uncertain):
            return True
        if any(p in paths for _, paths in self.fails):
            return True
        # under a bulk-removal root, or under a directory this window
        # created: nothing pre-existing can live below a created-in-window
        # dir, so the whole subtree is the run's own even where no
        # per-path record survived the kill
        return (any(is_under(p, r) for r in self.removed)
                or any(is_under(p, q)
                       for q, d in self.journal.items() if d))


# ---------------------------------------------------------------------------
# the manager
# ---------------------------------------------------------------------------

class SpillManager:
    """Per-mount spill state machine (``CannyFS.enable_spill`` /
    ``CannyFS.resume``).  Thread-safe: record hooks run on executor
    workers, cuts on barrier callers, elision queries on the submitting
    thread."""

    def __init__(self, engine, spill_dir: str, *, flush_records: int = 64,
                 max_outstanding: int = 8):
        self.engine = engine
        self.spill_dir = norm_path(spill_dir)
        self.journal_path = f"{self.spill_dir}/{JOURNAL_FILE}"
        self.marker_path = f"{self.spill_dir}/{CUT_FILE}"
        self.flush_records = max(int(flush_records), 1)
        self.max_outstanding = max(int(max_outstanding), 1)
        self._lock = threading.Lock()
        self._pending: list[bytes] = []            # encoded, unchunked
        self._outstanding: dict[int, tuple[int, bytes]] = {}
        self._chunk_seq = 0
        self._reserved = 0                         # next journal offset
        self._nrecords = 0
        self._cut_records = 0
        self.epoch = 0
        self._began = False
        self.txn = None
        # resume-session state
        self.image: Optional[SpillImage] = None
        self._resumed = False
        self._dirty: set[str] = set()              # real mutations this run
        self._bufs: dict[str, list[tuple[int, bytes]]] = {}
        self._removed_roots: list[tuple[str, tuple]] = []

    # ------------------------------------------------------------------
    # setup
    # ------------------------------------------------------------------

    def prepare(self) -> None:
        """Materialize the spill directory directly on the backend (the
        spill must not depend on the engine it protects)."""
        b = self.engine.backend
        cur = ""
        for part in self.spill_dir.split("/"):
            cur = f"{cur}/{part}" if cur else part
            try:
                b.mkdir(cur)
            except OSError:
                pass

    @property
    def resuming(self) -> bool:
        return self._resumed

    def removed_roots(self) -> list[tuple[str, tuple]]:
        return list(self._removed_roots)

    # ------------------------------------------------------------------
    # recording (hot path: called by the engine and the transaction)
    # ------------------------------------------------------------------

    def record_admit(self, kind: str, paths: tuple) -> None:
        if kind not in SPILL_KINDS or not self._began:
            return
        self._append({"t": "admit", "e": self.epoch, "k": kind,
                      "p": list(paths)})

    def record_done(self, op, elided: bool) -> None:
        if op.kind not in SPILL_KINDS or not self._began:
            return
        rec: dict[str, Any] = {"t": "done", "e": self.epoch, "k": op.kind,
                               "p": list(op.paths)}
        if elided:
            rec["el"] = 1
        else:
            pl = op.payload
            seg_fn = getattr(pl, "segments", None)
            if op.kind == "write":
                if callable(seg_fn):
                    rec["segs"] = [
                        [off, len(d), zlib.crc32(d) & 0xFFFFFFFF]
                        for off, d in seg_fn()]
                # a write without a WritePayload is unverifiable: parse
                # drops the path from the divertable set (segs absent)
            else:
                args = getattr(pl, "args", None)
                if args is not None:
                    try:
                        rec["args"] = list(args)
                        json.dumps(rec["args"])
                    except (TypeError, ValueError):
                        rec.pop("args", None)
        self._append(rec)

    def record_fail(self, op) -> None:
        if op.kind not in SPILL_KINDS or not self._began:
            return
        self._append({"t": "fail", "e": self.epoch, "k": op.kind,
                      "p": list(op.paths)})

    def record_journal(self, path: str, is_dir: bool) -> None:
        if not self._began:
            return
        self._append({"t": "jrnl", "e": self.epoch, "p": path,
                      "d": 1 if is_dir else 0})

    def record_preexist(self, path: str, existed: bool) -> None:
        """Admit-time existence, recorded by the create/write worker the
        moment its journaling probe settles — BEFORE the backend call
        runs.  On resume this is ``repair``'s only licence to journal an
        uncertain create/write that landed: without a surviving absence
        proof, a write_at to a pre-existing file is indistinguishable in
        the log from a landed-but-unjournaled create, and journaling it
        would put pre-transaction user data into rollback scope."""
        if not self._began:
            return
        self._append({"t": "pre", "e": self.epoch, "p": path,
                      "x": 1 if existed else 0})

    def record_journal_rename(self, src: str, dst: str) -> None:
        if not self._began:
            return
        self._append({"t": "jmv", "e": self.epoch, "s": src, "d": dst})

    def _append(self, rec: dict) -> None:
        line = _enc(rec)
        key = None
        with self._lock:
            self._pending.append(line)
            self._nrecords += 1
            self.engine.stats.spill_records += 1
            if len(self._pending) >= self.flush_records:
                key = self._chunk_locked()
        if key is not None:
            self._dispatch(key)

    def _chunk_locked(self) -> Optional[int]:
        if not self._pending:
            return None
        data = b"".join(self._pending)
        self._pending.clear()
        key = self._chunk_seq
        self._chunk_seq += 1
        self._outstanding[key] = (self._reserved, data)
        self._reserved += len(data)
        return key

    def _dispatch(self, key: int) -> None:
        """Hand one chunk to the low-priority speculative lane; when the
        lane refuses (poisoned/closed/budget-full) the chunk simply waits
        in ``_outstanding`` for the next cut.  If the lane is starved by
        an eager storm (too many unlanded chunks) escalate to a
        synchronous flush so durability lag stays bounded."""
        op = self.engine._sched.submit_speculative(
            "write", (self.journal_path,), lambda: self._write_chunk(key))
        del op  # refusal is fine: cut() owns the fallback
        with self._lock:
            over = len(self._outstanding) > self.max_outstanding
        if over:
            self._flush_outstanding()

    def _write_chunk(self, key: int) -> None:
        with self._lock:
            ent = self._outstanding.pop(key, None)
        if ent is None:
            return
        off, data = ent
        try:
            self.engine.backend.write_at(self.journal_path, off, data)
        except BaseException:
            # speculative ops must never reach the ledger: re-shelve the
            # chunk for the next cut and swallow (the journal stays a
            # contiguous prefix either way)
            with self._lock:
                self._outstanding[key] = (off, data)
            return
        with self._lock:
            self.engine.stats.spill_flushes += 1
            self.engine.stats.spill_bytes += len(data)

    def _flush_outstanding(self) -> None:
        with self._lock:
            items = sorted(self._outstanding.items(),
                           key=lambda kv: kv[1][0])
            for k, _ in items:
                self._outstanding.pop(k)
        for i, (k, (off, data)) in enumerate(items):
            try:
                self.engine.backend.write_at(self.journal_path, off, data)
            except Exception:
                with self._lock:  # keep the failed suffix for the next cut
                    for k2, (off2, data2) in items[i:]:
                        self._outstanding[k2] = (off2, data2)
                return
            with self._lock:
                self.engine.stats.spill_flushes += 1
                self.engine.stats.spill_bytes += len(data)

    def cut(self) -> None:
        """Observation seal: chunk whatever is buffered, force every
        outstanding chunk down synchronously, stamp the marker.  Failures
        are swallowed — a barrier must never start raising because the
        spill medium hiccuped; the un-landed suffix just isn't provable
        on resume."""
        with self._lock:
            self._chunk_locked()
            clean = (not self._outstanding
                     and self._nrecords == self._cut_records)
            nrec = self._nrecords
        if clean:
            return
        self._flush_outstanding()
        with self._lock:
            landed = not self._outstanding
        if not landed:
            # some chunks still haven't landed: a marker stamped now
            # would name records that are not durable.  Leave the old
            # stamp; the next cut re-tries the flush and stamps then.
            return
        marker = f"{self.epoch:08d}:{nrec:012d}".encode("ascii")
        try:
            self.engine.backend.write_at(self.marker_path, 0, marker)
        except Exception:
            return
        with self._lock:
            self._cut_records = nrec
            self.engine.stats.spill_cuts += 1

    # ------------------------------------------------------------------
    # transaction lifecycle
    # ------------------------------------------------------------------

    def attach_txn(self, txn) -> None:
        with self._lock:
            self.txn = txn
            fresh = not self._began
            self._began = True
            image = self.image if self._resumed else None
        if fresh:
            self._append({"t": "begin", "e": self.epoch})
            # sync-cut the begin record: from this point the journal
            # itself proves an open window, so a stale "committed" marker
            # from the *previous* transaction can never be misread as
            # this window's completion (no op of this window can land
            # before attach returns)
            self.cut()
        if image is not None:
            # reinstall the proven journal: rollback of the resumed
            # attempt must remove run-1 outputs too.  Direct seeding —
            # going through _record_create would re-emit jrnl records.
            with txn._lock:
                txn._created.update(image.journal)

    def on_commit(self) -> None:
        """Retire the window: committed record, final cut, then the
        journal is unlinked — but the marker is REWRITTEN as a committed
        proof, not removed.  Whatever instant a kill strikes, either the
        journal still carries the committed record or the marker names
        the committed epoch; a restart can always tell "this window
        finished" from "this window never started"."""
        self._append({"t": "committed", "e": self.epoch})
        self.cut()
        b = self.engine.backend
        try:
            b.write_at(self.marker_path, 0,
                       f"committed:{self.epoch:011d}".encode("ascii"))
        except Exception:
            pass
        try:
            b.unlink(self.journal_path)
        except OSError:
            pass
        self._reset_session(rewind=True)

    def on_rollback(self) -> None:
        """Called at the *start* of ``Transaction.rollback``, before any
        output is removed: the tombstone must hit the log first, so a
        kill striking mid-rollback can never leave a resume trusting
        durable claims whose files are half-deleted.  Flush, don't
        discard: dropping buffered chunks would leave a hole before the
        next epoch's begin record, making it unreachable to the
        monotone-prefix parser."""
        self._append({"t": "rolledback", "e": self.epoch})
        try:
            self.cut()
        except Exception:
            pass
        self._reset_session(rewind=False)

    def _reset_session(self, *, rewind: bool) -> None:
        with self._lock:
            self.epoch += 1
            self._began = False
            self.txn = None
            self._resumed = False
            self.image = None
            self._dirty.clear()
            self._bufs.clear()
            self._removed_roots = []
            self._pending.clear()
            self._outstanding.clear()
            if rewind:
                self._reserved = 0
                self._nrecords = 0
                self._cut_records = 0

    # ------------------------------------------------------------------
    # resume: load + repair
    # ------------------------------------------------------------------

    def load(self) -> dict:
        b = self.engine.backend
        try:
            raw = b.read_at(self.journal_path, 0, -1)
        except OSError:
            raw = b""
        img = SpillImage.parse(raw)
        marker = None
        try:
            marker = b.read_at(self.marker_path, 0, -1) \
                .decode("ascii", "replace")
        except OSError:
            pass
        committed_marker = (marker or "").startswith("committed:")
        if img.committed or (committed_marker and not img.began):
            # the window finished: either the journal still carries the
            # committed record (killed before retirement completed) or
            # retirement already ran and only the marker proof remains.
            # Finish the journal cleanup, keep the marker proof.
            try:
                b.unlink(self.journal_path)
            except OSError:
                pass
            with self._lock:
                self.epoch = img.epoch + 1
            return {"resumable": False, "committed": True, "marker": marker,
                    "records": img.nrecords}
        if img.end_offset < len(raw):
            # stale tail beyond the first gap: same-epoch records there
            # must not "reconnect" behind the appends we are about to make
            try:
                b.truncate(self.journal_path, img.end_offset)
            except OSError:
                pass
        with self._lock:
            self.image = img
            self.epoch = img.epoch
            self._reserved = img.end_offset
            self._nrecords = img.nrecords
            self._cut_records = img.nrecords
            self._began = img.began
            self._resumed = img.began
        return {
            "resumable": img.began, "committed": False, "marker": marker,
            "records": img.nrecords, "journal_paths": len(img.journal),
            "durable_dirs": len(img.durable_dirs),
            "durable_files": len(img.durable_files),
            "durable_meta": len(img.durable_meta),
            "removed": len(img.removed),
            "uncertain": sum(img.uncertain.values()),
        }

    def repair(self) -> dict:
        """Resolve the kill's in-flight ambiguity directly against the
        backend (the resume-time analogue of rollback's verification
        pass): re-issue uncertain bulk removals (healing a partially
        applied bulk DELETE), merge torn COPY+DELETE renames, probe
        uncertain removals, and journal any landed-but-unjournaled
        create so a later rollback cannot leak it."""
        if not self._resumed:
            return {"repairs": 0}
        b = self.engine.backend
        im = self.image
        repairs = 0
        for kind, paths in sorted(im.uncertain):
            p = paths[0]
            if kind == "remove_tree":
                try:
                    b.remove_tree(p)
                except FileNotFoundError:
                    pass   # the bulk DELETE fully applied before the kill
                except OSError:
                    continue
                self._removed_roots.append((p, im.purge_under(p)))
                repairs += 1
            elif kind in ("unlink", "rmdir"):
                try:
                    st = b.stat(p)
                except OSError:
                    continue
                if not st.exists:
                    im.durable_files.pop(p, None)
                    im.durable_dirs.discard(p)
                    im.removed.add(p)
                    self._removed_roots.append((p, (p,)))
            elif kind == "mkdir":
                try:
                    st = b.stat(p)
                except OSError:
                    continue
                if st.exists and st.is_dir:
                    im.durable_dirs.add(p)
                    if p not in im.journal:
                        im.journal[p] = True
                        self.record_journal(p, True)
                    repairs += 1
            elif kind in ("create", "write"):
                try:
                    st = b.stat(p)
                except OSError:
                    continue
                if not st.exists or p in im.journal:
                    continue
                if im.probed.get(p) is False:
                    # the op landed but its journal write did not, and a
                    # surviving probe record proves the path was absent
                    # before the op — it is truly this window's creation.
                    # Journal it, or rollback would *leak* the file (and
                    # a re-run's existence probe would wrongly memo it as
                    # pre-existing).
                    im.journal[p] = False
                    self.record_journal(p, False)
                    repairs += 1
                # no absence proof: leave the path unjournaled.  It may
                # be a pre-existing file whose write_at was in flight at
                # the kill; a leaked-on-rollback file is recoverable,
                # unlinking pre-transaction data is not.
            elif kind == "rename" and len(paths) == 2:
                if self._repair_rename(b, paths[0], paths[1]):
                    repairs += 1
        invalidated = self._validate_claims(b, im)
        with self._lock:
            self.engine.stats.resume_repairs += repairs
        return {"repairs": repairs, "invalidated": invalidated}

    def _validate_claims(self, b, im: "SpillImage") -> int:
        """Existence-check every durable claim.  A record proves the op
        was durable *at record time* — a structural op that landed after
        the last cut with no surviving record (rename, unlink, a bulk
        delete) may have invalidated it since.  One vectored stat batch
        over the proven set, no tree walk; a vanished path loses its
        claims (and its replay events), so the re-run executes it for
        real instead of eliding against a ghost."""
        probe = sorted(set(im.durable_files) | im.durable_dirs
                       | {k[0] for k in im.durable_meta})
        if not probe:
            return 0
        try:
            sts = b.stat_vec(probe)
        except OSError:
            sts = {}
        gone = set()
        for p in probe:
            st = sts.get(p)
            if st is None:
                try:
                    st = b.stat(p)
                except OSError:
                    continue
            if not st.exists:
                gone.add(p)
        if not gone:
            return 0
        dropped = 0
        for p in gone:
            if im.durable_files.pop(p, None) is not None:
                dropped += 1
            if p in im.durable_dirs:
                im.durable_dirs.discard(p)
                dropped += 1
        n_meta = len(im.durable_meta)
        im.durable_meta = {k: v for k, v in im.durable_meta.items()
                           if k[0] not in gone}
        dropped += n_meta - len(im.durable_meta)
        im.events = [(k, ps, r) for k, ps, r in im.events
                     if not any(q in gone for q in ps)]
        return dropped

    def _repair_rename(self, b, src: str, dst: str) -> bool:
        try:
            s_exists = b.stat(src).exists
            d_exists = b.stat(dst).exists
        except OSError:
            return False
        changed = False
        if s_exists and not d_exists:
            try:
                b.rename(src, dst)
                changed = True
            except OSError:
                return False
        elif s_exists and d_exists:
            # torn COPY+DELETE: keys live on both sides.  A key whose
            # dst copy is verified byte-identical to src is complete
            # (dst wins); any other dst — including a pre-existing
            # rename target whose COPY never ran — is overwritten from
            # src, never trusted.
            self._merge_move(b, src, dst)
            changed = True
        if not s_exists and not d_exists:
            return False
        # finish the journal's rekey exactly as _record_rename would have
        im = self.image
        for p in [p for p in im.journal if p == src or is_under(p, src)]:
            im.journal[dst + p[len(src):]] = im.journal.pop(p)
        self.record_journal_rename(src, dst)
        im._rekey(src, dst)
        return changed

    def _merge_move(self, b, src: str, dst: str) -> None:
        try:
            st = b.stat(src)
        except OSError:
            return
        if not st.exists:
            return
        if not st.is_dir:
            try:
                dstat = b.stat(dst)
            except OSError:
                return
            if not dstat.exists:
                try:
                    b.rename(src, dst)
                except OSError:
                    pass
                return
            # unlink src ONLY when dst is provably the completed copy
            # (same size, identical bytes).  When the rename target
            # pre-existed (rename-over-existing semantics) and the COPY
            # phase never started, dst holds the stale old content and
            # unlinking src would destroy the only copy of the moved
            # data — re-issue the rename instead (src wins); failing
            # that, keep both and dirty dst so the re-run rewrites it.
            same = dstat.size == st.size
            if same and st.size:
                try:
                    same = (zlib.crc32(b.read_at(src, 0, -1))
                            == zlib.crc32(b.read_at(dst, 0, -1)))
                except OSError:
                    same = False
            try:
                if same:
                    b.unlink(src)
                else:
                    b.rename(src, dst)
            except OSError:
                with self._lock:
                    self._dirty.add(dst)
            return
        try:
            b.mkdir(dst)
        except OSError:
            pass
        try:
            names = b.readdir(src)
        except OSError:
            names = []
        for name in names:
            self._merge_move(b, f"{src}/{name}", f"{dst}/{name}")
        try:
            b.rmdir(src)
        except OSError:
            pass

    # ------------------------------------------------------------------
    # resume-session elision / diversion queries (called by the fs layer)
    # ------------------------------------------------------------------

    def note_paths(self, fs, kind: str, paths: tuple) -> None:
        """Every *real* submitted mutation marks its paths dirty (no
        later elision may trust the stale image for them) and force-
        finalizes any diverted stream it touches, so op order around the
        diversion stays FIFO-correct."""
        if kind not in SPILL_KINDS:
            return
        flush = []
        with self._lock:
            for p in paths:
                if p in self._bufs:
                    flush.append(p)
                self._dirty.add(p)
        for p in flush:
            self.finalize(fs, p)

    def elide_mkdir(self, p: str) -> bool:
        if not self._resumed:
            return False
        with self._lock:
            return p in self.image.durable_dirs and p not in self._dirty

    def divert_create(self, p: str) -> bool:
        if not self._resumed:
            return False
        with self._lock:
            if (p in self._dirty or p in self._bufs
                    or p not in self.image.durable_files):
                return False
            self._bufs[p] = []
            return True

    def divert_write(self, p: str, offset: int, data: bytes) -> bool:
        if not self._resumed:
            return False
        with self._lock:
            buf = self._bufs.get(p)
            if buf is None:
                return False
            buf.append((offset, data))
            return True

    def elide_meta(self, kind: str, p: str, args: tuple) -> bool:
        if not self._resumed:
            return False
        with self._lock:
            if p in self._dirty or p in self._bufs:
                return False
            rec = self.image.durable_meta.get((p, kind))
            return rec is not None and list(rec) == list(args)

    def elide_unlink(self, p: str) -> bool:
        if not self._resumed:
            return False
        with self._lock:
            return (p in self.image.removed and p not in self._dirty
                    and p not in self._bufs)

    def elide_rmdir(self, p: str) -> bool:
        if not self._resumed:
            return False
        with self._lock:
            if p not in self.image.removed or p in self._dirty:
                return False
            return not any(is_under(q, p) for q in self._dirty)

    def elide_remove_root(self, p: str) -> bool:
        """May the whole ``rmtree(p)`` recursion be skipped?  Only when
        the removal is durably complete: the root is gone, nothing at or
        under it still holds a durable claim, and nothing under it was
        re-created for real this session."""
        if not self._resumed:
            return False
        with self._lock:
            im = self.image
            if p not in im.removed:
                return False
            if p in self._dirty or any(is_under(q, p) for q in self._dirty):
                return False
            if any(q == p or is_under(q, p) for q in im.durable_dirs):
                return False
            if any(q == p or is_under(q, p) for q in im.durable_files):
                return False
            return True

    def session_tolerant(self, p: str) -> bool:
        """Should a re-executed mkdir of ``p`` tolerate FileExistsError?
        Only on a resumed attempt, and only for paths the image vouches
        for (journaled, claimed, probed, uncertain, or under a subtree
        this window owns): the interrupted run's op may have landed
        without its record surviving the kill, so EEXIST there is the
        re-execution meeting run 1's own output.  Anywhere else the
        error is genuine — a fresh run would surface it too — and must
        not be masked."""
        if not self._resumed:
            return False
        with self._lock:
            return self.image is not None and self.image.vouches(p)

    def removal_tolerant(self, p: str) -> bool:
        """Should a re-executed unlink/rmdir tolerate absence?  Same
        scoping as ``session_tolerant``: the interrupted run's removal
        (or the repair pass) may already have taken a *vouched* path
        down without a surviving record; an ENOENT on a path run 1
        never touched is a real error."""
        if not self._resumed:
            return False
        with self._lock:
            return self.image is not None and self.image.vouches(p)

    # -- diverted-stream settlement -------------------------------------

    def finalize(self, fs, p: str) -> bool:
        """Settle one diverted create+write stream: verify the buffered
        content against the recorded durable segment checksums.  A match
        proves the backend already holds exactly these bytes — the whole
        stream is elided; any mismatch falls back to a plain rewrite
        (create + one covering write), marking the path dirty."""
        with self._lock:
            buf = self._bufs.pop(p, None)
            rec = (self.image.durable_files.get(p)
                   if self.image is not None else None)
        if buf is None:
            return False
        content = _assemble(buf)
        if rec is not None and _verify(content, rec["segs"]):
            with self._lock:
                self.engine.stats.resume_elided_ops += 1 + len(buf)
            return True
        with self._lock:
            self._dirty.add(p)
        fs.create(p)
        if content:
            fs._write_at(p, 0, content)
        return True

    def finalize_all(self, fs) -> None:
        while True:
            with self._lock:
                live = next(iter(self._bufs), None)
            if live is None:
                return
            self.finalize(fs, live)


__all__ = ["SPILL_KINDS", "SpillImage", "SpillManager", "commit_marker_ok"]
