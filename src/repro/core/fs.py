"""CannyFS — the POSIX-ish user API over the eager engine.

This is the in-process equivalent of the paper's FUSE mount: a task loops
over `mkdir/open/write/close/...` calls exactly as it would against a kernel
filesystem, and each call is either eagerly ACKed (background execution,
per-path ordering, deferred errors) or executed synchronously, per the
EagerFlags.
"""
from __future__ import annotations

import threading
import time
from typing import Iterable, Optional

from .backend import StorageBackend, StatResult, norm_path, parent_of
from .engine import EagerIOEngine
from .errors import ErrorLedger
from .flags import EagerFlags


class CannyFile:
    """Streaming file handle.

    Writes are queued eagerly with a running offset; the buffer is handed to
    the worker without copying (the user-space analogue of the paper's
    splice-based zero-copy path — we transfer ownership of the `bytes`
    object instead of kernel pipe pages).
    """

    def __init__(self, fs: "CannyFS", path: str, mode: str):
        if mode not in ("wb", "rb", "ab"):
            raise ValueError(f"mode {mode!r} not supported")
        self.fs = fs
        self.path = norm_path(path)
        self.mode = mode
        self._offset = 0
        self._closed = False
        if mode == "wb":
            fs.create(self.path)
        elif mode == "ab":
            st = fs.stat(self.path)
            self._offset = st.size if st.exists else 0
            if not st.exists:
                fs.create(self.path)

    # -- write side --
    def write(self, data: bytes) -> int:
        if self.mode == "rb":
            raise IOError("file opened read-only")
        if self._closed:
            raise ValueError("I/O on closed file")
        data = bytes(data)  # freeze caller's view; engine takes ownership
        off = self._offset
        self._offset += len(data)
        self.fs._write_at(self.path, off, data)
        return len(data)

    # -- read side --
    def read(self, size: int = -1) -> bytes:
        if self.mode != "rb":
            raise IOError("file opened write-only")
        out = self.fs.pread(self.path, self._offset, size)
        self._offset += len(out)
        return out

    def seek(self, offset: int) -> None:
        self._offset = int(offset)

    def tell(self) -> int:
        return self._offset

    def flush(self) -> None:
        self.fs.flush(self.path)

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        if self.mode in ("wb", "ab"):
            self.fs._on_close_write(self.path)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class CannyFS:
    """The mount object.  One per 'job'; all methods are thread-safe."""

    def __init__(self, backend: StorageBackend, *,
                 flags: EagerFlags | None = None,
                 max_inflight: int = 300,
                 workers: int = 32,
                 executor: str = "pool",
                 abort_on_error: bool = False):
        self.flags = flags or EagerFlags()
        self.engine = EagerIOEngine(
            backend, flags=self.flags, max_inflight=max_inflight,
            workers=workers, executor=executor, abort_on_error=abort_on_error)
        self.backend = backend
        self._txn_lock = threading.Lock()
        self._txn = None  # active Transaction (set by Transaction.__enter__)

    # ------------------------------------------------------------------
    # plumbing
    # ------------------------------------------------------------------

    def _submit(self, kind: str, paths: tuple[str, ...], fn, *,
                cache_kw: dict | None = None):
        eager = self.flags.is_eager(kind)
        return self.engine.submit(kind, paths, fn, eager=eager,
                                  cache_kw=cache_kw)

    def _journal_create(self, path: str, is_dir: bool) -> None:
        txn = self._txn
        if txn is not None:
            txn._record_create(norm_path(path), is_dir)

    def _journal_rename(self, src: str, dst: str) -> None:
        txn = self._txn
        if txn is not None:
            txn._record_rename(norm_path(src), norm_path(dst))

    # ------------------------------------------------------------------
    # namespace ops
    # ------------------------------------------------------------------

    def mkdir(self, path: str) -> None:
        b = self.backend
        self._journal_create(path, True)
        self._submit("mkdir", (path,), lambda: b.mkdir(path), cache_kw={})

    def makedirs(self, path: str, exist_ok: bool = True) -> None:
        parts = norm_path(path).split("/")
        cur = ""
        for part in parts:
            cur = f"{cur}/{part}" if cur else part
            st = self.engine.stat_cache.get(cur)
            if st is not None and st.exists:
                continue
            if not self.flags.mkdir and self.exists(cur):
                continue
            b, p = self.backend, cur

            def fn(p=p):
                try:
                    b.mkdir(p)
                except FileExistsError:
                    if not exist_ok:
                        raise
            self._journal_create(p, True)
            self._submit("mkdir", (p,), fn, cache_kw={})

    def rmdir(self, path: str) -> None:
        b = self.backend
        self._submit("rmdir", (path,), lambda: b.rmdir(path), cache_kw={})

    def create(self, path: str) -> None:
        b = self.backend
        self._journal_create(path, False)
        self._submit("create", (path,), lambda: b.create(path), cache_kw={})

    def unlink(self, path: str) -> None:
        b = self.backend
        self._submit("unlink", (path,), lambda: b.unlink(path), cache_kw={})

    def rename(self, src: str, dst: str) -> None:
        b = self.backend
        self._journal_rename(src, dst)
        self._submit("rename", (src, dst), lambda: b.rename(src, dst),
                     cache_kw={})

    def symlink(self, target: str, path: str) -> None:
        b = self.backend
        self._journal_create(path, False)
        self._submit("symlink", (path,), lambda: b.symlink(target, path),
                     cache_kw={})

    def link(self, src: str, dst: str) -> None:
        b = self.backend
        self._journal_create(dst, False)
        self._submit("link", (src, dst), lambda: b.link(src, dst))

    def readlink(self, path: str) -> str:
        b = self.backend
        return self.engine.submit("readlink", (path,),
                                  lambda: b.readlink(path), eager=False)

    # ------------------------------------------------------------------
    # data ops
    # ------------------------------------------------------------------

    def _write_at(self, path: str, offset: int, data: bytes) -> None:
        b = self.backend
        self._submit("write", (path,), lambda: b.write_at(path, offset, data),
                     cache_kw={"offset": offset, "nbytes": len(data)})

    def write_file(self, path: str, data: bytes) -> None:
        """create + write + close — the common whole-file put."""
        with self.open(path, "wb") as f:
            f.write(data)

    def pread(self, path: str, offset: int, size: int) -> bytes:
        """Data reads are never eager (paper §2)."""
        b = self.backend
        return self.engine.submit("read", (path,),
                                  lambda: b.read_at(path, offset, size),
                                  eager=False)

    def read_file(self, path: str) -> bytes:
        return self.pread(path, 0, -1)

    def open(self, path: str, mode: str = "rb") -> CannyFile:
        return CannyFile(self, path, mode)

    def truncate(self, path: str, size: int) -> None:
        b = self.backend
        self._submit("truncate", (path,), lambda: b.truncate(path, size),
                     cache_kw={"size": size})

    def fallocate(self, path: str, size: int) -> None:
        b = self.backend
        self._submit("fallocate", (path,), lambda: b.fallocate(path, size),
                     cache_kw={"size": size})

    def flush(self, path: str) -> None:
        if self.flags.flush:
            return  # eager flush == no-op ACK; data ordering is per-path
        self.engine.barrier(path)

    def fsync(self, path: str) -> None:
        b = self.backend
        self._submit("fsync", (path,), lambda: b.fsync(path))

    def _on_close_write(self, path: str) -> None:
        """close() of a written file: with eager flush this is an immediate
        ACK; otherwise it is a barrier (NFS close-to-open consistency —
         'the closing of files a barrier', paper §5)."""
        if not self.flags.flush:
            self.engine.barrier(path)

    # ------------------------------------------------------------------
    # metadata ops
    # ------------------------------------------------------------------

    def chmod(self, path: str, mode: int) -> None:
        b = self.backend
        self._submit("chmod", (path,), lambda: b.chmod(path, mode),
                     cache_kw={"mode": mode})

    def chown(self, path: str, uid: int, gid: int) -> None:
        b = self.backend
        self._submit("chown", (path,), lambda: b.chown(path, uid, gid))

    def utimens(self, path: str, atime: float, mtime: float) -> None:
        b = self.backend
        self._submit("utimens", (path,), lambda: b.utimens(path, atime, mtime))

    def setxattr(self, path: str, key: str, value: bytes) -> None:
        b = self.backend
        self._submit("setxattr", (path,), lambda: b.setxattr(path, key, value))

    def removexattr(self, path: str, key: str) -> None:
        b = self.backend
        self._submit("removexattr", (path,),
                     lambda: b.removexattr(path, key))

    def stat(self, path: str) -> StatResult:
        path = norm_path(path)
        if self.flags.mock_stat:
            hit = self.engine.stat_cache.get(path)
            if hit is not None and (hit.exists or self.flags.negative_stat_cache):
                self.engine.stats.mocked_stats += 1
                return hit
        b = self.backend
        cache = self.engine.stat_cache

        def fn():
            hit = cache.get(path)
            if hit is not None:
                return hit
            st = b.stat(path)
            cache.put(path, st)
            return st

        return self.engine.submit("stat", (path,), fn, eager=False)

    def exists(self, path: str) -> bool:
        return self.stat(path).exists

    def readdir(self, path: str) -> list[str]:
        path = norm_path(path)
        b = self.backend
        names = self.engine.submit("readdir", (path,),
                                   lambda: b.readdir(path), eager=False)
        if self.flags.readdir_prefetch:
            cache = self.engine.stat_cache
            for name in names:
                child = f"{path}/{name}" if path else name
                if cache.get(child) is None:
                    def pf(child=child):
                        if cache.get(child) is None:
                            cache.put(child, b.stat(child))
                    self.engine.submit("stat", (child,), pf, eager=True)
                    self.engine.stats.prefetched_stats += 1
        return names

    listdir = readdir

    # ------------------------------------------------------------------
    # composite workloads
    # ------------------------------------------------------------------

    def rmtree(self, path: str) -> None:
        """`rm -rf` — the paper's second benchmark.  readdir prefetch makes
        the per-entry stat a cache hit; unlinks/rmdirs are eager, and the
        engine's pending-children edges keep each rmdir after its subtree."""
        path = norm_path(path)
        for name in self.readdir(path):
            child = f"{path}/{name}" if path else name
            st = self.stat(child)
            if st.is_dir:
                self.rmtree(child)
            else:
                self.unlink(child)
        self.rmdir(path)

    def walk(self, path: str = ""):
        """Generator of (dir, subdirs, files) — `find`/`du`-style traversal."""
        path = norm_path(path)
        names = self.readdir(path)
        dirs, files = [], []
        for name in names:
            child = f"{path}/{name}" if path else name
            (dirs if self.stat(child).is_dir else files).append(name)
        yield path, dirs, files
        for d in dirs:
            child = f"{path}/{d}" if path else d
            yield from self.walk(child)

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------

    @property
    def ledger(self) -> ErrorLedger:
        return self.engine.ledger

    def drain(self) -> None:
        self.engine.drain()

    def close(self) -> None:
        """Unmount: drain all pending I/O and report deferred errors —
        the benchmarked 'fully killing the CannyFS process' step."""
        self.engine.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
