"""CannyFS — the POSIX-ish user API over the eager engine.

This is the in-process equivalent of the paper's FUSE mount: a task loops
over `mkdir/open/write/close/...` calls exactly as it would against a kernel
filesystem, and each call is either eagerly ACKed (background execution,
per-path ordering, deferred errors) or executed synchronously, per the
EagerFlags.
"""
from __future__ import annotations

import threading
import time
from contextlib import contextmanager
from typing import Iterable, Optional

from .backend import StorageBackend, StatResult, norm_path, parent_of
from .durability import SpillManager, _replay_kw
from .engine import EagerIOEngine
from .errors import ErrorLedger, ShortWriteError
from .flags import EagerFlags
from .fusion import FusionPolicy, MetaPayload, WritePayload
from .namespace import OverlayPolicy
from .prefetch import PrefetchPolicy
from .readahead import ReadPolicy


class CannyFile:
    """Streaming file handle.

    Writes are queued eagerly with a running offset; the buffer is handed to
    the worker without copying (the user-space analogue of the paper's
    splice-based zero-copy path — we transfer ownership of the `bytes`
    object instead of kernel pipe pages).
    """

    def __init__(self, fs: "CannyFS", path: str, mode: str):
        if mode not in ("wb", "rb", "ab"):
            raise ValueError(f"mode {mode!r} not supported")
        self.fs = fs
        self.path = norm_path(path)
        self.mode = mode
        self._offset = 0
        self._closed = False
        if mode == "wb":
            fs.create(self.path)
        elif mode == "ab":
            st = fs.stat(self.path)
            self._offset = st.size if st.exists else 0
            if not st.exists:
                fs.create(self.path)

    # -- write side --
    def write(self, data: bytes) -> int:
        if self.mode == "rb":
            raise IOError("file opened read-only")
        if self._closed:
            raise ValueError("I/O on closed file")
        data = bytes(data)  # freeze caller's view; engine takes ownership
        off = self._offset
        self._offset += len(data)
        self.fs._write_at(self.path, off, data)
        return len(data)

    # -- read side --
    def read(self, size: int = -1) -> bytes:
        if self.mode != "rb":
            raise IOError("file opened write-only")
        out = self.fs.pread(self.path, self._offset, size)
        self._offset += len(out)
        return out

    def seek(self, offset: int) -> None:
        self._offset = int(offset)

    def tell(self) -> int:
        return self._offset

    def flush(self) -> None:
        self.fs.flush(self.path)

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        if self.mode in ("wb", "ab"):
            self.fs._on_close_write(self.path)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class CannyFS:
    """The mount object.  One per 'job'; all methods are thread-safe."""

    def __init__(self, backend: StorageBackend, *,
                 flags: EagerFlags | None = None,
                 max_inflight: int = 300,
                 workers: int = 32,
                 executor: str = "pool",
                 abort_on_error: bool = False,
                 echo_errors: bool = True,
                 fusion: FusionPolicy | bool | None = None,
                 overlay: OverlayPolicy | bool | None = None,
                 prefetch: PrefetchPolicy | bool | None = None,
                 readahead: ReadPolicy | bool | None = None,
                 work_stealing: bool = True,
                 clock=None):
        self.flags = flags or EagerFlags()
        self.engine = EagerIOEngine(
            backend, flags=self.flags, max_inflight=max_inflight,
            workers=workers, executor=executor, abort_on_error=abort_on_error,
            ledger=ErrorLedger(echo=echo_errors), fusion=fusion,
            overlay=overlay, prefetch=prefetch, readahead=readahead,
            work_stealing=work_stealing, clock=clock)
        self.backend = backend
        self._txn_lock = threading.Lock()
        self._txn = None  # active Transaction (set by Transaction.__enter__)
        self._detached = threading.local()  # per-thread txn opt-out

    # ------------------------------------------------------------------
    # plumbing
    # ------------------------------------------------------------------

    _REGION_UNSET = object()

    # tenancy hooks (PR 10): the base mount is the untenanted whole-
    # namespace view, so these default to no-op / engine-global.  The
    # ``Tenant`` handle (core/tenancy.py) shares this engine but overrides
    # the hooks, and every public op below inherits prefix confinement,
    # quota admission, per-tenant spill/poison/retry bookkeeping and
    # prefix-scoped cache clears without further changes here.
    _tenant_state = None  # scheduler-side _TenantState; Tenant sets it

    def tenant(self, name: str, root_prefix: str | None = None,
               weight: float = 1.0, quota=None) -> "CannyFS":
        """Open a tenant handle on this mount's engine: a ``CannyFS``-
        shaped view confined to ``root_prefix`` (default: ``name``) with
        its own failure domain (ledger tag, poison flag, rollback and
        spill scope), a DWRR dispatch weight, and an optional
        ``TenantQuota`` byte/inode budget."""
        from .tenancy import Tenant
        return Tenant(self, name,
                      root_prefix if root_prefix is not None else name,
                      weight=weight, quota=quota)

    def _spill(self):
        """The spill journal this view records to — the tenant's own for
        Tenant handles (never the shared engine journal), else the
        engine's."""
        return self.engine._spill_for(self._tenant_state)

    def _check_paths(self, kind: str, paths) -> None:
        """Namespace confinement hook: Tenant raises PermissionError for
        paths outside its root prefix.  No-op on the base mount."""

    def _quota_admit(self, kind: str, paths, cache_kw=None) -> None:
        """Quota admission hook, charged synchronously at ACK time (the
        caller sees EDQUOT/ENOSPC, not a deferred ledger entry).  Charges
        are high-water per path, so the fused-write fast path and the
        engine submit path may both call this for one op without double
        counting.  No-op on the base mount."""

    def _note_fused(self) -> None:
        """A write/meta op of this view was absorbed by the coalescer."""
        ts = self._tenant_state
        if ts is not None:
            ts.stats.fused += 1

    def _note_retry(self) -> None:
        """run_transaction retry bookkeeping — engine-global counter plus
        the submitting tenant's own, so one tenant's transient-error storm
        is visible (and billable) per tenant."""
        self.engine.stats.retries += 1
        ts = self._tenant_state
        if ts is not None:
            ts.stats.retries += 1

    def _note_rollback(self, n_leftovers: int) -> None:
        self.engine.stats.rollbacks += 1
        self.engine.stats.rollback_leftovers += n_leftovers
        ts = self._tenant_state
        if ts is not None:
            ts.stats.rollbacks += 1

    def _backoff_salt(self) -> str:
        """Extra salt for run_transaction's deterministic backoff RNG:
        the tenant name, so per-tenant retry schedules are independent
        streams (one tenant's attempt count never perturbs a
        neighbour's jitter)."""
        return ""

    def _reset_poison(self) -> None:
        """Scope-aware poison clear: the whole engine for the base mount,
        only this tenant's flag for a Tenant handle."""
        self.engine.reset_poison(self._tenant_state)

    def _clear_window_caches(self, *, rollback: bool) -> None:
        """Drop the optimization-window caches at a commit/rollback
        boundary.  The base mount owns the whole namespace and clears
        wholesale (matching pre-tenancy behaviour exactly); a Tenant
        clears the overlay only under its own prefix so a neighbour's
        open window survives the boundary."""
        eng = self.engine
        ov = eng.overlay
        if ov is not None:
            ov.clear()
        if rollback and eng.readahead is not None:
            eng.readahead.clear()
        sb = eng.stat_batcher
        if sb is not None:
            sb.clear()

    def _submit(self, kind: str, paths: tuple[str, ...], fn, *,
                cache_kw: dict | None = None, region=_REGION_UNSET,
                payload=None):
        paths_n = tuple(norm_path(p) for p in paths)
        self._check_paths(kind, paths_n)
        self._quota_admit(kind, paths_n, cache_kw)
        sp = self._spill()
        if sp is not None:
            # real mutations poison the spill image for their paths (no
            # later elision may trust run-1 state there) and force-settle
            # any diverted stream they touch, keeping FIFO order intact
            sp.note_paths(self, kind, paths_n)
        eager = self.flags.is_eager(kind)
        # tag the op with the active transaction so its deferred error is
        # attributed (and later scope-cleared) exactly, even when another
        # region opens before this one's rollback runs.  Journaling ops
        # pass the txn they captured so tag and journal can never diverge.
        if region is CannyFS._REGION_UNSET:
            region = self._active_txn()
        return self.engine.submit(kind, paths, fn, eager=eager,
                                  cache_kw=cache_kw, region=region,
                                  payload=payload, tenant=self._tenant_state)

    def _active_txn(self):
        """The transaction to journal into, captured at submission time.
        _active flips on only once __enter__ completes — work racing the
        open is pre-region and must not be journaled (a rollback would
        otherwise delete it)."""
        if getattr(self._detached, "on", False):
            return None
        txn = self._txn
        return txn if (txn is not None and txn._active) else None

    @contextmanager
    def detached(self):
        """Run the enclosed I/O outside any active transaction on this
        thread: nothing is journaled and deferred errors stay untagged.
        For subsystems with their own commit protocol (the checkpoint
        manager) whose files must not be rolled back — or whose failures
        blamed on — a user transaction that happens to be open."""
        prev = getattr(self._detached, "on", False)
        self._detached.on = True
        try:
            yield self
        finally:
            self._detached.on = prev

    def _submit_journaled(self, kind: str, paths: tuple[str, ...], call,
                          journal, *, cache_kw: dict | None = None):
        """Delegate, then journal into the region on *success*, from the
        executing worker: a failed (or pre-existing-target) op created
        nothing, so rollback must not remove it.  The txn is captured at
        submission — keeping the ledger region tag and the journal in
        lockstep — and rollback's drain guarantees every journal write
        lands before the journal is read."""
        txn = self._active_txn()

        def fn():
            out = call()
            if txn is not None:
                journal(txn)
            return out

        return self._submit(kind, paths, fn, cache_kw=cache_kw, region=txn)

    # ------------------------------------------------------------------
    # namespace ops
    # ------------------------------------------------------------------

    def mkdir(self, path: str) -> None:
        b, p, txn = self.backend, norm_path(path), self._active_txn()
        sp = self._spill()
        if sp is not None and sp.elide_mkdir(p):
            # provably durable from the interrupted run: refresh the
            # claims (journal membership was seeded at attach) and skip
            # the backend roundtrip
            self._elide_replay("mkdir", (p,), {})
            return

        def fn():
            try:
                b.mkdir(p)
            except FileExistsError:
                if sp is None or not sp.session_tolerant(p):
                    # not a path the spill image vouches for: a fresh run
                    # would surface this EEXIST too — don't mask it
                    raise
                # idempotent re-execution: the interrupted run's mkdir on
                # a vouched path landed but was not provably durable (its
                # record missed the last cut).  The dir exists with
                # unknown contents — keep the membership delta, drop
                # completeness.  NOT journaled: there is no proof the dir
                # was absent before the window (it may pre-date the job),
                # and a pre-existing — possibly empty, hence rmdir-able —
                # directory must never enter rollback scope; if run 1 did
                # create it, the journal seeded at attach already covers
                # it.
                ov2 = self.engine.overlay
                if ov2 is not None:
                    ov2.demote(p)
                return
            # the dir provably came into existence fresh and empty just
            # now: the overlay's provisional admit-time claim is promoted
            # to backend-proven (journal + promote on *success* only — a
            # failed mkdir created nothing and is invalidated instead)
            ov = self.engine.overlay
            if ov is not None:
                ov.promote(p)
            if txn is not None:
                txn._record_create(p, True)

        self._submit("mkdir", (p,), fn, cache_kw={}, region=txn)

    def makedirs(self, path: str, exist_ok: bool = True) -> None:
        parts = norm_path(path).split("/")
        cur = ""
        txn = self._active_txn()
        # vectored parent probe: in sync-mkdir mode every uncached
        # component below pays one backend stat roundtrip (the
        # ``self.exists`` check) — warm the stat cache with ONE
        # ``stat_vec`` over the whole chain instead, so a deep
        # manifest-driven extract probes each parent chain in a single
        # roundtrip.  Advisory: a failed batch falls back per-component.
        if not self.flags.mkdir and self.engine.readahead is not None:
            cache = self.engine.stat_cache
            probe, anc = [], ""
            for part in parts:
                anc = f"{anc}/{part}" if anc else part
                if cache.get(anc) is None:
                    probe.append(anc)
            if len(probe) > 1:
                b = self.backend

                def pfn(probe=tuple(probe)):
                    try:
                        res = b.stat_vec(list(probe))
                    except OSError:
                        return None
                    for q in probe:
                        st = res.get(q)
                        if st is not None and cache.get(q) is None:
                            cache.put(q, st)
                    return None

                self.engine.submit("stat", tuple(probe), pfn, eager=False,
                                   tenant=self._tenant_state)
        for part in parts:
            cur = f"{cur}/{part}" if cur else part
            st = self.engine.stat_cache.get(cur)
            if st is not None and st.exists:
                continue
            if not self.flags.mkdir and self.exists(cur):
                continue
            b, p = self.backend, cur

            def fn(p=p, txn=txn):
                ov = self.engine.overlay
                try:
                    b.mkdir(p)
                except FileExistsError:
                    # the dir pre-existed: the overlay's admit-time claim
                    # of a fresh (complete, empty) directory is wrong —
                    # demote its completeness; the membership deltas
                    # recorded so far remain valid
                    if ov is not None:
                        ov.demote(p)
                    if not exist_ok:
                        raise
                else:  # journal only dirs this region actually created
                    if ov is not None:
                        ov.promote(p)
                    if txn is not None:
                        txn._record_create(p, True)
            self._submit("mkdir", (p,), fn, cache_kw={}, region=txn)

    def rmdir(self, path: str) -> None:
        p, txn = norm_path(path), self._active_txn()
        sp = self._spill()
        if sp is not None and sp.elide_rmdir(p):
            self._elide_replay("rmdir", (p,), {})
            return
        # cross-path bulk-remove peephole: when the overlay proves this
        # directory's subtree is fully known and ends empty after the
        # pending removals, those unlinks/rmdirs are elided and ONE
        # vectored remove_tree backend call covers the whole prefix.
        # Collapses roll up through the rmtree recursion: leaf dirs fuse
        # first, parents then absorb their children's fused removals.
        if self.flags.is_eager("rmdir") and self.flags.is_eager("remove_tree"):
            prep = self.engine.prepare_rmtree(p, region=txn)
            if prep is not None:
                eng = self.engine
                self._submit("remove_tree", (p, *prep.covered),
                             lambda: eng.run_bulk_remove(prep), cache_kw={},
                             region=txn, payload=prep)
                return
        b = self.backend
        tolerant = sp is not None and sp.removal_tolerant(p)

        def fn():
            try:
                b.rmdir(p)
            except FileNotFoundError:
                # the interrupted run's removal was in flight at the kill:
                # the directory may already be durably gone
                if not tolerant:
                    raise

        self._submit("rmdir", (p,), fn, cache_kw={}, region=txn)

    def create(self, path: str) -> None:
        b, p, txn = self.backend, norm_path(path), self._active_txn()
        sp = self._spill()
        if sp is not None and sp.divert_create(p):
            # the interrupted run durably created (and wrote) this file:
            # buffer the re-run's stream instead of re-submitting; close
            # verifies the content against the recorded segment checksums
            # and either elides the whole stream or falls back to a real
            # rewrite (SpillManager.finalize)
            self.engine.stat_cache.on_op("create", (p,))
            ov = self.engine.overlay
            if ov is not None:
                ov.on_op("create", (p,))
            return
        # the journaling existence probe below batches: enqueued before
        # this op's own admission (which consumes the probe's exemption),
        # it fuses with neighbouring probes into ONE speculative stat_vec
        sb = self.engine.stat_batcher
        if txn is not None and sb is not None:
            sb.enqueue(p, "create")

        def fn():
            # create succeeds on an existing file (O_TRUNC) — journal only
            # true creations, or rollback would unlink a pre-transaction
            # file outright.  (Truncated content is not restored: the
            # journal records namespace, not data.)  The extra stat is paid
            # only inside transactions, by the background worker — or not
            # at all when the batched probe landed.
            if txn is not None:
                hit = sb.lookup(p) if sb is not None else None
                existed = hit.exists if hit is not None else b.stat(p).exists
                if sp is not None:
                    # spill the probe result BEFORE the backend call: if a
                    # kill leaves this op uncertain, repair may journal
                    # the landed file only on this surviving absence proof
                    sp.record_preexist(p, existed)
            else:
                existed = False
            b.create(p)
            if txn is not None and not existed:
                txn._record_create(p, False)

        self._submit("create", (p,), fn, cache_kw={}, region=txn)

    def unlink(self, path: str) -> None:
        b, p, txn = self.backend, norm_path(path), self._active_txn()
        sp = self._spill()
        if sp is not None and sp.elide_unlink(p):
            self._elide_replay("unlink", (p,), {})
            return
        # optimizer: a pending create/write chain on this path is invisible
        # at every observation point once the path is unlinked in the same
        # window — elide it.  The unlink must then tolerate absence: the op
        # that would have created the file (create, or an implicit-create
        # write) no longer executes — or the interrupted run's removal was
        # in flight at the kill, so the file may already be gone.
        tolerant = ((self.flags.is_eager("unlink")
                     and self.engine.prepare_unlink(p, region=txn))
                    or (sp is not None and sp.removal_tolerant(p)))

        def fn():
            try:
                b.unlink(p)
            except FileNotFoundError:
                if not tolerant:
                    raise

        self._submit("unlink", (p,), fn, cache_kw={}, region=txn)

    def rename(self, src: str, dst: str) -> None:
        b = self.backend
        s, d = norm_path(src), norm_path(dst)
        # optimizer rule 5 (cost-gated): on media where rename is a
        # server-side copy+delete, a source whose whole backend lifetime
        # is still pending (create+write+metadata, nothing executed) is
        # rebuilt at the destination instead — the copy+delete round-trips
        # never happen.  The capture is all-or-nothing; on any ineligible
        # op the plain backend rename below runs untouched.
        if (s != d and self.flags.is_eager("rename")
                and self.flags.is_eager("create")
                and self.flags.is_eager("write")
                and self.flags.is_eager("unlink")
                and self.engine.rename_retarget_wanted()):
            txn = self._active_txn()
            chain = self.engine.prepare_rename_retarget(s, region=txn)
            if chain is not None:
                self._replay_retargeted(chain, s, d, txn)
                return
        self._submit_journaled("rename", (s, d), lambda: b.rename(s, d),
                               lambda t: t._record_rename(s, d),
                               cache_kw={})

    def _replay_retargeted(self, chain, s: str, d: str, txn) -> None:
        """Re-drive a captured source chain at the destination through the
        public ops (oldest-first: the create lands before its writes), so
        journaling, stat-cache/overlay bookkeeping and destination-side
        fusion all happen exactly as if the caller had built the file at
        the destination in the first place.  The elided source ops never
        journalled (their fns never ran), so nothing double-records."""
        b = self.backend
        for op in chain:
            pl = op.payload
            if op.kind == "create":
                self.create(d)
            elif op.kind == "write":
                for off, data in pl.segments():
                    self._write_at(d, off, data)
            elif op.kind == "chmod":
                self.chmod(d, *pl.args)
            elif op.kind == "utimens":
                self.utimens(d, *pl.args)
            elif op.kind == "truncate":
                self.truncate(d, *pl.args)
        # the source still disappears: a pre-existing file at the source
        # (the elided create would have O_TRUNCed it) must go, and the
        # overlay/stat-cache must see the path removed.  Submitted
        # directly — NOT via self.unlink, whose elision pass would find
        # the already-captured chain gone and leave the op intolerant,
        # pushing a spurious ENOENT into the ledger when the source was
        # never materialized.

        def fn():
            try:
                b.unlink(s)
            except FileNotFoundError:
                pass

        self._submit("unlink", (s,), fn, cache_kw={}, region=txn)

    def symlink(self, target: str, path: str) -> None:
        b, p = self.backend, norm_path(path)
        self._submit_journaled("symlink", (p,), lambda: b.symlink(target, p),
                               lambda t: t._record_create(p, False),
                               cache_kw={})

    def link(self, src: str, dst: str) -> None:
        b = self.backend
        s, d = norm_path(src), norm_path(dst)
        self._submit_journaled("link", (s, d), lambda: b.link(s, d),
                               lambda t: t._record_create(d, False),
                               cache_kw={})

    def readlink(self, path: str) -> str:
        b = self.backend
        p = norm_path(path)
        self._check_paths("readlink", (p,))
        return self.engine.submit("readlink", (p,),
                                  lambda: b.readlink(p), eager=False,
                                  tenant=self._tenant_state)

    # ------------------------------------------------------------------
    # data ops
    # ------------------------------------------------------------------

    def _write_at(self, path: str, offset: int, data: bytes) -> None:
        b, p, txn = self.backend, norm_path(path), self._active_txn()
        cache_kw = {"offset": offset, "nbytes": len(data)}
        # confinement + quota run BEFORE the fusion attempt: a denied path
        # must never be absorbed into a neighbour's pending vector, and a
        # fused write still consumes budget (the high-water charge is
        # idempotent with _submit's)
        self._check_paths("write", (p,))
        self._quota_admit("write", (p,), cache_kw)
        sp = self._spill()
        if sp is not None and sp.divert_write(p, offset, data):
            # resumed diverted stream: buffered for close-time verification
            self.engine.stat_cache.on_op("write", (p,), **cache_kw)
            ov = self.engine.overlay
            if ov is not None:
                ov.on_op("write", (p,), **cache_kw)
            return
        # feed the coalescer: if the path's pending tip is an unclaimed,
        # unsealed write in the same region, this write is absorbed into
        # its vector and ACKed without a new engine op
        if self.flags.is_eager("write") and self.engine.try_fuse_write(
                p, offset, data, region=txn, cache_kw=cache_kw):
            self._note_fused()
            return
        payload = WritePayload(offset, data)
        # batch the journaling probe (same conditions fn re-checks at
        # execution — they cannot flip in between, because enqueue
        # requires a quiescent path and later same-path admissions are
        # FIFO-ordered after this op)
        sb = self.engine.stat_batcher
        if (sb is not None and txn is not None and not txn._has_created(p)
                and not txn._is_preexisting(p)):
            sb.enqueue(p, "write")

        def fn():
            # write_vec creates a missing file implicitly; if its create op
            # faulted earlier, the file would otherwise be an unjournaled
            # orphan that rollback cannot remove.  The existence probe is
            # skipped on the hot paths (path already journaled, or already
            # proven to pre-exist — streamed appends pay one probe total).
            probe = (txn is not None and not txn._has_created(p)
                     and not txn._is_preexisting(p))
            if probe:
                hit = sb.lookup(p) if sb is not None else None
                existed = hit.exists if hit is not None else b.stat(p).exists
                if sp is not None:
                    # spilled pre-backend-call: repair's only licence to
                    # journal this path if the op lands without a record
                    sp.record_preexist(p, existed)
            else:
                existed = True
            expected = payload.nbytes   # frozen once the op is claimed
            out = b.write_vec(p, payload.segments())
            if probe:
                if existed:
                    txn._mark_preexisting(p)
                else:
                    txn._record_create(p, False)
            if out < expected:
                # torn op: journal ran first so rollback removes the torn
                # file; EIO-class error makes run_transaction resubmit
                raise ShortWriteError(p, expected, out)
            return out

        self._submit("write", (p,), fn, cache_kw=cache_kw, region=txn,
                     payload=payload)

    def write_file(self, path: str, data: bytes) -> None:
        """create + write + close — the common whole-file put."""
        with self.open(path, "wb") as f:
            f.write(data)

    def pread(self, path: str, offset: int, size: int) -> bytes:
        """Data reads are never eager (paper §2) — but with the read-ahead
        layer on, a *sequential* reader's bytes are usually already here:
        the first sync read registers a ticketed page buffer and pipelines
        speculative ``read_vec`` windows ahead of the consumer, so later
        preads are served without a backend roundtrip.  A page hit is
        byte-identical to the sync path (pages register only on quiescent
        paths and die on any racing admitted mutation); any miss falls
        through to the sync read below and re-feeds the observer."""
        b = self.backend
        p = norm_path(path)
        self._check_paths("read", (p,))
        ra = self.engine.readahead
        if ra is not None and size >= 0:
            out = ra.read(p, offset, size)
            if out is not None:
                return out
        out = self.engine.submit("read", (p,),
                                 lambda: b.read_at(p, offset, size),
                                 eager=False, tenant=self._tenant_state)
        if ra is not None:
            ra.observe_sync(p, offset, len(out), size)
        return out

    def read_file(self, path: str) -> bytes:
        return self.pread(path, 0, -1)

    def open(self, path: str, mode: str = "rb") -> CannyFile:
        return CannyFile(self, path, mode)

    def _submit_foldable(self, kind: str, path: str, args: tuple, apply_fn,
                         cache_kw: dict | None) -> None:
        """Submit a last-wins metadata op (chmod/utimens/truncate) through
        the optimizer: an adjacent pending same-kind op absorbs the new
        arguments instead of a second backend roundtrip."""
        p, txn = norm_path(path), self._active_txn()
        sp = self._spill()
        if sp is not None and sp.elide_meta(kind, p, args):
            # last-wins metadata durably applied with identical arguments
            # by the interrupted run: skip the roundtrip
            self._elide_replay(kind, (p,), cache_kw or {})
            return
        if self.flags.is_eager(kind) and self.engine.try_fuse_meta(
                kind, p, args, region=txn, cache_kw=cache_kw):
            self._note_fused()
            return
        payload = MetaPayload(args)
        self._submit(kind, (p,), lambda: apply_fn(p, *payload.args),
                     cache_kw=cache_kw, region=txn, payload=payload)

    def truncate(self, path: str, size: int) -> None:
        self._submit_foldable("truncate", path, (size,),
                              self.backend.truncate, {"size": size})

    def fallocate(self, path: str, size: int) -> None:
        b = self.backend
        self._submit("fallocate", (path,), lambda: b.fallocate(path, size),
                     cache_kw={"size": size})

    def flush(self, path: str) -> None:
        sp = self._spill()
        if sp is not None:
            sp.finalize(self, norm_path(path))
        if self.flags.flush:
            return  # eager flush == no-op ACK; data ordering is per-path
        self.engine.barrier(path, tenant=self._tenant_state)

    def fsync(self, path: str) -> None:
        b = self.backend
        self._submit("fsync", (path,), lambda: b.fsync(path))

    def _on_close_write(self, path: str) -> None:
        """close() of a written file: with eager flush this is an immediate
        ACK; otherwise it is a barrier (NFS close-to-open consistency —
         'the closing of files a barrier', paper §5).  A resumed diverted
        stream settles here: the buffered content is verified against the
        recorded durable checksums and elided, or rewritten for real."""
        sp = self._spill()
        if sp is not None:
            sp.finalize(self, norm_path(path))
        if not self.flags.flush:
            self.engine.barrier(path, tenant=self._tenant_state)

    # ------------------------------------------------------------------
    # metadata ops
    # ------------------------------------------------------------------

    def chmod(self, path: str, mode: int) -> None:
        self._submit_foldable("chmod", path, (mode,),
                              self.backend.chmod, {"mode": mode})

    def chown(self, path: str, uid: int, gid: int) -> None:
        b = self.backend
        self._submit("chown", (path,), lambda: b.chown(path, uid, gid))

    def utimens(self, path: str, atime: float, mtime: float) -> None:
        self._submit_foldable("utimens", path, (atime, mtime),
                              self.backend.utimens, None)

    def setxattr(self, path: str, key: str, value: bytes) -> None:
        b = self.backend
        self._submit("setxattr", (path,), lambda: b.setxattr(path, key, value))

    def removexattr(self, path: str, key: str) -> None:
        b = self.backend
        self._submit("removexattr", (path,),
                     lambda: b.removexattr(path, key))

    def stat(self, path: str) -> StatResult:
        """Stat is an *overlay read*: answered from the write-through
        cache (positive and negative hits) or from the overlay's proven
        membership (a complete parent that does not list the name) without
        sealing anything; only a miss takes the sync, sealing path."""
        path = norm_path(path)
        self._check_paths("stat", (path,))
        ov = self.engine.overlay
        mock = ov.policy.mock_stat if ov is not None else self.flags.mock_stat
        negative = (ov.policy.negative_stat if ov is not None
                    else self.flags.negative_stat_cache)
        if mock:
            hit = self.engine.stat_cache.get(path)
            if hit is not None and (hit.exists or negative):
                self.engine.stats.mocked_stats += 1
                return hit
            if hit is None and negative and ov is not None \
                    and ov.lookup(path) is False:
                self.engine.stats.mocked_stats += 1
                return StatResult(False, mocked=True)
        b = self.backend
        cache = self.engine.stat_cache

        def fn():
            hit = cache.get(path)
            if hit is not None:
                return hit
            st = b.stat(path)
            cache.put(path, st)
            return st

        return self.engine.submit("stat", (path,), fn, eager=False,
                                  tenant=self._tenant_state)

    def exists(self, path: str) -> bool:
        return self.stat(path).exists

    def _overlay_readdir_hit(self, ov, path: str) -> list[str] | None:
        """One overlay readdir attempt with its hit accounting, or None
        on a miss (shared by the fast path and the post-latch re-try)."""
        names = ov.readdir(path)
        if names is None:
            return None
        stats = self.engine.stats
        stats.overlay_readdirs += 1
        if self.engine._sched.has_pending_under(path):
            stats.overlay_seals_avoided += 1
        if (self.engine.prefetcher is not None
                and ov.was_speculative(path)):
            stats.prefetch_hits += 1
        return names

    def readdir(self, path: str) -> list[str]:
        """Readdir consults the namespace overlay first: when the
        directory's membership is fully determined by the transaction's
        own writes (created in-window) or a cached backend listing, the
        answer comes from pending state and the chains beneath stay
        rewritable (no seal, no backend roundtrip).  A miss with a
        speculative batch already in flight for the path latches onto
        that batch (``MetadataPrefetcher.wait_for`` — one shared
        roundtrip, demand-promoting a frontier-queued path) and re-tries
        the overlay; only then does it execute ONE vectored
        ``readdir_plus`` call — names plus attributes, the NFS
        READDIRPLUS analogue — installing the listing into the overlay,
        warming the stat cache, seeding the prefetch frontier with the
        discovered subdirectories, and sealing as any sync op does."""
        path = norm_path(path)
        self._check_paths("readdir", (path,))
        ov = self.engine.overlay
        b = self.backend
        if ov is not None:
            if ov.policy.readdir_overlay:
                names = self._overlay_readdir_hit(ov, path)
                if names is not None:
                    return names
                # consumer latch: a speculative batch already carrying
                # this directory is in flight — wait for its install
                # instead of issuing a duplicate roundtrip, then re-try
                # the overlay (a cancelled/failed batch falls through to
                # the sync path exactly as before)
                pf = self.engine.prefetcher
                if pf is not None and pf.wait_for(path):
                    names = self._overlay_readdir_hit(ov, path)
                    if names is not None:
                        return names
            cache = self.engine.stat_cache
            warm = ov.policy.prefetch

            def fn():
                listing = b.readdir_plus(path)
                if warm:
                    for name, st in listing:
                        child = f"{path}/{name}" if path else name
                        if st is not None and cache.get(child) is None:
                            cache.put(child, st)
                            self.engine.stats.prefetched_stats += 1
                ov.install_listing(path, listing)
                # a cold miss is the prefetch pipeline's trigger: the
                # subdirectories this listing discovered are enqueued for
                # batched speculative fetching ahead of the consumer
                pf = self.engine.prefetcher
                if pf is not None:
                    pf.seed_children(path, listing)
                return [name for name, _ in listing]

            return self.engine.submit("readdir", (path,), fn, eager=False,
                                      tenant=self._tenant_state)
        # overlay disabled: the pre-overlay path — plain backend readdir
        # plus the legacy advisory per-entry prefetch stats
        names = self.engine.submit("readdir", (path,),
                                   lambda: b.readdir(path), eager=False,
                                   tenant=self._tenant_state)
        if self.flags.readdir_prefetch:
            cache = self.engine.stat_cache
            for name in names:
                child = f"{path}/{name}" if path else name
                if cache.get(child) is None:
                    def pf(child=child):
                        if cache.get(child) is None:
                            try:
                                cache.put(child, b.stat(child))
                            except OSError:
                                pass  # advisory warm-up only: a failure
                                # must not land in the ledger and condemn
                                # a transaction — consumers stat on demand
                    self.engine.submit("stat", (child,), pf, eager=True,
                                       tenant=self._tenant_state)
                    self.engine.stats.prefetched_stats += 1
        return names

    listdir = readdir

    # ------------------------------------------------------------------
    # composite workloads
    # ------------------------------------------------------------------

    def rmtree(self, path: str) -> None:
        """`rm -rf` — the paper's second benchmark, readdir-driven.

        With the namespace overlay this walk stays inside the unobserved
        window: readdirs of in-window (or once-listed) directories answer
        from pending state without sealing, per-entry stats hit the cache
        warmed by the listing, and each ``rmdir`` tries the bulk-remove
        peephole — collapsing the subtree's pending unlinks/rmdirs into
        one vectored ``remove_tree`` backend call that rolls up the
        recursion to a single fused removal of the whole tree.  With the
        overlay off (or on any miss) this degrades gracefully to the
        per-entry path: eager unlinks/rmdirs ordered by the engine's
        pending-children edges."""
        path = norm_path(path)
        sp = self._spill()
        if sp is not None and sp.elide_remove_root(path):
            # the interrupted run durably removed this whole subtree (and
            # nothing under it was re-created since): skip the recursion
            self._elide_replay("remove_tree", (path,), {})
            return
        for name in self.readdir(path):
            child = f"{path}/{name}" if path else name
            st = self.stat(child)
            if st.is_dir:
                self.rmtree(child)
            else:
                self.unlink(child)
        self.rmdir(path)

    def walk(self, path: str = ""):
        """Generator of (dir, subdirs, files) — `find`/`du`-style traversal.

        Overlay fast path: a directory whose membership *and* child kinds
        are fully determined by pending state or a cached listing yields
        without a single backend roundtrip or seal (counted in
        ``overlay_readdirs``); any other directory falls back to the
        readdir + per-entry stat walk for that directory only — each
        subdirectory re-tries the fast path."""
        path = norm_path(path)
        ov = self.engine.overlay
        if ov is not None and ov.policy.readdir_overlay:
            kinds = ov.listing_kinds(path)
            if kinds is not None:
                dirs, files = kinds
                stats = self.engine.stats
                stats.overlay_readdirs += 1
                if self.engine._sched.has_pending_under(path):
                    stats.overlay_seals_avoided += 1
                if (self.engine.prefetcher is not None
                        and ov.was_speculative(path)):
                    stats.prefetch_hits += 1
                yield path, dirs, files
                for d in dirs:
                    child = f"{path}/{d}" if path else d
                    yield from self.walk(child)
                return
        names = self.readdir(path)
        dirs, files = [], []
        for name in names:
            child = f"{path}/{name}" if path else name
            (dirs if self.stat(child).is_dir else files).append(name)
        yield path, dirs, files
        for d in dirs:
            child = f"{path}/{d}" if path else d
            yield from self.walk(child)

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------

    @property
    def ledger(self) -> ErrorLedger:
        return self.engine.ledger

    @property
    def stats(self):
        """Engine counters: per-op fault/trace counters (deferred_errors,
        injected_faults, rollbacks, retries) and the optimizer's fusion
        counters (fused_writes, folded_meta, elided_ops, bytes_elided)."""
        return self.engine.stats

    @property
    def poisoned(self) -> bool:
        """True once abort_on_error tripped; new submissions fail fast."""
        return self.engine.poisoned

    def _arm_spill(self, sp: SpillManager) -> None:
        """Install a prepared spill journal where ``_spill()`` finds it:
        engine-global here, the tenant's own slot for Tenant handles."""
        self.engine.spill = sp

    def _quota_release(self, paths) -> None:
        """Rollback removed these paths directly through the backend —
        give the tenant its budget back.  No-op on the base mount."""

    def _elide_replay(self, kind: str, paths: tuple, kw: dict) -> None:
        """Account one re-run op skipped as provably durable, refreshing
        the write-through claims it would have installed at admission."""
        self.engine.stat_cache.on_op(kind, paths, **kw)
        ov = self.engine.overlay
        if ov is not None:
            ov.on_op(kind, paths, **kw)
            if kind == "mkdir":
                ov.promote(paths[0])
        self.engine.stats.resume_elided_ops += 1

    def enable_spill(self, spill_dir: str, *,
                     flush_records: int = 64) -> SpillManager:
        """Arm the durability spill: from here on the active transaction's
        journal and every op outcome persist incrementally to
        ``spill_dir`` on this mount's own backend (see core/durability.py).
        Call before opening the transaction."""
        sp = SpillManager(self.engine, spill_dir,
                          flush_records=flush_records)
        sp.prepare()
        self._arm_spill(sp)
        return sp

    def resume(self, spill_dir: str, *, flush_records: int = 64) -> dict:
        """Re-prove an interrupted optimization window from the spill on a
        FRESH mount: parse the journal, repair the kill's in-flight
        ambiguity against the backend, replay the proven delta into the
        stat cache and namespace overlay (no tree re-walk), and arm the
        spill so the re-executed job body elides/diverts ops that are
        provably durable.  Returns a report dict (records parsed, repairs,
        ops replayed, ...)."""
        sp = SpillManager(self.engine, spill_dir,
                          flush_records=flush_records)
        sp.prepare()
        report = sp.load()
        report.update(sp.repair())
        cache, ov = self.engine.stat_cache, self.engine.overlay
        replayed = 0
        if sp.resuming:
            for kind, paths, rec in sp.image.events:
                kw = _replay_kw(kind, rec)
                cache.on_op(kind, paths, **kw)
                if ov is not None:
                    ov.on_op(kind, paths, **kw)
                    if kind == "mkdir":
                        ov.promote(paths[0])
                replayed += 1
            # failed ops recorded no durable effect — whatever claims the
            # replay stream installed for them must not stand
            for kind, paths in sp.image.fails:
                for p in paths:
                    cache.invalidate(p)
                    if ov is not None:
                        ov.invalidate(p)
            # repair-time removals (re-issued bulk deletes, probed-gone
            # paths) post-date the event stream: apply them last
            for root, gone in sp.removed_roots():
                cache.on_op("remove_tree", tuple(gone))
                if ov is not None:
                    ov.on_op("remove_tree", (root,))
        # preemption skipped the rollback that would have cleared the
        # poison gate; the re-proof IS the recovery — lift it (tenant-
        # scoped on a Tenant view, a no-op on a genuinely fresh mount)
        self._reset_poison()
        self._arm_spill(sp)
        self.engine.stats.resumes += 1
        self.engine.stats.resume_replayed_ops += replayed
        ts = self._tenant_state
        if ts is not None:
            ts.stats.resumes += 1
        report["replayed"] = replayed
        return report

    def drain(self) -> None:
        sp = self._spill()
        if sp is not None:
            sp.finalize_all(self)
        self.engine.drain()

    def close(self) -> None:
        """Unmount: drain all pending I/O and report deferred errors —
        the benchmarked 'fully killing the CannyFS process' step."""
        sp = self._spill()
        if sp is not None:
            sp.finalize_all(self)
        self.engine.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
