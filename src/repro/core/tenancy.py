"""Tenancy — N concurrent jobs on one eager engine (PR 10).

One ``CannyFS`` engine historically served exactly one job: the ledger,
poison flag, spill journal, rollback scope and in-flight budget were all
engine-global, so co-tenancy meant one tenant's fault storm rolled back
or poisoned everyone sharing the mount.  The ``Tenant`` handle turns the
per-job transaction boundary into a per-tenant isolation boundary:

* **namespace** — every op is confined to the tenant's ``root_prefix``
  (PermissionError outside it); commit/rollback clears the shared
  namespace overlay only under that prefix, leaving a neighbour's open
  optimization window intact.
* **failure domain** — ledger entries carry the tenant tag, the poison
  flag / rollback scope / retry+backoff bookkeeping / spill journal are
  per-tenant, and ``engine abort_on_error`` cancels only the faulting
  tenant's queued ops.
* **resources** — an optional ``TenantQuota`` (bytes + inodes) is
  charged synchronously at ACK time, and the scheduler dispatches ready
  lanes by deficit-weighted round-robin over the tenants' weights.
* **admission control** — at global budget saturation the scheduler
  sheds speculative lanes first, then backpressures only the over-share
  tenant's submits (see ``core/scheduler.py``).

``Tenant`` subclasses ``CannyFS`` but deliberately never calls its
``__init__``: it *shares* the parent's engine, backend and flags, and
overrides only the tenancy hooks the base class routes every public op
through.  A tenant handle is itself a full ``CannyFS`` — transactions,
spill/resume, walk/rmtree all work unchanged, scoped.
"""
from __future__ import annotations

import errno
import threading

from .backend import is_under, norm_path
from .durability import SpillManager
from .fs import CannyFS


class TenantQuota:
    """Synchronous byte + inode budget for one tenant.

    Mirrors ``QuotaBackend``'s accounting (high-water bytes per path,
    live inode set) but charges at *ACK time* in the submitting thread:
    an eager op's backend-side EDQUOT would land in the deferred ledger
    long after the ACK succeeded, whereas a tenant budget must reject the
    over-budget tenant's call immediately — and only that tenant's.

    Charges are idempotent high-water marks, so the fused-write fast path
    and the engine submit path may both charge one op safely.
    """

    def __init__(self, budget_bytes: int = 0, max_inodes: int | None = None):
        self.budget_bytes = int(budget_bytes)  # 0 = unbudgeted bytes
        self.max_inodes = max_inodes
        self._lock = threading.Lock()
        self._charged: dict[str, int] = {}     # path -> high-water bytes
        self._inodes: set[str] = set()
        self.used = 0
        self.edquot_count = 0
        self.enospc_count = 0

    # -- charging (raises to the caller BEFORE any state is mutated) --

    def _charge_inode_locked(self, p: str) -> None:
        if p in self._inodes:
            return
        if self.max_inodes is not None and len(self._inodes) >= self.max_inodes:
            self.enospc_count += 1
            raise OSError(errno.ENOSPC,
                          f"tenant inode budget ({self.max_inodes}) exhausted",
                          p)
        self._inodes.add(p)

    def _charge_bytes_locked(self, p: str, size: int) -> None:
        cur = self._charged.get(p, 0)
        if size <= cur:
            return
        delta = size - cur
        if self.budget_bytes and self.used + delta > self.budget_bytes:
            self.edquot_count += 1
            raise OSError(errno.EDQUOT,
                          f"tenant byte budget ({self.budget_bytes}) exhausted",
                          p)
        self._charged[p] = size
        self.used += delta

    def _release_locked(self, p: str) -> None:
        self.used -= self._charged.pop(p, 0)
        self._inodes.discard(p)

    def admit(self, kind: str, paths, cache_kw=None) -> None:
        """Charge (or release) one op's budget effect by kind.  Raises
        OSError(EDQUOT/ENOSPC) without mutating state when over budget."""
        kw = cache_kw or {}
        with self._lock:
            if kind in ("create", "mkdir", "symlink"):
                self._charge_inode_locked(paths[0])
            elif kind == "link":
                self._charge_inode_locked(paths[1])
            elif kind in ("write", "fallocate", "truncate"):
                p = paths[0]
                if kind == "write":
                    size = int(kw.get("offset", 0)) + int(kw.get("nbytes", 0))
                else:
                    size = int(kw.get("size", 0))
                self._charge_inode_locked(p)   # write_vec creates implicitly
                self._charge_bytes_locked(p, size)
            elif kind == "unlink":
                self._release_locked(paths[0])
            elif kind == "rmdir":
                self._inodes.discard(paths[0])
            elif kind == "remove_tree":
                root = paths[0]
                for p in [q for q in self._charged if is_under(q, root)]:
                    self._release_locked(p)
                self._inodes = {q for q in self._inodes
                                if not is_under(q, root)}
            elif kind == "rename":
                s, d = paths[0], paths[1]
                self._charge_inode_locked(d)   # may raise before the move
                moved = self._charged.pop(s, None)
                self._inodes.discard(s)
                if moved is not None:
                    # move the source's charge to the destination's
                    # high-water mark; the total never grows across a
                    # rename, so bytes cannot newly exceed the budget
                    self.used -= moved
                    cur = self._charged.get(d, 0)
                    if moved > cur:
                        self._charged[d] = moved
                        self.used += moved - cur
        # (reads/metadata kinds fall through uncharged)

    def release(self, path: str) -> None:
        """Rollback removed ``path`` behind the engine's back — refund."""
        with self._lock:
            self._release_locked(norm_path(path))

    def usage(self) -> dict:
        """Snapshot for observability (EngineStats.tenants / paper table)."""
        with self._lock:
            return {
                "budget_bytes": self.budget_bytes,
                "bytes_used": self.used,
                "bytes_remaining": (self.budget_bytes - self.used
                                    if self.budget_bytes else None),
                "max_inodes": self.max_inodes,
                "inodes_used": len(self._inodes),
                "inodes_remaining": (self.max_inodes - len(self._inodes)
                                     if self.max_inodes is not None else None),
                "edquot_count": self.edquot_count,
                "enospc_count": self.enospc_count,
            }

    def inodes_used(self) -> int:
        with self._lock:
            return len(self._inodes)


class Tenant(CannyFS):
    """A confined, isolated view over a shared ``CannyFS`` engine.

    Obtained via ``CannyFS.tenant(name, root_prefix, weight, quota)``.
    Shares the parent's engine/backend/flags (``__init__`` of the base
    class is deliberately not called) but owns:

    * a fresh transaction slot — each tenant runs its own concurrent
      ``Transaction`` / ``run_transaction`` with tenant-scoped rollback,
      ledger clear, poison reset and retry/backoff streams;
    * the scheduler-side tenant state — DWRR credit, budget-slice
      accounting, tenant poison flag;
    * an optional ``TenantQuota`` charged at ACK time;
    * its own spill journal slot (``enable_spill``/``resume`` arm the
      tenant's journal, never the shared engine one).
    """

    _ANCESTOR_OK = frozenset({"mkdir", "stat", "readdir"})

    def __init__(self, parent: CannyFS, name: str, root_prefix: str, *,
                 weight: float = 1.0, quota=None):
        self.parent = parent
        self.flags = parent.flags
        self.engine = parent.engine
        self.backend = parent.backend
        self.name = name
        self.root_prefix = norm_path(root_prefix)
        if not self.root_prefix:
            raise ValueError("tenant root_prefix must not be the fs root")
        self._txn_lock = threading.Lock()
        self._txn = None
        self._detached = threading.local()
        if isinstance(quota, int):
            quota = TenantQuota(quota)
        self.quota = quota
        self._tenant_state = self.engine.register_tenant(name, weight)
        if quota is not None:
            st = self._tenant_state.stats
            st.quota_bytes_budget = quota.budget_bytes

    # -- tenancy hooks (see CannyFS for the contract) -------------------

    def _check_paths(self, kind: str, paths) -> None:
        root = self.root_prefix
        for p in paths:
            if is_under(p, root):
                continue
            if kind in self._ANCESTOR_OK and (p == "" or is_under(root, p)):
                # probing/scaffolding the ancestor chain of the tenant's
                # own root (makedirs of the root itself, stat/readdir of
                # the fs root "") is namespace-neutral for neighbours —
                # allow it
                continue
            raise PermissionError(
                errno.EACCES,
                f"tenant {self.name!r} is confined to {root!r}", p)

    def _quota_admit(self, kind: str, paths, cache_kw=None) -> None:
        q = self.quota
        if q is None:
            return
        q.admit(kind, paths, cache_kw)
        st = self._tenant_state.stats
        st.quota_bytes_used = q.used
        st.quota_inodes_used = q.inodes_used()

    def _quota_release(self, paths) -> None:
        q = self.quota
        if q is None:
            return
        for p in paths:
            q.release(p)
        st = self._tenant_state.stats
        st.quota_bytes_used = q.used
        st.quota_inodes_used = q.inodes_used()

    def _backoff_salt(self) -> str:
        return self.name

    def _arm_spill(self, sp: SpillManager) -> None:
        self._tenant_state.spill = sp

    def _clear_window_caches(self, *, rollback: bool) -> None:
        eng = self.engine
        ov = eng.overlay
        if ov is not None:
            # prefix-scoped: the neighbour tenants' proven listings and
            # in-window membership survive this tenant's boundary
            ov.clear_under(self.root_prefix)
        if rollback and eng.readahead is not None:
            # read-ahead pages are pure caches — a global drop is
            # correctness-neutral for neighbours (their next read
            # re-primes) and guarantees no stale page survives the
            # direct-backend rollback sweep
            eng.readahead.clear()
        sb = eng.stat_batcher
        if sb is not None:
            sb.clear()

    # -- lifecycle ------------------------------------------------------

    @property
    def poisoned(self) -> bool:
        """True once THIS tenant's abort tripped (or the whole engine)."""
        return self._tenant_state.poisoned or self.engine.poisoned

    @property
    def tenant_stats(self):
        """This tenant's ``TenantStats`` sub-snapshot."""
        return self._tenant_state.stats

    def tenant_ledger(self):
        """Deferred errors attributed to this tenant only."""
        return self.engine.ledger.entries_for_tenant(self.name)

    def drain(self) -> None:
        sp = self._spill()
        if sp is not None:
            sp.finalize_all(self)
        self.engine.drain()

    def close(self) -> None:
        """Release the handle: settle this tenant's diverted streams and
        wait for the engine to drain — the shared engine itself is NEVER
        torn down by a tenant (the owning mount's close() does that)."""
        self.drain()
