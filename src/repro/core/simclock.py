"""Discrete-event simulation clock: exactly-reproducible virtual time.

``VirtualClock`` (core/backend.py) credits each ``sleep`` instantly and
approximates the schedule's critical path with per-thread accounting —
fast, but the *interleaving* of real threads still decides who executes
what, so makespans, steal counts and per-worker loads vary run to run,
and CI guards had to pace with scaled real sleeps just to keep the OS
scheduler honest (``PacedVirtualClock``), capping them below
``REPRO_BENCH_SCALE=1.0``.

``SimClock`` replaces the approximation with a cooperative discrete-event
simulation over the *real* engine threads:

* every participating thread (the benchmark driver + the executor's pool
  workers) is an **actor**; exactly one actor runs at a time (the
  "token"), so every lock acquisition, shard pop, steal and fuse decision
  happens in a deterministic order — the whole schedule is a pure
  function of the op stream and the latency model's seed;
* ``sleep(dt)`` parks the calling actor on the event queue with a wake
  deadline ``now + dt`` and hands the token to the next runnable actor;
  virtual time advances **only** when no actor is runnable, jumping to
  the earliest deadline — milliseconds of wall time simulate any modelled
  timescale at any scale;
* blocking points that are *not* modelled time (a worker parking on the
  scheduler's ready condition, the driver waiting on a sync op's
  completion event or the in-flight budget) bracket their real wait with
  ``block_begin()`` / ``block_end()`` so the simulation knows the actor
  is off the timeline and time may advance past it;
* parking/wakeup and steal probes are charged on the virtual timeline
  too (``wake_latency_s``, ``steal_probe_s``) — the dispatch layer's
  bookkeeping costs are modelled, not just the backend roundtrips.

Determinism contract: with the token held by one actor at a time, ties
between runnable actors are broken by (thread name, attach order), and
the latency model's RNG draws happen in token order — two same-seed runs
produce byte-identical schedules, makespans and per-worker loads (the
``dispatch_guard``/``walk_guard`` determinism regression relies on
this).  Callers that must never block another actor in *real* time while
holding the token (the rule that keeps the simulation deadlock-free):
never call ``sleep`` while holding a lock another actor can contend.

Usage::

    clock  = SimClock()
    remote = LatencyBackend(InMemoryBackend(), LatencyModel(...),
                            clock=clock)
    fs     = CannyFS(remote, workers=8)   # auto-discovers the SimClock:
    ...                                   # driver + workers attach
    fs.close()                            # quiesces; workers detach
    clock.makespan()                      # elapsed virtual seconds

The engine attaches the constructing thread and its pool workers
automatically; standalone use (unit tests, hand-rolled harnesses) can
``attach()``/``detach()`` explicitly or rely on ``sleep``'s transient
auto-attach.
"""
from __future__ import annotations

import itertools
import threading
from typing import Optional

from .backend import Clock

# actor states
_RUNNING, _READY, _SLEEPING, _BLOCKED = range(4)


class _Actor:
    __slots__ = ("ident", "name", "order", "state", "deadline", "nest",
                 "channel", "bseq")

    def __init__(self, ident: int, name: str, order: int):
        self.ident = ident
        self.name = name
        self.order = order          # attach order: tie-break after name
        self.state = _READY
        self.deadline = 0.0
        self.nest = 1               # attach() nesting depth
        self.channel = None         # what a _BLOCKED actor waits on
        self.bseq = 0               # FIFO order within the channel


class SimClock(Clock):
    """Deterministic discrete-event clock (see module docstring).

    ``wake_latency_s`` is charged each time a parked worker resumes (the
    modelled cost of the parking-lot handoff); ``steal_probe_s`` each
    time a worker pops from a non-owned shard (the modelled cost of the
    probe walk).  Both default tiny-but-nonzero so the dispatch layer's
    costs exist on the timeline without drowning the backend RTTs."""

    #: marks the clock as discrete-event: LatencyBackend switches its
    #: server-slot semaphore to a virtual-timeline queue model, and the
    #: engine wires park/steal/sync-wait hooks through the scheduler
    discrete_event = True

    def __init__(self, start: float = 0.0, *,
                 wake_latency_s: float = 1e-6,
                 steal_probe_s: float = 1e-7):
        self._cv = threading.Condition()
        self._start = float(start)
        self._now = float(start)
        self.wake_latency_s = float(wake_latency_s)
        self.steal_probe_s = float(steal_probe_s)
        self._actors: dict[int, _Actor] = {}
        self._running: Optional[int] = None   # ident of the token holder
        self._order = itertools.count()
        self._bseq = itertools.count()        # channel-FIFO block stamps
        self._busy: dict[str, float] = {}     # per-actor virtual busy time

    # ------------------------------------------------------------------
    # participation
    # ------------------------------------------------------------------

    def attach(self, name: str | None = None) -> None:
        """Join the simulation: the calling thread becomes an actor and
        blocks until it is granted the run token.  Nested attaches from
        the same thread count and must be matched by detaches."""
        ident = threading.get_ident()
        with self._cv:
            a = self._actors.get(ident)
            if a is not None:
                a.nest += 1
                return
            a = _Actor(ident, name or threading.current_thread().name,
                       next(self._order))
            self._actors[ident] = a
            self._cv.notify_all()       # wait_attached() watchers
            self._schedule_locked()
            while a.state != _RUNNING:
                self._cv.wait()

    def detach(self) -> None:
        """Leave the simulation (releasing the token if held).  No-op for
        threads that never attached; nested attaches unwind first."""
        ident = threading.get_ident()
        with self._cv:
            a = self._actors.get(ident)
            if a is None:
                return
            if a.nest > 1:
                a.nest -= 1
                return
            del self._actors[ident]
            if self._running == ident:
                self._running = None
            self._schedule_locked()
            self._cv.notify_all()

    def attached(self) -> bool:
        with self._cv:
            return threading.get_ident() in self._actors

    def wait_attached(self, n: int) -> None:
        """Block (holding the token) until ``n`` actors are registered —
        the engine calls this after spawning its pool so the actor set is
        identical at every driver yield point, run to run."""
        with self._cv:
            while len(self._actors) < n:
                self._cv.wait()

    # ------------------------------------------------------------------
    # the event queue
    # ------------------------------------------------------------------

    def _schedule_locked(self) -> None:
        """Grant the token to the next runnable actor; if none is runnable
        but some are sleeping, advance virtual time to the earliest wake
        deadline first.  All-blocked (or empty) is not an error: a real
        wakeup (event set, condition notify, a new attach) will
        reschedule."""
        if self._running is not None:
            return
        actors = self._actors.values()
        ready = [a for a in actors if a.state == _READY]
        if not ready:
            sleepers = [a for a in actors if a.state == _SLEEPING]
            if not sleepers:
                return
            self._now = max(self._now, min(a.deadline for a in sleepers))
            for a in sleepers:
                if a.deadline <= self._now:
                    a.state = _READY
            ready = [a for a in actors if a.state == _READY]
        nxt = min(ready, key=lambda a: (a.name, a.order))
        nxt.state = _RUNNING
        self._running = nxt.ident
        self._cv.notify_all()

    def _yield_as(self, a: _Actor, state: int) -> None:
        """Move the calling (token-holding) actor to ``state`` and hand
        the token on.  Caller holds ``_cv``."""
        if self._running == a.ident:
            self._running = None
        a.state = state
        self._schedule_locked()

    def _wait_for_token(self, a: _Actor) -> None:
        while a.state != _RUNNING:
            self._cv.wait()

    # ------------------------------------------------------------------
    # Clock interface
    # ------------------------------------------------------------------

    def now(self) -> float:
        with self._cv:
            return self._now

    def sleep(self, dt: float) -> None:
        """Advance this actor ``dt`` virtual seconds: park on the event
        queue and yield the token; wake when virtual time reaches the
        deadline.  Unattached threads are attached for the duration of
        the call (convenience for standalone use)."""
        if dt <= 0:
            return
        ident = threading.get_ident()
        transient = False
        with self._cv:
            a = self._actors.get(ident)
            if a is None:
                transient = True
                a = _Actor(ident, threading.current_thread().name,
                           next(self._order))
                self._actors[ident] = a
                self._cv.notify_all()
                self._schedule_locked()
                self._wait_for_token(a)
            self._busy[a.name] = self._busy.get(a.name, 0.0) + dt
            a.deadline = self._now + dt
            self._yield_as(a, _SLEEPING)
            self._wait_for_token(a)
            if transient:
                del self._actors[ident]
                if self._running == ident:
                    self._running = None
                self._schedule_locked()
                self._cv.notify_all()

    # ------------------------------------------------------------------
    # external-wait brackets (scheduler / engine hooks)
    # ------------------------------------------------------------------

    def block_begin(self, channel: object = None) -> None:
        """The calling actor is about to block on something *outside* the
        virtual timeline (a condition wait for work, a completion event).
        Yields the token immediately and returns — the caller then enters
        its real wait.  Call while still holding the lock the real wait
        releases, so the token's next holder cannot slip a notify in
        before the wait begins (no lost wakeups).

        ``channel`` identifies *what* is being waited on (the condition
        object, the event); the waking side calls ``wake(channel, n)`` —
        from the token holder, so the READY transition happens in token
        order, not whenever the waiter's real thread gets scheduled.  A
        waiter whose real wait can end without any sim-side waker (e.g. a
        thread join) may pass no channel and relies on ``block_end``'s
        self-wake, which is deterministic only when no runnable actor
        raced it — the engine uses that solely for final teardown."""
        with self._cv:
            a = self._actors.get(threading.get_ident())
            if a is None:
                return
            a.channel = channel
            a.bseq = next(self._bseq)
            self._yield_as(a, _BLOCKED)

    def block_end(self) -> None:
        """The real wait returned: rejoin the runnable set and block until
        the token is granted again.  Call *after* releasing the lock the
        real wait re-acquired (a token-less actor must never hold a lock
        a running actor can contend).  If a ``wake`` already moved this
        actor to READY (or granted it), only the token wait remains."""
        with self._cv:
            a = self._actors.get(threading.get_ident())
            if a is None:
                return
            if a.state == _BLOCKED:
                a.channel = None
                a.state = _READY
                self._schedule_locked()
            self._wait_for_token(a)

    def wake(self, channel: object, n: Optional[int] = None) -> int:
        """Move up to ``n`` actors blocked on ``channel`` (all, when None)
        to READY, oldest block first, and return how many moved.  Called
        by the waking side *together with* its real notify/set, from the
        token holder, so the handoff is part of the deterministic
        schedule: CPython conditions wake waiters FIFO, and blocked-stamp
        order equals real wait-entry order (block_begin happens under the
        condition's own lock), so sim and real pick the same threads."""
        with self._cv:
            blocked = sorted((a for a in self._actors.values()
                              if a.state == _BLOCKED and a.channel is channel),
                             key=lambda a: a.bseq)
            if n is not None:
                blocked = blocked[:n]
            for a in blocked:
                a.channel = None
                a.state = _READY
            if blocked and self._running is None:
                self._schedule_locked()
            return len(blocked)

    def wait_event(self, event: threading.Event) -> None:
        """Hooked ``Event.wait``: yields the token around the real wait so
        virtual time can advance past this actor while it waits for a
        completion set by another actor (who must pair ``event.set()``
        with ``wake(event)``).  Safe for unattached threads (plain
        wait)."""
        if event.is_set():
            return
        with self._cv:
            a = self._actors.get(threading.get_ident())
            if a is None:
                event.wait()
                return
            a.channel = event
            a.bseq = next(self._bseq)
            self._yield_as(a, _BLOCKED)
        event.wait()
        self.block_end()

    # ------------------------------------------------------------------
    # measurement
    # ------------------------------------------------------------------

    def makespan(self) -> float:
        """Elapsed virtual seconds — the simulated schedule's true
        critical path (idle gaps included), not the per-thread
        approximation ``VirtualClock.makespan`` returns."""
        with self._cv:
            return self._now - self._start

    def thread_seconds(self) -> dict[str, float]:
        """Per-actor virtual busy seconds, keyed by *thread name* (stable
        across runs, unlike idents): how evenly the schedule spread its
        modelled service time."""
        with self._cv:
            return dict(self._busy)


__all__ = ["SimClock"]
