"""Namespace overlay: the write-back directory-tree delta of the pending
op stream.

The optimizer's blind spot before this layer was `readdir`: every
namespace *read* was an observation point that sealed the pending chains
beneath it, so a readdir-driven `rmtree` — the paper's second headline
benchmark — forfeited elision and paid one backend op per entry.  The
overlay closes that gap by mirroring the engine's submitted mutations as
a per-directory membership delta:

* every namespace mutation (`mkdir`/`create`/`symlink`/`link`/`unlink`/
  `rmdir`/`rename`/`remove_tree`, plus implicit-create `write`s) is
  applied to the overlay at *admission* — the same instant the
  write-through stat cache learns it, strictly before the op can run;
* a directory is **complete** when its full membership is determined by
  the transaction's own writes (created inside the window) or by a cached
  backend listing (installed when a readdir miss executed);
* `readdir`/`stat`/`exists` become *overlay reads*: when the answer is
  fully determined by pending state + cache they return immediately and
  **do not seal** the chains below — observation-point classification is
  per-answer, not per-call.  An overlay miss still takes the sync path
  and seals, exactly as before.

Correctness contract (mirrors the stat cache's): the overlay answers
from *intended effects* in submission order.  A background op that later
fails invalidates every overlay claim on its paths (membership dropped,
parent completeness demoted), so the next read consults the backend; the
deferred-error ledger carries the truth either way.  A tolerant
`makedirs` mkdir that lands on a pre-existing directory demotes the
directory's completeness at execution (its real contents are unknown).

The overlay is also what makes **cross-path bulk-remove fusion** safe:
`Fuser.prepare_bulk_remove` may collapse the pending unlinks/rmdirs
under a directory into one vectored ``remove_tree`` backend call only
when the subtree is overlay-known — i.e. when the engine can prove the
directory ends empty from its own write stream (see fusion.py).

Lifecycle: populated at submission, invalidated per-path on op failure,
cleared wholesale by transaction rollback (which mutates the backend
behind the engine's back) and dropped at commit (the delta is spent once
the window closes).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass

from .backend import StatResult, is_under, norm_path, parent_of

# membership kinds tracked per directory entry (None = present, kind
# not yet proven — enough for readdir, not enough for a bulk remove)
_DIR, _FILE, _LINK = "dir", "file", "link"


@dataclass(frozen=True)
class OverlayPolicy:
    """Which overlay answers are allowed.  This is where the engine's old
    ``mock_stat``/``readdir_prefetch``/``negative_stat_cache`` flags now
    live: ``CannyFS(overlay=None)`` derives a policy from the legacy
    flags, ``overlay=OverlayPolicy(...)`` supersedes them."""

    enabled: bool = True
    readdir_overlay: bool = True   # answer readdir from the overlay
    mock_stat: bool = True         # answer stat from the write-through cache
    negative_stat: bool = True     # ...including proven-absent answers
    prefetch: bool = True          # readdir misses warm the stat cache
    #                                (one vectored readdir_plus call)

    @classmethod
    def off(cls) -> "OverlayPolicy":
        return cls(enabled=False, readdir_overlay=False, mock_stat=False,
                   negative_stat=False, prefetch=False)

    @classmethod
    def from_flags(cls, flags) -> "OverlayPolicy":
        """Fold the legacy EagerFlags knobs into an overlay policy; with
        every knob off (EagerFlags.all_off — the 'direct' baseline) the
        overlay is disabled outright and all reads hit the backend."""
        enabled = (flags.mock_stat or flags.readdir_prefetch
                   or flags.negative_stat_cache)
        return cls(enabled=enabled,
                   readdir_overlay=flags.readdir_prefetch,
                   mock_stat=flags.mock_stat,
                   negative_stat=flags.negative_stat_cache,
                   prefetch=flags.readdir_prefetch)


class _DirState:
    """One directory's delta: known-present children (name -> kind),
    known-absent names, and whether membership is complete.

    ``provisional`` completeness comes from an *unexecuted* mkdir's
    admit-time claim of a fresh empty directory.  Overlay reads may use
    it (the same intent-based approximation as the write-through stat
    cache, self-repairing at execution), but the bulk-remove pass must
    not: until the backend confirms the mkdir created the directory, a
    pre-existing directory with unknown contents is possible, and a fused
    ``remove_tree`` would silently delete data an unfused execution
    would have preserved behind ENOTEMPTY."""

    __slots__ = ("children", "absent", "complete", "provisional")

    def __init__(self):
        self.children: dict[str, str | None] = {}
        self.absent: set[str] = set()
        self.complete = False
        self.provisional = False


class NamespaceOverlay:
    """Thread-safe directory-tree delta.  A leaf lock in the engine's
    lock order (nests under shard/op/control locks, holds no other)."""

    def __init__(self, policy: OverlayPolicy | None = None):
        self.policy = policy or OverlayPolicy()
        self._lock = threading.Lock()
        self._dirs: dict[str, _DirState] = {}

    # ------------------------------------------------------------------
    # write side: mirror the op stream (called from submit's on_admit)
    # ------------------------------------------------------------------

    def _state(self, dirpath: str) -> _DirState:
        st = self._dirs.get(dirpath)
        if st is None:
            st = self._dirs[dirpath] = _DirState()
        return st

    def _add(self, dirpath: str, name: str, kind: str | None) -> None:
        st = self._state(dirpath)
        if name not in st.children:
            st.children[name] = kind
        elif st.children[name] is None and kind is not None:
            st.children[name] = kind   # first proven kind wins
        st.absent.discard(name)

    def _remove(self, dirpath: str, name: str) -> None:
        st = self._state(dirpath)
        st.children.pop(name, None)
        st.absent.add(name)

    @staticmethod
    def _split(path: str) -> tuple[str, str]:
        return parent_of(path), path.rsplit("/", 1)[-1]

    def on_op(self, kind: str, paths: tuple[str, ...], **kw) -> None:
        """Apply one admitted op's intended namespace effect."""
        with self._lock:
            if kind == "mkdir":
                p = paths[0]
                par, name = self._split(p)
                self._add(par, name, _DIR)
                # intended effect: a freshly created directory is empty,
                # hence complete — but only *provisionally* until the
                # mkdir executes (promote on success, demote on a
                # tolerant EEXIST, invalidate on error)
                st = self._state(p)
                if not st.complete:
                    st.complete = True
                    st.provisional = True
            elif kind in ("create", "write", "truncate"):
                par, name = self._split(paths[0])
                self._add(par, name, _FILE)
            elif kind == "symlink":
                par, name = self._split(paths[0])
                self._add(par, name, _LINK)
            elif kind == "link":
                par, name = self._split(paths[1] if len(paths) > 1
                                         else paths[0])
                self._add(par, name, _FILE)
            elif kind == "unlink":
                self._remove(*self._split(paths[0]))
            elif kind == "rmdir":
                p = paths[0]
                self._remove(*self._split(p))
                self._dirs.pop(p, None)
            elif kind == "remove_tree":
                root = paths[0]
                self._remove(*self._split(root))
                for k in [k for k in self._dirs if is_under(k, root)]:
                    del self._dirs[k]
            elif kind == "rename":
                src, dst = paths
                kind_src = None
                sp, sn = self._split(src)
                st = self._dirs.get(sp)
                if st is not None:
                    kind_src = st.children.get(sn)
                self._remove(sp, sn)
                # transfer the renamed subtree's dir states key-for-key
                moved_dir = False
                for k in [k for k in self._dirs if is_under(k, src)]:
                    self._dirs[dst + k[len(src):]] = self._dirs.pop(k)
                    moved_dir = moved_dir or k == src
                dp, dn = self._split(dst)
                self._add(dp, dn, _DIR if moved_dir else kind_src)
            elif kind == "fallocate":
                # backends disagree on whether fallocate creates a missing
                # file (LocalBackend does, InMemory does not) — membership
                # under its parent is no longer provable
                st = self._dirs.get(parent_of(paths[0]))
                if st is not None:
                    st.complete = False

    def install_listing(self, path: str,
                        listing: list[tuple[str, StatResult | None]]) -> None:
        """Install a backend listing (from an executed readdir miss) as the
        directory's base membership.  Names the overlay already has a
        delta for keep it — their ops are ordered around the readdir and
        the listing agrees with every op ordered before it."""
        with self._lock:
            if path:
                # a rmdir/remove_tree admitted after this readdir was
                # submitted already popped the dir's state and marked it
                # absent in its parent — installing the (older) listing
                # would resurrect a complete overlay entry for a
                # directory that no longer exists
                par, name = self._split(path)
                pst = self._dirs.get(par)
                if pst is not None and name in pst.absent:
                    return
            st = self._state(path)
            for name, stt in listing:
                if name in st.children or name in st.absent:
                    continue
                st.children[name] = (None if stt is None
                                     else _DIR if stt.is_dir
                                     else _LINK if stt.is_symlink
                                     else _FILE)
            st.complete = True
            st.provisional = False   # backend truth, not an intent claim

    # ------------------------------------------------------------------
    # read side
    # ------------------------------------------------------------------

    def readdir(self, path: str) -> list[str] | None:
        """The directory's full listing, or None when membership is not
        fully determined by pending state + cached listings (a miss: the
        caller must take the sync, sealing path)."""
        with self._lock:
            st = self._dirs.get(path)
            if st is None or not st.complete:
                return None
            return sorted(st.children)

    def lookup(self, path: str) -> bool | None:
        """Presence of ``path``: True/False when provable, None otherwise.
        False needs either an explicit absence delta (unlinked/removed in
        the window) or a complete parent that does not list the name."""
        path = norm_path(path)
        if not path:
            return True
        with self._lock:
            par, name = self._split(path)
            st = self._dirs.get(par)
            if st is None:
                return None
            if name in st.children:
                return True
            if name in st.absent or st.complete:
                return False
            return None

    def subtree(self, root: str) -> tuple[list[str], list[str]] | None:
        """(files, dirs) of *present* entries under ``root``, or None when
        any reachable directory is incomplete, provisional (its mkdir has
        not yet proven the dir was created fresh) or any kind unproven —
        the bulk-remove pass may only fire on a fully overlay-PROVEN
        tree, because a fused remove_tree deletes unconditionally where
        an unfused rmdir would have failed ENOTEMPTY."""
        with self._lock:
            return self._subtree(root)

    def _subtree(self, root):
        st = self._dirs.get(root)
        if st is None or not st.complete or st.provisional:
            return None
        files: list[str] = []
        dirs: list[str] = []
        for name, kind in st.children.items():
            p = f"{root}/{name}" if root else name
            if kind == _DIR:
                sub = self._subtree(p)
                if sub is None:
                    return None
                dirs.append(p)
                files.extend(sub[0])
                dirs.extend(sub[1])
            elif kind is None:
                return None
            else:
                files.append(p)
        return files, dirs

    # ------------------------------------------------------------------
    # invalidation
    # ------------------------------------------------------------------

    def invalidate(self, path: str) -> None:
        """A background op on ``path`` failed (or was cancelled): every
        claim the overlay made about it is suspect.  Drop its membership
        entry, demote its parent's completeness, and forget the state of
        any directory at or under it."""
        path = norm_path(path)
        with self._lock:
            if path:
                par, name = self._split(path)
                st = self._dirs.get(par)
                if st is not None:
                    st.children.pop(name, None)
                    st.absent.discard(name)
                    st.complete = False
            for k in [k for k in self._dirs if is_under(k, path)]:
                del self._dirs[k]

    def demote(self, path: str) -> None:
        """Keep the membership delta but drop completeness (a tolerant
        mkdir found the directory pre-existing: its base contents are
        unknown, the deltas recorded so far are still valid)."""
        with self._lock:
            st = self._dirs.get(norm_path(path))
            if st is not None:
                st.complete = False
                st.provisional = False

    def promote(self, path: str) -> None:
        """An executed mkdir confirmed it created ``path`` fresh: its
        provisional admit-time completeness is now backend-proven.  A
        state popped in the meantime (a rmdir admitted while the mkdir
        was pending) is deliberately NOT resurrected."""
        with self._lock:
            st = self._dirs.get(norm_path(path))
            if st is not None and st.complete:
                st.provisional = False

    def clear(self) -> None:
        with self._lock:
            self._dirs.clear()


__all__ = ["NamespaceOverlay", "OverlayPolicy"]
