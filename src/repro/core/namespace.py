"""Namespace overlay: the write-back directory-tree delta of the pending
op stream.

The optimizer's blind spot before this layer was `readdir`: every
namespace *read* was an observation point that sealed the pending chains
beneath it, so a readdir-driven `rmtree` — the paper's second headline
benchmark — forfeited elision and paid one backend op per entry.  The
overlay closes that gap by mirroring the engine's submitted mutations as
a per-directory membership delta:

* every namespace mutation (`mkdir`/`create`/`symlink`/`link`/`unlink`/
  `rmdir`/`rename`/`remove_tree`, plus implicit-create `write`s) is
  applied to the overlay at *admission* — the same instant the
  write-through stat cache learns it, strictly before the op can run;
* a directory is **complete** when its full membership is determined by
  the transaction's own writes (created inside the window) or by a cached
  backend listing (installed when a readdir miss executed);
* `readdir`/`stat`/`exists` become *overlay reads*: when the answer is
  fully determined by pending state + cache they return immediately and
  **do not seal** the chains below — observation-point classification is
  per-answer, not per-call.  An overlay miss still takes the sync path
  and seals, exactly as before.

Correctness contract (mirrors the stat cache's): the overlay answers
from *intended effects* in submission order.  A background op that later
fails invalidates every overlay claim on its paths (membership dropped,
parent completeness demoted), so the next read consults the backend; the
deferred-error ledger carries the truth either way.  A tolerant
`makedirs` mkdir that lands on a pre-existing directory demotes the
directory's completeness at execution (its real contents are unknown).

The overlay is also what makes **cross-path bulk-remove fusion** safe:
`Fuser.prepare_bulk_remove` may collapse the pending unlinks/rmdirs
under a directory into one vectored ``remove_tree`` backend call only
when the subtree is overlay-known — i.e. when the engine can prove the
directory ends empty from its own write stream (see fusion.py).

Lifecycle: populated at submission, invalidated per-path on op failure,
cleared wholesale by transaction rollback (which mutates the backend
behind the engine's back) and dropped at commit (the delta is spent once
the window closes).
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass

from .backend import StatResult, is_under, norm_path, parent_of

# membership kinds tracked per directory entry (None = present, kind
# not yet proven — enough for readdir, not enough for a bulk remove)
_DIR, _FILE, _LINK = "dir", "file", "link"


@dataclass(frozen=True)
class OverlayPolicy:
    """Which overlay answers are allowed.  This is where the engine's old
    ``mock_stat``/``readdir_prefetch``/``negative_stat_cache`` flags now
    live: ``CannyFS(overlay=None)`` derives a policy from the legacy
    flags, ``overlay=OverlayPolicy(...)`` supersedes them."""

    enabled: bool = True
    readdir_overlay: bool = True   # answer readdir from the overlay
    mock_stat: bool = True         # answer stat from the write-through cache
    negative_stat: bool = True     # ...including proven-absent answers
    prefetch: bool = True          # readdir misses warm the stat cache
    #                                (one vectored readdir_plus call)
    # LRU bound on directories whose completeness comes from a *cached
    # backend listing* (installed by an executed readdir miss).  Eviction
    # demotes completeness only — the pending membership delta (entries
    # created/unlinked through the mount) is never dropped, so a re-listed
    # directory still merges the transaction's own writes.  Directories
    # complete from in-window creation don't count against the bound.
    # <= 0 means unbounded.
    max_cached_listings: int = 4096

    @classmethod
    def off(cls) -> "OverlayPolicy":
        return cls(enabled=False, readdir_overlay=False, mock_stat=False,
                   negative_stat=False, prefetch=False)

    @classmethod
    def from_flags(cls, flags) -> "OverlayPolicy":
        """Fold the legacy EagerFlags knobs into an overlay policy; with
        every knob off (EagerFlags.all_off — the 'direct' baseline) the
        overlay is disabled outright and all reads hit the backend."""
        enabled = (flags.mock_stat or flags.readdir_prefetch
                   or flags.negative_stat_cache)
        return cls(enabled=enabled,
                   readdir_overlay=flags.readdir_prefetch,
                   mock_stat=flags.mock_stat,
                   negative_stat=flags.negative_stat_cache,
                   prefetch=flags.readdir_prefetch)


class _DirState:
    """One directory's delta: known-present children (name -> kind),
    known-absent names, and whether membership is complete.

    ``provisional`` completeness comes from an *unexecuted* mkdir's
    admit-time claim of a fresh empty directory.  Overlay reads may use
    it (the same intent-based approximation as the write-through stat
    cache, self-repairing at execution), but the bulk-remove pass must
    not: until the backend confirms the mkdir created the directory, a
    pre-existing directory with unknown contents is possible, and a fused
    ``remove_tree`` would silently delete data an unfused execution
    would have preserved behind ENOTEMPTY.

    ``speculative`` marks a completeness installed by the metadata
    prefetch pipeline (``install_speculative``) that no consumer has read
    yet — purely observability (``prefetch_hits``); the listing itself is
    executed backend truth, exactly like a sync readdir miss's."""

    __slots__ = ("children", "absent", "complete", "provisional",
                 "speculative")

    def __init__(self):
        self.children: dict[str, str | None] = {}
        self.absent: set[str] = set()
        self.complete = False
        self.provisional = False
        self.speculative = False


class SpeculationTicket:
    """One in-flight speculative listing's validity token.

    Registered by ``speculation_wanted`` when the prefetcher enqueues a
    directory; any racing *admitted* mutation that could make the fetched
    listing stale — rmdir/remove_tree/rename at or above the directory, a
    mkdir over it, an op failure invalidating it or its parent's
    membership, a transaction rollback — flips ``cancelled`` under the
    overlay lock, and ``install_speculative`` then refuses the listing.
    This is what keeps the prefetch pipeline *advisory*: a speculative
    read can warm the overlay only while nothing has moved underneath it,
    so observed semantics stay byte-identical to the unprefetched
    engine."""

    __slots__ = ("path", "cancelled")

    def __init__(self, path: str):
        self.path = path
        self.cancelled = False


class RemoveWitness:
    """Exec-time re-verification token for one fused bulk removal
    (ROADMAP item m).

    Registered by ``subtree_for_removal`` for every directory whose
    completeness was still *provisional* at fuse time (its mkdir admitted
    but not yet executed).  The overlay updates it as those mkdirs land:
    ``promote`` discards the dir from ``pending`` (created fresh — the
    claim holds), ``demote``/``invalidate`` set ``demoted`` (the dir
    pre-existed or its op failed: a fused unconditional removal could
    delete contents an unfused ENOTEMPTY would have preserved).  All
    mutation happens under the overlay's lock; the executor reads the
    verdict through ``resolve_witness``."""

    __slots__ = ("pending", "watched", "demoted")

    def __init__(self):
        self.pending: set[str] = set()   # dirs awaiting their mkdir's proof
        self.watched: set[str] = set()   # every dir ever registered (for
        #                                  watcher-list cleanup)
        self.demoted = False


class NamespaceOverlay:
    """Thread-safe directory-tree delta.  A leaf lock in the engine's
    lock order (nests under shard/op/control locks, holds no other)."""

    def __init__(self, policy: OverlayPolicy | None = None):
        self.policy = policy or OverlayPolicy()
        self._lock = threading.Lock()
        self._dirs: dict[str, _DirState] = {}
        # LRU over dirs whose completeness came from a cached backend
        # listing (insertion/refresh order = recency; see OverlayPolicy)
        self._listed: OrderedDict[str, None] = OrderedDict()
        # exec-time re-verification: provisional dir -> watching witnesses
        self._watchers: dict[str, list[RemoveWitness]] = {}
        # speculative prefetch tickets: path -> the (single) live ticket
        self._specs: dict[str, SpeculationTicket] = {}

    # ------------------------------------------------------------------
    # write side: mirror the op stream (called from submit's on_admit)
    # ------------------------------------------------------------------

    def _state(self, dirpath: str) -> _DirState:
        st = self._dirs.get(dirpath)
        if st is None:
            st = self._dirs[dirpath] = _DirState()
        return st

    # -- cached-listing LRU (all under self._lock) ---------------------

    def _touch_listing(self, path: str, *, cold: bool = False) -> None:
        """Mark a cached-listing dir most-recently-used — or, with
        ``cold``, least-recently-used — and evict past the policy bound.
        Eviction demotes completeness only: the membership delta (pending
        entries created/removed through the mount) stays.

        ``cold`` is the speculative-install recency: a prefetched listing
        enters at the LRU-cold end, so at capacity speculation evicts
        other speculation (or itself) and can never demote the hot
        in-use window; a dir already cached hot keeps its recency."""
        bound = self.policy.max_cached_listings
        if bound <= 0:
            return
        if cold:
            if path not in self._listed:
                self._listed[path] = None
                self._listed.move_to_end(path, last=False)
        else:
            self._listed[path] = None
            self._listed.move_to_end(path)
        while len(self._listed) > bound:
            victim, _ = self._listed.popitem(last=False)
            st = self._dirs.get(victim)
            if st is not None:
                st.complete = False
                st.provisional = False
                st.speculative = False

    def _drop_listed(self, path: str) -> None:
        self._listed.pop(path, None)

    def _demote_watchers_under(self, path: str) -> None:
        """A dir at/under ``path`` became unreliable: demote every fused
        removal whose proof rests on it."""
        for k, ws in self._watchers.items():
            if is_under(k, path):
                for w in ws:
                    w.demoted = True

    # -- speculative-prefetch tickets (all under self._lock) -----------

    def _cancel_specs_under(self, path: str) -> None:
        """A structural mutation at ``path``: every in-flight speculative
        listing at/under it would be stale on arrival — cancel them."""
        if not self._specs:
            return
        for k, t in self._specs.items():
            if is_under(k, path):
                t.cancelled = True

    def _cancel_spec_at(self, path: str) -> None:
        t = self._specs.get(path)
        if t is not None:
            t.cancelled = True

    def _add(self, dirpath: str, name: str, kind: str | None) -> None:
        st = self._state(dirpath)
        if name not in st.children:
            st.children[name] = kind
        elif st.children[name] is None and kind is not None:
            st.children[name] = kind   # first proven kind wins
        st.absent.discard(name)

    def _remove(self, dirpath: str, name: str) -> None:
        st = self._state(dirpath)
        st.children.pop(name, None)
        st.absent.add(name)

    @staticmethod
    def _split(path: str) -> tuple[str, str]:
        return parent_of(path), path.rsplit("/", 1)[-1]

    def on_op(self, kind: str, paths: tuple[str, ...], **kw) -> None:
        """Apply one admitted op's intended namespace effect."""
        with self._lock:
            if kind == "mkdir":
                p = paths[0]
                # a mkdir over a dir being speculatively listed changes
                # what the listing should say — the in-flight fetch loses
                self._cancel_spec_at(p)
                par, name = self._split(p)
                self._add(par, name, _DIR)
                # intended effect: a freshly created directory is empty,
                # hence complete — but only *provisionally* until the
                # mkdir executes (promote on success, demote on a
                # tolerant EEXIST, invalidate on error)
                st = self._state(p)
                if not st.complete:
                    st.complete = True
                    st.provisional = True
            elif kind in ("create", "write", "truncate"):
                par, name = self._split(paths[0])
                self._add(par, name, _FILE)
            elif kind == "symlink":
                par, name = self._split(paths[0])
                self._add(par, name, _LINK)
            elif kind == "link":
                par, name = self._split(paths[1] if len(paths) > 1
                                         else paths[0])
                self._add(par, name, _FILE)
            elif kind == "unlink":
                self._remove(*self._split(paths[0]))
            elif kind == "rmdir":
                p = paths[0]
                self._cancel_specs_under(p)
                self._remove(*self._split(p))
                self._dirs.pop(p, None)
                self._drop_listed(p)
            elif kind == "remove_tree":
                root = paths[0]
                self._cancel_specs_under(root)
                self._remove(*self._split(root))
                for k in [k for k in self._dirs if is_under(k, root)]:
                    del self._dirs[k]
                for k in [k for k in self._listed if is_under(k, root)]:
                    del self._listed[k]
            elif kind == "rename":
                src, dst = paths
                # in-flight listings anywhere under either endpoint would
                # land at paths that no longer mean the same directory
                self._cancel_specs_under(src)
                self._cancel_specs_under(dst)
                kind_src = None
                sp, sn = self._split(src)
                st = self._dirs.get(sp)
                if st is not None:
                    kind_src = st.children.get(sn)
                self._remove(sp, sn)
                # transfer the renamed subtree's dir states key-for-key
                moved_dir = False
                for k in [k for k in self._dirs if is_under(k, src)]:
                    self._dirs[dst + k[len(src):]] = self._dirs.pop(k)
                    moved_dir = moved_dir or k == src
                for k in [k for k in self._listed if is_under(k, src)]:
                    del self._listed[k]
                    self._listed[dst + k[len(src):]] = None
                dp, dn = self._split(dst)
                self._add(dp, dn, _DIR if moved_dir else kind_src)
            elif kind == "fallocate":
                # backends disagree on whether fallocate creates a missing
                # file (LocalBackend does, InMemory does not) — membership
                # under its parent is no longer provable, and a listing of
                # the parent already in flight must not re-prove it
                self._cancel_spec_at(parent_of(paths[0]))
                st = self._dirs.get(parent_of(paths[0]))
                if st is not None:
                    st.complete = False

    def _merge_listing_locked(self, path: str, listing) -> _DirState:
        """Merge a backend listing into ``path``'s base membership (names
        the overlay already has a delta for keep it — their ops are
        ordered around the listing and the listing agrees with every op
        ordered before it) and mark the dir complete."""
        st = self._state(path)
        for name, stt in listing:
            if name in st.children or name in st.absent:
                continue
            st.children[name] = (None if stt is None
                                 else _DIR if stt.is_dir
                                 else _LINK if stt.is_symlink
                                 else _FILE)
        st.complete = True
        st.provisional = False   # backend truth, not an intent claim
        return st

    def _removed_behind_locked(self, path: str) -> bool:
        """True when a rmdir/remove_tree/rename admitted after a listing
        of ``path`` was taken already popped the dir's state and marked
        it absent in its parent — installing the (older) listing would
        resurrect a complete overlay entry for a directory that no
        longer exists."""
        if not path:
            return False
        par, name = self._split(path)
        pst = self._dirs.get(par)
        return pst is not None and name in pst.absent

    def install_listing(self, path: str,
                        listing: list[tuple[str, StatResult | None]]) -> None:
        """Install a backend listing (from an executed readdir miss) as
        the directory's base membership, at hot LRU recency."""
        with self._lock:
            if self._removed_behind_locked(path):
                return
            self._merge_listing_locked(path, listing)
            self._touch_listing(path)

    # ------------------------------------------------------------------
    # speculative prefetch (core/prefetch.py rides these)
    # ------------------------------------------------------------------

    def speculation_wanted(self, path: str) -> SpeculationTicket | None:
        """Register intent to speculatively list ``path``; None when a
        fetch would be pointless (already complete, already being
        fetched, or pending removal/rename marked it absent)."""
        path = norm_path(path)
        if not self.policy.enabled:
            return None
        with self._lock:
            if path in self._specs:
                return None
            st = self._dirs.get(path)
            if st is not None and st.complete:
                return None
            if self._removed_behind_locked(path):
                return None
            t = SpeculationTicket(path)
            self._specs[path] = t
            return t

    def end_speculation(self, ticket: SpeculationTicket | None) -> None:
        """Unregister a ticket without installing (idempotent) — the
        fetch failed, was dropped, or its batch was cancelled."""
        if ticket is None:
            return
        with self._lock:
            if self._specs.get(ticket.path) is ticket:
                del self._specs[ticket.path]

    def install_speculative(self, ticket: SpeculationTicket,
                            listing, warm=None) -> str:
        """Install a speculatively fetched listing, atomically re-checking
        the ticket under the overlay lock.  ``warm`` (if given) runs
        *inside* the critical section on a successful install — the
        prefetcher warms the stat cache there, so a racing op-failure
        invalidation (which takes this lock first, then clears the stat
        cache) can never lose to a late warming write.  Returns the
        verdict:

        * ``"installed"`` — the listing is now the dir's base membership,
          inserted at LRU-*cold* recency (it can never evict the hot
          in-use window; see ``_touch_listing``);
        * ``"cancelled"`` — a racing admitted mutation invalidated the
          fetch (the prefetcher counts it, nothing was installed);
        * ``"stale"``     — the dir was already complete (a sync miss beat
          the speculation) or a pending removal marked it absent;
        * ``"evicted"``   — installed but immediately evicted by the
          cached-listings bound (the cache is full of hotter entries)."""
        path = ticket.path
        with self._lock:
            if self._specs.get(path) is ticket:
                del self._specs[path]
            if ticket.cancelled:
                return "cancelled"
            if self._removed_behind_locked(path):
                return "stale"
            st = self._dirs.get(path)
            if st is not None and st.complete:
                return "stale"
            st = self._merge_listing_locked(path, listing)
            st.speculative = True
            if warm is not None:
                warm()
            self._touch_listing(path, cold=True)
            if not st.complete:
                st.speculative = False
                return "evicted"
            return "installed"

    def was_speculative(self, path: str) -> bool:
        """True exactly once per consumed speculative listing: the first
        overlay read answered from it clears the flag (prefetch_hits)."""
        with self._lock:
            st = self._dirs.get(path)
            if st is not None and st.speculative:
                st.speculative = False
                return True
            return False

    # ------------------------------------------------------------------
    # read side
    # ------------------------------------------------------------------

    def readdir(self, path: str) -> list[str] | None:
        """The directory's full listing, or None when membership is not
        fully determined by pending state + cached listings (a miss: the
        caller must take the sync, sealing path)."""
        with self._lock:
            st = self._dirs.get(path)
            if st is None or not st.complete:
                return None
            if path in self._listed:
                self._touch_listing(path)   # LRU recency on cache hits
            return sorted(st.children)

    def listing_kinds(self, path: str) -> tuple[list[str], list[str]] | None:
        """(subdir names, file/link names) of a complete directory with
        every child's kind proven, or None (the walk fast path falls back
        to readdir + per-entry stat for this directory only)."""
        with self._lock:
            st = self._dirs.get(path)
            if st is None or not st.complete:
                return None
            dirs: list[str] = []
            files: list[str] = []
            for name in sorted(st.children):
                kind = st.children[name]
                if kind is None:
                    return None
                (dirs if kind == _DIR else files).append(name)
            if path in self._listed:
                self._touch_listing(path)
            return dirs, files

    def lookup(self, path: str) -> bool | None:
        """Presence of ``path``: True/False when provable, None otherwise.
        False needs either an explicit absence delta (unlinked/removed in
        the window) or a complete parent that does not list the name."""
        path = norm_path(path)
        if not path:
            return True
        with self._lock:
            par, name = self._split(path)
            st = self._dirs.get(par)
            if st is None:
                return None
            if name in st.children:
                return True
            if name in st.absent or st.complete:
                return False
            return None

    def subtree_for_removal(self, root: str, *, allow_provisional: bool
                            ) -> tuple[list[str], list[str],
                                       "RemoveWitness | None"] | None:
        """(files, dirs, witness) of *present* entries under ``root`` for
        the bulk-remove pass, or None when any reachable directory is
        incomplete or any kind unproven — the pass may only fire on an
        overlay-proven tree, because a fused remove_tree deletes
        unconditionally where an unfused rmdir would have failed
        ENOTEMPTY.

        Without ``allow_provisional`` a directory whose completeness is
        still an unexecuted mkdir's admit-time claim also returns None.
        With it, the scan tolerates such directories and returns a
        ``RemoveWitness`` watching them (registered atomically with the
        scan, so a promote/demote racing the fuse decision is never
        lost).  The witness is None when the whole tree was already
        backend-proven.  The caller must either attach the witness to the
        fused op (released by the engine at completion) or hand it back
        via ``release_witness`` when it declines to fuse."""
        with self._lock:
            prov: list[str] = []
            sub = self._subtree(root, prov if allow_provisional else None)
            if sub is None:
                return None
            files, dirs = sub
            if not prov:
                return files, dirs, None
            w = RemoveWitness()
            w.pending.update(prov)
            w.watched.update(prov)
            for d in prov:
                self._watchers.setdefault(d, []).append(w)
            return files, dirs, w

    def _subtree(self, root, provisional_out):
        """``provisional_out`` is None for strict (backend-proven only)
        scans, or a list collecting the provisional dirs encountered."""
        st = self._dirs.get(root)
        if st is None or not st.complete:
            return None
        if st.provisional:
            if provisional_out is None:
                return None
            provisional_out.append(root)
        files: list[str] = []
        dirs: list[str] = []
        for name, kind in st.children.items():
            p = f"{root}/{name}" if root else name
            if kind == _DIR:
                sub = self._subtree(p, provisional_out)
                if sub is None:
                    return None
                dirs.append(p)
                files.extend(sub[0])
                dirs.extend(sub[1])
            elif kind is None:
                return None
            else:
                files.append(p)
        return files, dirs

    # ------------------------------------------------------------------
    # exec-time re-verification witnesses (the bulk-remove pass under
    # provisional dirs: fusion.BulkRemovePayload carries one of these)
    # ------------------------------------------------------------------

    def merge_witness(self, parent: RemoveWitness | None,
                      child: RemoveWitness) -> RemoveWitness:
        """A parent fused removal absorbs a child's: the parent inherits
        every directory the child is still waiting on (and its verdict so
        far), so the rolled-up op re-verifies the whole subtree."""
        with self._lock:
            if parent is None:
                parent = RemoveWitness()
            parent.demoted = parent.demoted or child.demoted
            for d in child.pending:
                if d not in parent.watched:
                    parent.watched.add(d)
                    self._watchers.setdefault(d, []).append(parent)
                parent.pending.add(d)
            return parent

    def resolve_witness(self, w: RemoveWitness) -> str:
        """The exec-time verdict: ``"promoted"`` (every watched mkdir
        created its dir fresh — run the vectored removal), ``"demoted"``
        (any demotion/invalidation, or a mkdir somehow still unproven —
        take the byte-identical per-entry fallback), or ``"clean"`` (the
        witness never watched anything)."""
        with self._lock:
            if w.demoted or w.pending:
                return "demoted"
            return "promoted" if w.watched else "clean"

    def release_witness(self, w: RemoveWitness | None) -> None:
        """Unregister a witness from every watcher list (idempotent)."""
        if w is None:
            return
        with self._lock:
            for d in w.watched:
                lst = self._watchers.get(d)
                if lst is None:
                    continue
                try:
                    lst.remove(w)
                except ValueError:
                    pass
                if not lst:
                    del self._watchers[d]
            w.watched.clear()

    # ------------------------------------------------------------------
    # invalidation
    # ------------------------------------------------------------------

    def invalidate(self, path: str) -> None:
        """A background op on ``path`` failed (or was cancelled): every
        claim the overlay made about it is suspect.  Drop its membership
        entry, demote its parent's completeness, forget the state of any
        directory at or under it, and demote every fused removal whose
        re-verification watches a directory in that subtree."""
        path = norm_path(path)
        with self._lock:
            # a failed op's effects are unknown (a torn write may have
            # created the file after all): an in-flight listing of the
            # parent fetched before the failure must not re-prove its
            # membership, and nothing under the path can be trusted
            self._cancel_specs_under(path)
            if path:
                self._cancel_spec_at(parent_of(path))
                par, name = self._split(path)
                st = self._dirs.get(par)
                if st is not None:
                    st.children.pop(name, None)
                    st.absent.discard(name)
                    st.complete = False
            for k in [k for k in self._dirs if is_under(k, path)]:
                del self._dirs[k]
            for k in [k for k in self._listed if is_under(k, path)]:
                del self._listed[k]
            self._demote_watchers_under(path)

    def demote(self, path: str) -> None:
        """Keep the membership delta but drop completeness (a tolerant
        mkdir found the directory pre-existing: its base contents are
        unknown, the deltas recorded so far are still valid).  Any fused
        removal watching this directory loses its proof."""
        path = norm_path(path)
        with self._lock:
            self._cancel_spec_at(path)
            st = self._dirs.get(path)
            if st is not None:
                st.complete = False
                st.provisional = False
                st.speculative = False
            for w in self._watchers.get(path, ()):
                w.demoted = True

    def promote(self, path: str) -> None:
        """An executed mkdir confirmed it created ``path`` fresh: its
        provisional admit-time completeness is now backend-proven, and
        any fused removal watching the directory checks it off.  A state
        popped in the meantime (a rmdir admitted while the mkdir was
        pending) is deliberately NOT resurrected — but the witnesses are
        still settled: the fused removal that popped it is exactly the op
        waiting on this proof."""
        path = norm_path(path)
        with self._lock:
            st = self._dirs.get(path)
            if st is not None and st.complete:
                st.provisional = False
            for w in self._watchers.get(path, ()):
                w.pending.discard(path)

    def delta_summary(self) -> dict:
        """Snapshot of the membership delta this overlay is holding: how
        many directories are tracked, how many of those carry a full
        (complete) listing vs. a provisional or speculative one, and the
        totals of known-present children and known-absent names.  This is
        the view the durability layer reports after a resume reinstalls
        the delta from the spill journal (a resumed mount should show the
        same counts as the preempted one for the replayed prefix) — and a
        cheap invariant hook for tests that don't want to poke _dirs."""
        with self._lock:
            dirs = len(self._dirs)
            complete = provisional = speculative = 0
            children = absent = 0
            for st in self._dirs.values():
                if st.complete:
                    complete += 1
                if st.provisional:
                    provisional += 1
                if st.speculative:
                    speculative += 1
                children += len(st.children)
                absent += len(st.absent)
            return {
                "dirs": dirs,
                "complete": complete,
                "provisional": provisional,
                "speculative": speculative,
                "children": children,
                "absent": absent,
            }

    def clear(self) -> None:
        with self._lock:
            self._dirs.clear()
            self._listed.clear()
            # rollback mutates the backend behind the engine: no pending
            # fused removal may keep trusting its pre-rollback proof
            for ws in self._watchers.values():
                for w in ws:
                    w.demoted = True
            self._watchers.clear()
            # ...and no speculative listing fetched before the window
            # closed may install afterwards
            for t in self._specs.values():
                t.cancelled = True
            self._specs.clear()

    def clear_under(self, prefix: str) -> None:
        """Tenant-scoped window close (PR 10): drop every claim at or
        under ``prefix`` — directory states, cached listings, fused-
        removal proofs and in-flight speculative fetches — while claims
        about the rest of the namespace (the neighbour tenants' open
        optimization windows) stand untouched.  The prefix's own parent
        loses the child's membership claim too: a rollback may have
        removed the subtree's root itself."""
        prefix = norm_path(prefix)
        if not prefix:
            self.clear()
            return
        with self._lock:
            self._cancel_specs_under(prefix)
            self._cancel_spec_at(parent_of(prefix))
            par, name = self._split(prefix)
            st = self._dirs.get(par)
            if st is not None:
                # membership of the scoped root in its (shared) parent is
                # no longer proven either way
                st.children.pop(name, None)
                st.absent.discard(name)
                st.complete = False
            for k in [k for k in self._dirs if is_under(k, prefix)]:
                del self._dirs[k]
            for k in [k for k in self._listed if is_under(k, prefix)]:
                del self._listed[k]
            self._demote_watchers_under(prefix)


__all__ = ["NamespaceOverlay", "OverlayPolicy", "RemoveWitness",
           "SpeculationTicket"]
