"""Per-operation eagerness flags.

The paper: "Individual flags are provided for the eagerness status for
approximately 20 different I/O operations, roughly corresponding to different
POSIX I/O primitives. The default setting is that all of these are on."

An *eager* operation is acknowledged to the caller immediately and executed
in the background; a non-eager one is still routed through the same per-path
queues (to keep ordering) but the caller blocks until it really completed and
sees its error directly.  Data reads can never be eager.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class EagerFlags:
    # -- structural / namespace ops ------------------------------------
    mkdir: bool = True
    rmdir: bool = True
    remove_tree: bool = True     # fused bulk removal (rides rmdir's mode)
    create: bool = True          # file creation (open with O_CREAT)
    unlink: bool = True
    rename: bool = True
    symlink: bool = True
    link: bool = True            # hard link
    # -- data ops -------------------------------------------------------
    write: bool = True           # pwrite-style block write
    truncate: bool = True
    flush: bool = True           # close()/flush barrier per file
    fsync: bool = True
    fallocate: bool = True
    # -- metadata writes --------------------------------------------------
    chmod: bool = True
    chown: bool = True
    utimens: bool = True
    setxattr: bool = True
    removexattr: bool = True
    # -- metadata reads (mocking / caching, not deferral) ------------------
    # These three now parameterize the namespace overlay (core/namespace.py)
    # via OverlayPolicy.from_flags; an explicit CannyFS(overlay=...) policy
    # supersedes them.
    mock_stat: bool = True       # answer stat from the write-through cache
    readdir_prefetch: bool = True  # answer/warm readdir via the overlay
    negative_stat_cache: bool = True  # cache ENOENT results from unlink/rmdir

    def replace(self, **kw) -> "EagerFlags":
        return dataclasses.replace(self, **kw)

    @classmethod
    def all_off(cls) -> "EagerFlags":
        """Fully synchronous mode — the 'direct' baseline through the same
        code path (useful to isolate engine overhead from eagerness wins)."""
        return cls(**{f.name: False for f in dataclasses.fields(cls)})

    def is_eager(self, kind: str) -> bool:
        return bool(getattr(self, kind, False))


N_FLAGS = len(dataclasses.fields(EagerFlags))  # ~20, as in the paper
