"""Job-as-transaction semantics (paper §1, §5).

A Transaction brackets a region of work against a CannyFS mount:

* every path *created* inside the region is journaled;
* ``commit()`` drains the engine and succeeds iff no deferred error was
  recorded during the region — the job's outputs are then durable;
* ``rollback()`` removes everything the region created (files first, then
  directories deepest-first), restoring the pre-transaction namespace;
* ``run_transaction`` is the paper's "roll back and resubmit" loop.

Transactions are also the *optimization-window* boundaries for the
engine's op-fusion pass: between observation points (reads, barriers and
this module's commit/rollback drains) the region's pending op stream may
be coalesced, folded or elided, because only commit-visible state is
promised.  The boundaries compose mechanically: the fusion pass only
rewrites ops in the *same* region (so a fused failure lands in exactly one
region's ledger scope and an elided create skips exactly that region's
journal), every sync op and barrier seals the ops it waits on, and
commit/rollback drain — after which nothing is pending to rewrite.  An op
elided inside a region therefore commits trivially (its effects were
proven invisible) and has nothing to roll back (it journaled nothing and
created nothing).

Torn ops ride the same loop: a fused write that lands short surfaces as a
deferred ``ShortWriteError`` (errno EIO, transient), the torn file *was*
journaled before the tear was detected, so rollback removes it and the
resubmission rewrites it whole.
"""
from __future__ import annotations

import errno as _errno
import random
import threading
import time
import zlib
from typing import Callable, TypeVar

from .backend import is_under
from .errors import (EnginePoisonedError, OpCancelledError, ProcessKilled,
                     RollbackLeakError, TransactionFailedError)
from .fs import CannyFS

T = TypeVar("T")

# OSError errnos worth resubmitting a job over (the paper's transient I/O
# failure classes).  ENOENT/EISDIR/EEXIST-style errors are deterministic
# body bugs: retrying them just replays the same failure.
TRANSIENT_ERRNOS = frozenset({
    _errno.EIO, _errno.ENOSPC, _errno.EDQUOT, _errno.EACCES, _errno.EPERM,
    _errno.ECONNRESET, _errno.ECONNABORTED, _errno.ECONNREFUSED,
    _errno.ETIMEDOUT, _errno.ESTALE, _errno.EAGAIN, _errno.EINTR,
    _errno.ENETDOWN, _errno.ENETUNREACH, _errno.EBUSY,
})


class Transaction:
    def __init__(self, fs: CannyFS, name: str = "txn"):
        self.fs = fs
        self.name = name
        self._lock = threading.Lock()
        self._created: dict[str, bool] = {}   # path -> is_dir
        self._preexisting: set[str] = set()   # probe memo (see _write_at)
        self._active = False
        self.committed = False
        self.rolled_back = False
        # paths rollback could not remove (verified against the backend)
        self.rollback_leftovers: list[str] = []
        # the region's deferred errors as they stood when rollback ran
        # (rollback clears them from the ledger; retry decisions need them)
        self.final_errors: list = []

    # -- journal hooks (called by CannyFS) --
    def _record_create(self, path: str, is_dir: bool) -> None:
        with self._lock:
            known = self._created.get(path)
            self._created[path] = is_dir
        if known is None or known != is_dir:
            # new (or re-kinded) journal entry: persist it so a resumed
            # attempt's rollback scope covers this path too.  Seeded
            # entries (attach_txn) re-record nothing.
            sp = self.fs._spill()
            if sp is not None:
                sp.record_journal(path, is_dir)

    def _has_created(self, path: str) -> bool:
        with self._lock:
            return path in self._created

    # existence-probe memo for _write_at's orphan check: paths proven to
    # pre-exist are never probed again (streamed appends stay one op/chunk)
    def _is_preexisting(self, path: str) -> bool:
        with self._lock:
            return path in self._preexisting

    def _mark_preexisting(self, path: str) -> None:
        with self._lock:
            self._preexisting.add(path)

    def _record_rename(self, src: str, dst: str) -> None:
        with self._lock:
            for p in [p for p in self._created if is_under(p, src)]:
                self._created[dst + p[len(src):]] = self._created.pop(p)
        sp = self.fs._spill()
        if sp is not None:
            sp.record_journal_rename(src, dst)

    # -- lifecycle --
    def __enter__(self) -> "Transaction":
        # no drain barrier here: region tags and the journal both capture
        # the active txn at submission time, so in-flight pre-region ops
        # stay untagged/unjournaled no matter when they finish — and a
        # transaction open must not stall on unrelated background I/O
        with self.fs._txn_lock:
            if self.fs._txn is not None:
                raise RuntimeError("nested transactions are not supported")
            self.fs._txn = self
        self._active = True
        sp = self.fs._spill()
        if sp is not None:
            # open the spill epoch (or, on a resumed mount, seed this
            # region's journal with the interrupted attempt's proven one)
            sp.attach_txn(self)
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.fs._txn = None
        self._active = False
        if exc_type is not None:
            # caller failed mid-transaction → roll back, re-raise.
            # Exception: a ProcessKilled (raised directly, or at the root
            # of this region's deferred errors) means the process is
            # 'gone' — neither roll back nor retry in-process; recovery
            # is a fresh mount's CannyFS.resume() against the spill.
            killed = issubclass(exc_type, ProcessKilled) or any(
                isinstance(en.error, ProcessKilled) for en in self.errors())
            if not killed:
                self.rollback()
            return False
        if not self.committed and not self.rolled_back:
            self.commit()
        return False

    def errors(self):
        return self.fs.ledger.entries_for(self)

    def commit(self) -> None:
        """Drain all deferred I/O; surface any failure as a single
        transaction-level error (this is where the 'canny assumption' is
        finally checked)."""
        self.fs.drain()
        errs = self.errors()
        if errs:
            raise TransactionFailedError(errs)
        sp = self.fs._spill()
        if sp is not None:
            # committed marker + final cut, then the spill log is retired
            sp.on_commit()
        # the optimization window is closed: drop the namespace overlay's
        # delta (its claims are now plain backend truth; the next window
        # rebuilds its own) and retire the window-scoped existence probes.
        # The read-ahead pages stay: commit mutated nothing behind the
        # engine.  Scope-aware: a Tenant clears only under its prefix.
        self.fs._clear_window_caches(rollback=False)
        self.committed = True

    def rollback(self) -> None:
        """Remove every output of the transaction.  Runs synchronously and
        directly against the backend — rollback must not itself be canny.

        Removal is verified against the backend (one retry pass for
        stragglers — e.g. a transient injected fault on the unlink itself);
        anything still present afterwards is reported in
        ``rollback_leftovers`` rather than silently leaked."""
        self.fs.drain()
        self.final_errors = self.errors()
        sp = self.fs._spill()
        if sp is not None:
            # tombstone the epoch BEFORE removing anything: a kill mid-
            # rollback must leave a log that proves "this window is dead",
            # never one whose durable claims point at half-deleted files
            sp.on_rollback()
        with self._lock:
            created = dict(self._created)
            self._created.clear()
        files = sorted((p for p, d in created.items() if not d),
                       key=lambda p: -p.count("/"))
        dirs = sorted((p for p, d in created.items() if d),
                      key=lambda p: -p.count("/"))
        backend = self.fs.backend

        failed: list[str] = []

        def sweep(paths: list[str], remove) -> None:
            for p in paths:
                try:
                    remove(p)
                except OSError:
                    failed.append(p)  # a non-raising remove needs no verify
                self.fs.engine.stat_cache.invalidate(p)

        sweep(files, backend.unlink)
        sweep(dirs, backend.rmdir)
        # verification pass over the failures only: ask the backend what
        # actually survived, retry once, record the rest.  A path that
        # cannot even be stat'ed is *reported*, not assumed gone.
        leftovers: list[str] = []
        for p in sorted(failed, key=lambda q: -q.count("/")):
            try:
                st = backend.stat(p)
            except OSError:
                leftovers.append(p)
                continue
            if not st.exists:
                continue
            try:
                (backend.rmdir if created[p] else backend.unlink)(p)
            except OSError:
                pass
            self.fs.engine.stat_cache.invalidate(p)
            try:
                if backend.stat(p).exists:
                    leftovers.append(p)
            except OSError:
                leftovers.append(p)
        self.rollback_leftovers = leftovers
        # the removed outputs hand their quota charges back to the tenant
        # (no-op on untenanted mounts)
        self.fs._quota_release([p for p in created if p not in leftovers])
        # rollback mutated the backend behind the engine's back (direct
        # unlinks/rmdirs): every overlay claim under this view's scope is
        # now suspect — clear it, and every read-ahead page / batched
        # existence probe with it.  Scope-aware: a Tenant clears the
        # overlay only under its prefix, keeping neighbours' windows open.
        self.fs._clear_window_caches(rollback=True)
        # scoped clear: only this region's errors are handled — entries
        # from earlier work or a concurrently-opened region must survive
        self.fs.ledger.clear_region(self)
        # scope-aware: a Tenant clears only its own poison flag
        self.fs._reset_poison()
        self.fs._note_rollback(len(leftovers))
        self.rolled_back = True


def _entry_signal(err: BaseException) -> bool | None:
    """Transience signal of one ledger entry: None for cancellations (a
    secondary effect of poisoning — says nothing about the root cause)."""
    if isinstance(err, OpCancelledError):
        return None
    if isinstance(err, OSError):
        return err.errno in TRANSIENT_ERRNOS
    return True


def _is_resubmittable(e: BaseException, region_errs=()) -> bool:
    """Would resubmitting the job plausibly clear this failure?

    Decides from root causes: cancelled-op entries are ignored, and a
    poison raised into the body is judged by the region's own recorded
    errors (``region_errs``, snapshotted before rollback cleared them) —
    a deterministic ENOENT that tripped abort_on_error must not buy
    itself a full retry budget via the poison path."""
    if isinstance(e, TransactionFailedError):
        # retry iff any real deferred entry looks transient — deterministic
        # cascades (ENOENT under a faulted mkdir) carry their transient
        # root cause in the same ledger scope
        signals = [s for s in (_entry_signal(en.error) for en in e.entries)
                   if s is not None]
        return any(signals) if signals else True
    if isinstance(e, (EnginePoisonedError, OpCancelledError)):
        signals = [s for s in (_entry_signal(en.error) for en in region_errs)
                   if s is not None]
        return any(signals) if signals else True  # unknown cause: resubmit
    if isinstance(e, OSError):
        return e.errno in TRANSIENT_ERRNOS
    return True  # unknown failure class: keep the paper's resubmit default


def _was_killed(e: BaseException,
                region_errs=()) -> ProcessKilled | None:
    """Did this attempt die of a (simulated) process kill?  Checked before
    rollback: a dead process neither rolls back nor resubmits in-process —
    the failure must propagate so a fresh mount can ``resume()``.  Returns
    the root ``ProcessKilled`` (for uniform re-raising) or ``None``."""
    if isinstance(e, ProcessKilled):
        return e
    entries = (e.entries if isinstance(e, TransactionFailedError)
               else region_errs)
    for en in entries:
        if isinstance(en.error, ProcessKilled):
            return en.error
    return None


def _backoff_sleep(fs: CannyFS, name: str, attempt: int,
                   base_s: float, cap_s: float, seed: int | None) -> None:
    """Seeded full-jitter exponential backoff, charged on the injected
    clock.  The draw is derived per (seed, job-name, attempt) the same way
    ``FaultPlan`` derives its per-match draws — a tuple-of-int hash, stable
    across processes — defaulting to the fault plan's own seed when the
    backend stack carries one, so chaos sweeps and their emitted
    ``BENCH_*.json`` replay byte-identically per seed."""
    if seed is None:
        seed = getattr(getattr(fs.backend, "plan", None), "seed", 0)
    # per-tenant salt (empty on the base mount, so untenanted draws are
    # unchanged): one tenant's attempt count never perturbs a neighbour's
    # jitter stream
    salted = fs._backoff_salt() + name
    rng = random.Random(hash((int(seed), zlib.crc32(salted.encode("utf-8")),
                              attempt)))
    delay = rng.random() * min(cap_s, base_s * (2 ** attempt))
    if delay <= 0:
        return
    clock = fs.engine.sim
    if clock is None:
        clock = getattr(fs.backend, "clock", None)
    if clock is not None and hasattr(clock, "sleep"):
        clock.sleep(delay)
    else:
        time.sleep(delay)


def run_transaction(fs: CannyFS, body: Callable[[CannyFS], T], *,
                    name: str = "job", retries: int = 2,
                    backoff_s: float = 0.0,
                    backoff_cap_s: float = 30.0,
                    backoff_seed: int | None = None,
                    retry_on: tuple[type[BaseException], ...] = (
                        TransactionFailedError, EnginePoisonedError,
                        OpCancelledError, OSError)) -> T:
    """The paper's full model: run body as a transaction; on failure roll
    back (outputs removed) and retry the whole thing.

    ``retry_on`` defaults to every I/O-shaped failure: deferred errors
    surfacing at commit, fail-fast submissions against a poisoned engine,
    and synchronous OSErrors raised straight out of the body (a blocking
    read/readdir that hit an injected or real fault) — but only for
    *transient* errnos (``TRANSIENT_ERRNOS``).  A deterministic body bug —
    FileNotFoundError on a misspelled path, whether raised synchronously or
    deferred into the commit's TransactionFailedError — is rolled back once
    and propagates immediately.  A commit failure is still retried when
    *any* of its entries is transient: cascade errors (ENOENT on ops under
    a faulted mkdir) ride along with their transient root cause.

    ``backoff_s`` arms seeded-jitter exponential backoff between attempts:
    each resubmission sleeps ``U(0, min(backoff_cap_s, backoff_s * 2**k))``
    (full jitter, AWS-style), drawn from a per-(seed, name, attempt) RNG
    — ``backoff_seed``, defaulting to the backend fault plan's seed — and
    charged on the engine's sim clock (or the backend's virtual clock)
    when one is present, so chaos sweeps stay deterministic per seed.

    A ``ProcessKilled`` failure (injected preemption) is exempt from the
    whole loop: no rollback, no resubmission — it propagates so a fresh
    mount can ``CannyFS.resume()`` from the durability spill."""
    last: BaseException | None = None
    leftover_acc: list[str] = []   # verified leakage across all attempts
    for attempt in range(retries + 1):
        txn = Transaction(fs, name=f"{name}#{attempt}")
        try:
            with txn:
                out = body(fs)
            if leftover_acc:
                # an earlier attempt's verified leakage must not vanish
                # behind this attempt's success — route it through the
                # deferred-error channel so teardown reporting surfaces it
                fs.ledger.record(
                    0, "rollback", tuple(leftover_acc),
                    RollbackLeakError(
                        f"{len(leftover_acc)} path(s) survived rollback of "
                        f"failed attempts"))
            return out
        except retry_on as e:
            kill = _was_killed(e, fs.ledger.entries_for(txn))
            if kill is not None:
                # preempted, not failed: resume(), don't resubmit.  Raise
                # the root ProcessKilled so callers see ONE preemption
                # signal whether the kill struck a sync op in the body or
                # surfaced as a deferred entry at commit
                raise kill from e
            if not txn.rolled_back:  # commit failed inside __exit__
                txn.rollback()
            # rollback snapshotted the region's errors before clearing
            # them — the resubmittability decision needs the root causes
            region_errs = txn.final_errors
            for p in txn.rollback_leftovers:
                if p not in leftover_acc:
                    leftover_acc.append(p)
            if leftover_acc:
                # verified on-backend leakage must reach the caller, not
                # die with the per-attempt txn objects (a retry only
                # journals what it created itself, so an earlier attempt's
                # stuck path would otherwise go unreported)
                e.rollback_leftovers = list(leftover_acc)
            if not _is_resubmittable(e, region_errs):
                raise  # deterministic body bug: rolled back, not retried
            last = e
            if attempt < retries:
                fs._note_retry()  # engine-global + per-tenant bookkeeping
                if backoff_s:  # no pointless sleep after the final attempt
                    _backoff_sleep(fs, name, attempt, backoff_s,
                                   backoff_cap_s, backoff_seed)
            continue
    assert last is not None
    raise last
