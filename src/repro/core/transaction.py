"""Job-as-transaction semantics (paper §1, §5).

A Transaction brackets a region of work against a CannyFS mount:

* every path *created* inside the region is journaled;
* ``commit()`` drains the engine and succeeds iff no deferred error was
  recorded during the region — the job's outputs are then durable;
* ``rollback()`` removes everything the region created (files first, then
  directories deepest-first), restoring the pre-transaction namespace;
* ``run_transaction`` is the paper's "roll back and resubmit" loop.
"""
from __future__ import annotations

import posixpath
import threading
import time
from typing import Callable, TypeVar

from .backend import norm_path
from .errors import TransactionFailedError
from .fs import CannyFS

T = TypeVar("T")


class Transaction:
    def __init__(self, fs: CannyFS, name: str = "txn"):
        self.fs = fs
        self.name = name
        self._lock = threading.Lock()
        self._created: dict[str, bool] = {}   # path -> is_dir
        self._ledger_start = 0
        self._active = False
        self.committed = False
        self.rolled_back = False

    # -- journal hooks (called by CannyFS) --
    def _record_create(self, path: str, is_dir: bool) -> None:
        with self._lock:
            self._created[path] = is_dir

    def _record_rename(self, src: str, dst: str) -> None:
        with self._lock:
            prefix = src + "/"
            for p in [p for p in self._created if p == src or p.startswith(prefix)]:
                self._created[dst + p[len(src):]] = self._created.pop(p)

    # -- lifecycle --
    def __enter__(self) -> "Transaction":
        if self.fs._txn is not None:
            raise RuntimeError("nested transactions are not supported")
        self._ledger_start = len(self.fs.ledger)
        self._active = True
        self.fs._txn = self
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.fs._txn = None
        self._active = False
        if exc_type is not None:
            # caller failed mid-transaction → roll back, re-raise
            self.rollback()
            return False
        if not self.committed and not self.rolled_back:
            self.commit()
        return False

    def errors(self):
        return self.fs.ledger.entries()[self._ledger_start:]

    def commit(self) -> None:
        """Drain all deferred I/O; surface any failure as a single
        transaction-level error (this is where the 'canny assumption' is
        finally checked)."""
        self.fs.drain()
        errs = self.errors()
        if errs:
            raise TransactionFailedError(errs)
        self.committed = True

    def rollback(self) -> None:
        """Remove every output of the transaction.  Runs synchronously and
        directly against the backend — rollback must not itself be canny."""
        self.fs.drain()
        with self._lock:
            created = dict(self._created)
            self._created.clear()
        files = sorted((p for p, d in created.items() if not d),
                       key=lambda p: -p.count("/"))
        dirs = sorted((p for p, d in created.items() if d),
                      key=lambda p: -p.count("/"))
        backend = self.fs.backend
        for p in files:
            try:
                backend.unlink(p)
            except OSError:
                pass
            self.fs.engine.stat_cache.invalidate(p)
        for p in dirs:
            try:
                backend.rmdir(p)
            except OSError:
                pass
            self.fs.engine.stat_cache.invalidate(p)
        # the failed region's errors are handled; un-poison so a retry can run
        self.fs.ledger.clear()
        self.fs.engine.reset_poison()
        self.rolled_back = True


def run_transaction(fs: CannyFS, body: Callable[[CannyFS], T], *,
                    name: str = "job", retries: int = 2,
                    backoff_s: float = 0.0) -> T:
    """The paper's full model: run body as a transaction; on failure roll
    back (outputs removed) and retry the whole thing."""
    last: BaseException | None = None
    for attempt in range(retries + 1):
        txn = Transaction(fs, name=f"{name}#{attempt}")
        try:
            with txn:
                out = body(fs)
            return out
        except TransactionFailedError as e:
            last = e
            if not txn.rolled_back:  # commit failed inside __exit__
                txn.rollback()
            if backoff_s:
                time.sleep(backoff_s * (attempt + 1))
            continue
    assert last is not None
    raise last
