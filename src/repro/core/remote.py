"""SFTP/WebDAV-style remote-stream backend.

``RemoteStreamBackend`` is the second production-shaped member of the
backend zoo: POSIX semantics (native rename, real directories, ranged
reads/writes) but **every operation is a high-RTT round-trip** while
**payload streaming is cheap** once a request is in flight — the SFTP
profile, where the protocol chatters per op but the encrypted stream
saturates the link.

The consequences the engine must exploit (and the cost hints advertise):

* metadata round-trips dominate — batching/pipelining wins linearly, so
  the vectored ops (``readdir_plus_vec``, ``stat_vec``, ``write_vec``,
  ``read_vec``, ``remove_tree``) cost ONE round-trip plus a small
  pipelined per-item overhead, exactly the accounting ``walk_guard``'s
  roundtrip bound is written against (``op_count`` counts public calls,
  so a fused batch is one op);
* rename is native and cheap (one round-trip) — the fuser's
  rename-retarget rule must NOT fire here, unlike the object store;
* streaming is cheap — the read-ahead window and fused write batches
  should grow toward the (large) bandwidth-delay product.

State is delegated to an internal ``InMemoryBackend`` oracle; this class
adds deterministic round-trip charging (no randomness) and the
``op_count``/``busy_s`` accounting the guards read.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Optional

from .backend import (Clock, CostHint, InMemoryBackend, StorageBackend,
                      VirtualClock)


@dataclass(frozen=True)
class RemoteStreamModel:
    """Deterministic SFTP-shaped cost parameters.

    * ``rtt_ms``        — per-request round-trip (high: every op pays it).
    * ``per_item_ms``   — marginal cost per extra item pipelined inside a
      vectored call (the stream is already open; each item is one more
      protocol packet, not one more round-trip).
    * ``bandwidth_mb_s``— streaming payload rate (cheap relative to RTT).
    """

    rtt_ms: float = 40.0
    per_item_ms: float = 0.5
    bandwidth_mb_s: float = 110.0

    @property
    def rtt_s(self) -> float:
        return self.rtt_ms / 1e3

    @property
    def per_item_s(self) -> float:
        return self.per_item_ms / 1e3

    @property
    def bytes_per_s(self) -> float:
        return self.bandwidth_mb_s * 1e6


class RemoteStreamBackend(StorageBackend):
    """High-RTT, cheap-streaming POSIX remote (see module docstring)."""

    def __init__(self, inner: Optional[InMemoryBackend] = None,
                 model: Optional[RemoteStreamModel] = None,
                 clock: Optional[Clock] = None):
        self.inner = inner if inner is not None else InMemoryBackend()
        self.model = model or RemoteStreamModel()
        self.clock = clock or VirtualClock()
        self._acct = threading.Lock()
        self.op_count = 0   # round-trips: one per public call, fused or not
        self.busy_s = 0.0

    def _roundtrip(self, nbytes: int = 0, extra_items: int = 0) -> None:
        lat = (self.model.rtt_s + extra_items * self.model.per_item_s
               + (nbytes / self.model.bytes_per_s if nbytes > 0 else 0.0))
        with self._acct:
            self.op_count += 1
            self.busy_s += lat
        self.clock.sleep(lat)

    # -- namespace (native: one round-trip each, rename included) ------

    def mkdir(self, p): self._roundtrip(); self.inner.mkdir(p)
    def rmdir(self, p): self._roundtrip(); self.inner.rmdir(p)
    def create(self, p): self._roundtrip(); self.inner.create(p)
    def unlink(self, p): self._roundtrip(); self.inner.unlink(p)
    def rename(self, s, d): self._roundtrip(); self.inner.rename(s, d)
    def symlink(self, t, p): self._roundtrip(); self.inner.symlink(t, p)
    def link(self, s, d): self._roundtrip(); self.inner.link(s, d)

    def readlink(self, p):
        out = self.inner.readlink(p)
        self._roundtrip(len(out))
        return out

    # -- data ----------------------------------------------------------

    def write_at(self, p, o, data):
        self._roundtrip(len(data))
        return self.inner.write_at(p, o, data)

    def write_vec(self, p, segments):
        # one round-trip for the fused vector; each extra segment is one
        # pipelined packet on the open stream
        self._roundtrip(sum(len(d) for _, d in segments),
                        extra_items=max(0, len(segments) - 1))
        return self.inner.write_vec(p, segments)

    def read_at(self, p, o, size):
        out = self.inner.read_at(p, o, size)
        self._roundtrip(len(out))
        return out

    def read_vec(self, p, spans):
        out = self.inner.read_vec(p, spans)
        self._roundtrip(sum(len(b) for b in out),
                        extra_items=max(0, len(spans) - 1))
        return out

    def truncate(self, p, s): self._roundtrip(); self.inner.truncate(p, s)
    def fallocate(self, p, s): self._roundtrip(); self.inner.fallocate(p, s)
    def fsync(self, p): self._roundtrip(); self.inner.fsync(p)

    # -- metadata ------------------------------------------------------

    def chmod(self, p, m): self._roundtrip(); self.inner.chmod(p, m)
    def chown(self, p, u, g): self._roundtrip(); self.inner.chown(p, u, g)
    def utimens(self, p, a, m): self._roundtrip(); self.inner.utimens(p, a, m)
    def setxattr(self, p, k, v):
        self._roundtrip(len(v)); self.inner.setxattr(p, k, v)
    def removexattr(self, p, k): self._roundtrip(); self.inner.removexattr(p, k)

    def stat(self, p):
        self._roundtrip()
        return self.inner.stat(p)

    def readdir(self, p):
        out = self.inner.readdir(p)
        self._roundtrip(extra_items=max(0, len(out) - 1))
        return out

    def readdir_plus(self, p):
        out = self.inner.readdir_plus(p)
        self._roundtrip(extra_items=max(0, len(out) - 1))
        return out

    def readdir_plus_vec(self, paths):
        # the prefetch pipeline's win on this medium: one round-trip for
        # the whole batch of listings, per-directory packets pipelined
        out = self.inner.readdir_plus_vec(paths)
        items = sum(len(v) for v in out.values()) + len(paths)
        self._roundtrip(extra_items=max(0, items - 1))
        return out

    def stat_vec(self, paths):
        self._roundtrip(extra_items=max(0, len(paths) - 1))
        return self.inner.stat_vec(paths)

    def remove_tree(self, p):
        removed = self.inner.remove_tree(p)
        self._roundtrip(extra_items=max(0, removed - 1))
        return removed

    # -- cost model ----------------------------------------------------

    def cost_hint(self, op: str, nbytes: int = 0) -> Optional[CostHint]:
        m = self.model
        # every class, rename included, is one round-trip: the fuser's
        # cost comparison sees rename ≈ create and never retargets here
        return CostHint(rtt_s=m.rtt_s, bytes_per_s=m.bytes_per_s,
                        per_request_overhead_s=m.per_item_s)

    # -- plumbing ------------------------------------------------------

    def snapshot(self) -> dict:
        return self.inner.snapshot()

    def __getattr__(self, name):
        return getattr(self.inner, name)
