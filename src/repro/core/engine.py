"""The CannyFS eager-I/O engine.

Semantics (paper §2–§3):

* Every operation is routed through per-path FIFO order: two ops touching the
  same path execute in submission order; ops on disjoint paths run
  concurrently on a worker pool.
* *Eager* ops (per-flag) are acknowledged immediately — the caller continues
  while the op waits in the DAG.  Non-eager ops and all data reads block the
  caller until the op (and transitively everything it depends on) has really
  executed — this is the read barrier ("when a read takes place, all writes
  to the same object first have to be flushed").
* Cross-path dependencies that per-path order cannot see (create under a
  pending mkdir, readdir racing child creation, rename spanning two paths)
  are expressed as explicit DAG edges.  This goes slightly beyond the
  paper, which serializes per path only and documents imperfect cross-path
  serialization; edges make the engine safe for the checkpoint/data layers.
* Failures of background ops land in the ErrorLedger (reported immediately +
  at teardown); optional abort_on_error poisons the engine: queued ops are
  cancelled and new submissions fail fast.
* ``max_inflight`` bounds queued ops (paper default 300; benchmark 4000) —
  submission *blocks* at the bound, which is the backpressure/straggler
  story for the training integration.
* Two executor models: ``pool`` (recycled workers — the paper's stated
  future work) and ``thread_per_op`` (the paper's actual implementation,
  kept for faithful overhead comparisons).
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from .backend import StorageBackend, StatResult, norm_path, parent_of
from .errors import (EnginePoisonedError, ErrorLedger, OpCancelledError)
from .flags import EagerFlags

# ops that change the namespace under their parent directory — a readdir /
# rmdir / rename of the parent must wait for *all* of these (siblings do not
# chain with each other, so per-path order alone cannot express this).
STRUCTURAL = {"mkdir", "rmdir", "create", "unlink", "rename", "symlink", "link"}
# ops that must observe a complete namespace under their own path
NEEDS_CHILDREN = {"rmdir", "readdir", "rename"}


class _Op:
    __slots__ = ("seq", "kind", "paths", "fn", "done", "error", "result",
                 "remaining_deps", "dependents", "cancelled", "submitted_at",
                 "started_at", "finished_at", "eager", "region")

    def __init__(self, seq: int, kind: str, paths: tuple[str, ...],
                 fn: Callable[[], Any], eager: bool = True,
                 region: object = None):
        self.seq = seq
        self.kind = kind
        self.paths = paths
        self.fn = fn
        self.eager = eager
        self.region = region  # active Transaction at submission, if any
        self.done = threading.Event()
        self.error: BaseException | None = None
        self.result: Any = None
        self.remaining_deps = 0
        self.dependents: list[_Op] = []
        self.cancelled = False
        self.submitted_at = time.monotonic()
        self.started_at = 0.0
        self.finished_at = 0.0


@dataclass
class EngineStats:
    submitted: int = 0
    eager_acks: int = 0
    sync_ops: int = 0
    executed: int = 0
    cancelled: int = 0
    mocked_stats: int = 0
    prefetched_stats: int = 0
    barrier_waits: int = 0
    max_queue_depth: int = 0
    ack_latency_s: float = 0.0   # total caller-visible latency of eager ops
    exec_latency_s: float = 0.0  # total background execution time
    # -- fault / trace counters (chaos + error-path observability) --------
    deferred_errors: int = 0     # background failures recorded in the ledger
    injected_faults: int = 0     # of those, carried an `.injected` tag
    rollbacks: int = 0           # Transaction.rollback() invocations
    rollback_leftovers: int = 0  # paths a verified rollback failed to remove
    retries: int = 0             # run_transaction resubmissions
    op_counts: dict = field(default_factory=dict)     # kind -> submitted
    error_counts: dict = field(default_factory=dict)  # kind -> deferred errs


class _StatCache:
    """Write-through metadata cache.

    The paper mocks stat with default values; we can do strictly better
    because the engine *knows* every pending mutation — sizes/mtimes are
    tracked as writes are queued, so an eager-mode ``stat`` is answered
    exactly without flushing."""

    def __init__(self):
        self._lock = threading.Lock()
        self._entries: dict[str, StatResult] = {}

    def get(self, path: str) -> Optional[StatResult]:
        with self._lock:
            return self._entries.get(path)

    def put(self, path: str, st: StatResult) -> None:
        with self._lock:
            self._entries[path] = st

    def on_op(self, kind: str, paths: tuple[str, ...], **kw) -> None:
        now = time.time()
        with self._lock:
            if kind == "mkdir":
                self._entries[paths[0]] = StatResult(True, is_dir=True,
                                                     mtime=now, mocked=True)
            elif kind == "create":
                self._entries[paths[0]] = StatResult(True, size=0, mtime=now,
                                                     mocked=True)
            elif kind == "symlink":
                self._entries[paths[0]] = StatResult(True, is_symlink=True,
                                                     mtime=now, mocked=True)
            elif kind in ("unlink", "rmdir"):
                self._entries[paths[0]] = StatResult(False, mocked=True)
            elif kind == "rename":
                src, dst = paths
                ent = self._entries.pop(src, None)
                if ent is not None:
                    self._entries[dst] = ent
                self._entries[src] = StatResult(False, mocked=True)
            elif kind == "write":
                prev = self._entries.get(paths[0])
                end = kw.get("offset", 0) + kw.get("nbytes", 0)
                size = max(end, prev.size if prev and prev.exists else 0)
                self._entries[paths[0]] = StatResult(True, size=size,
                                                     mtime=now, mocked=True)
            elif kind in ("truncate", "fallocate"):
                self._entries[paths[0]] = StatResult(True, size=kw.get("size", 0),
                                                     mtime=now, mocked=True)
            elif kind == "chmod":
                prev = self._entries.get(paths[0])
                if prev and prev.exists:
                    self._entries[paths[0]] = StatResult(
                        True, is_dir=prev.is_dir, is_symlink=prev.is_symlink,
                        size=prev.size, mtime=prev.mtime,
                        mode=kw.get("mode", prev.mode), mocked=True)

    def invalidate(self, path: str) -> None:
        with self._lock:
            self._entries.pop(path, None)

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()


class EagerIOEngine:
    def __init__(self, backend: StorageBackend, *,
                 flags: EagerFlags | None = None,
                 max_inflight: int = 300,
                 workers: int = 32,
                 executor: str = "pool",          # "pool" | "thread_per_op"
                 abort_on_error: bool = False,
                 ledger: ErrorLedger | None = None):
        if executor not in ("pool", "thread_per_op"):
            raise ValueError(f"unknown executor: {executor!r}")
        self.backend = backend
        self.flags = flags or EagerFlags()
        self.max_inflight = int(max_inflight)
        self.abort_on_error = abort_on_error
        # explicit None-check: an empty ErrorLedger is falsy (__len__ == 0),
        # so `ledger or ...` would silently discard a caller-provided ledger
        self.ledger = ledger if ledger is not None else ErrorLedger()
        self.stats = EngineStats()
        self.stat_cache = _StatCache()

        self._lock = threading.Lock()
        self._ready_cv = threading.Condition(self._lock)
        self._idle_cv = threading.Condition(self._lock)
        self._budget_cv = threading.Condition(self._lock)
        self._ready: deque[_Op] = deque()
        self._last_op: dict[str, _Op] = {}        # last pending op per path
        # every pending structural op, grouped by parent dir (seq -> op)
        self._pending_children: dict[str, dict[int, _Op]] = {}
        self._inflight = 0                        # submitted, not finished
        self._seq = 0
        self._poisoned = False
        self._closed = False
        self._executor = executor
        self._threads: list[threading.Thread] = []
        if executor == "pool":
            for i in range(workers):
                t = threading.Thread(target=self._worker_loop,
                                     name=f"cannyfs-w{i}", daemon=True)
                t.start()
                self._threads.append(t)
        else:
            t = threading.Thread(target=self._dispatcher_loop,
                                 name="cannyfs-dispatch", daemon=True)
            t.start()
            self._threads.append(t)

    # ------------------------------------------------------------------
    # submission
    # ------------------------------------------------------------------

    def submit(self, kind: str, paths: tuple[str, ...],
               fn: Callable[[], Any], *, eager: bool,
               cache_kw: dict | None = None,
               region: object = None) -> Any:
        """Route one op through the DAG.  Eager → returns None immediately;
        sync → waits and returns the op's result (re-raising its error)."""
        t0 = time.monotonic()
        paths = tuple(norm_path(p) for p in paths)
        with self._lock:
            if self._poisoned:
                raise EnginePoisonedError(
                    "cannyfs engine poisoned by an earlier deferred error")
            if self._closed:
                raise RuntimeError("engine is closed")
            # budget: block the *caller* — this is the paper's in-flight cap
            while self._inflight >= self.max_inflight:
                self._budget_cv.wait()
            self._seq += 1
            op = _Op(self._seq, kind, paths, fn, eager=eager, region=region)
            deps: list[_Op] = []
            seen: set[int] = set()

            def add_dep(d: Optional[_Op]):
                if d is not None and not d.done.is_set() and id(d) not in seen:
                    seen.add(id(d))
                    deps.append(d)

            for p in paths:
                add_dep(self._last_op.get(p))
                # an op under a directory whose creation/rename is pending
                # must wait for it
                add_dep(self._last_op.get(parent_of(p)))
            if kind in NEEDS_CHILDREN:
                for p in paths:
                    for d in list(self._pending_children.get(p, {}).values()):
                        add_dep(d)
            op.remaining_deps = len(deps)
            for d in deps:
                d.dependents.append(op)
            for p in paths:
                self._last_op[p] = op
            if kind in STRUCTURAL:
                for p in paths:
                    self._pending_children.setdefault(parent_of(p), {})[op.seq] = op
            self._inflight += 1
            self.stats.submitted += 1
            self.stats.op_counts[kind] = self.stats.op_counts.get(kind, 0) + 1
            self.stats.max_queue_depth = max(self.stats.max_queue_depth,
                                             self._inflight)
            # write-through cache updates before the op can possibly run:
            # a fast-failing op's error-path invalidation must win over
            # this ACK-time mocked entry, so order them under the lock
            if cache_kw is not None:
                self.stat_cache.on_op(kind, paths, **cache_kw)
            if op.remaining_deps == 0:
                self._ready.append(op)
                self._ready_cv.notify()
        if eager:
            self.stats.eager_acks += 1
            self.stats.ack_latency_s += time.monotonic() - t0
            return None
        self.stats.sync_ops += 1
        op.done.wait()
        self.stats.ack_latency_s += time.monotonic() - t0
        if op.error is not None:
            raise op.error
        return op.result

    # ------------------------------------------------------------------
    # barriers
    # ------------------------------------------------------------------

    def barrier(self, path: str) -> None:
        """Wait until every op submitted so far on ``path`` has executed."""
        path = norm_path(path)
        with self._lock:
            op = self._last_op.get(path)
        if op is not None:
            self.stats.barrier_waits += 1
            op.done.wait()

    def drain(self) -> None:
        """Global barrier: wait for the whole DAG to execute."""
        with self._idle_cv:
            while self._inflight > 0:
                self._idle_cv.wait()

    # ------------------------------------------------------------------
    # error / lifecycle
    # ------------------------------------------------------------------

    @property
    def poisoned(self) -> bool:
        return self._poisoned

    def reset_poison(self) -> None:
        """Clear the poisoned state after a transaction rollback handled the
        failure (the retry path of run_transaction)."""
        with self._lock:
            self._poisoned = False

    def _poison(self) -> None:
        with self._lock:
            self._poisoned = True
            # cancel everything not yet started; their dependents cascade
            for op in list(self._ready):
                op.cancelled = True

    def close(self) -> None:
        """Orderly teardown: drain, then report the ledger (paper's global
        destructor double-report)."""
        if self._closed:
            return
        self.drain()
        with self._lock:
            self._closed = True
            self._ready_cv.notify_all()
        self.ledger.report()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # ------------------------------------------------------------------
    # execution
    # ------------------------------------------------------------------

    def _worker_loop(self) -> None:
        while True:
            with self._lock:
                while not self._ready and not self._closed:
                    self._ready_cv.wait()
                if self._closed and not self._ready:
                    return
                op = self._ready.popleft()
            self._execute(op)

    def _dispatcher_loop(self) -> None:
        """thread_per_op mode: the paper's 'high number of threads created
        and scrapped' model — one fresh thread per ready op."""
        while True:
            with self._lock:
                while not self._ready and not self._closed:
                    self._ready_cv.wait()
                if self._closed and not self._ready:
                    return
                op = self._ready.popleft()
            t = threading.Thread(target=self._execute, args=(op,), daemon=True)
            t.start()

    def _execute(self, op: _Op) -> None:
        op.started_at = time.monotonic()
        if op.cancelled or (self._poisoned and self.abort_on_error):
            op.error = OpCancelledError(f"{op.kind}{op.paths}")
            op.cancelled = True
            self.stats.cancelled += 1
            # a cancelled eager op was ACKed but never executed — without a
            # ledger entry a transaction commit (region-tagged) or the
            # checkpoint manager's path scan (untagged) would conclude the
            # I/O landed when it was silently dropped
            if op.eager:
                self.ledger.record(op.seq, op.kind, op.paths, op.error,
                                   region=op.region)
        else:
            try:
                op.result = op.fn()
            except BaseException as e:  # noqa: BLE001
                op.error = e
                # the ledger exists for errors the caller never saw (paper:
                # "not properly reported back"); sync ops re-raise directly
                if op.eager:
                    self.ledger.record(op.seq, op.kind, op.paths, e,
                                       region=op.region)
                    if self.abort_on_error:
                        self._poison()
        op.finished_at = time.monotonic()
        self.stats.exec_latency_s += op.finished_at - op.started_at
        self.stats.executed += 1
        if op.error is not None:
            # the write-through cache recorded this op's effect at ACK time;
            # it never materialized (failed or cancelled), so the mocked
            # entry is wrong — drop it and let the backend answer again
            for p in op.paths:
                self.stat_cache.invalidate(p)
        with self._lock:
            if op.error is not None and op.eager and not op.cancelled:
                self.stats.deferred_errors += 1
                self.stats.error_counts[op.kind] = \
                    self.stats.error_counts.get(op.kind, 0) + 1
                if getattr(op.error, "injected", False):
                    self.stats.injected_faults += 1
            for d in op.dependents:
                d.remaining_deps -= 1
                if d.remaining_deps == 0:
                    self._ready.append(d)
                    self._ready_cv.notify()
            for p in op.paths:
                if self._last_op.get(p) is op:
                    del self._last_op[p]
            if op.kind in STRUCTURAL:
                for p in op.paths:
                    kids = self._pending_children.get(parent_of(p))
                    if kids is not None:
                        kids.pop(op.seq, None)
                        if not kids:
                            del self._pending_children[parent_of(p)]
            self._inflight -= 1
            self._budget_cv.notify()
            if self._inflight == 0:
                self._idle_cv.notify_all()
        op.done.set()
