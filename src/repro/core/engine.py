"""The CannyFS eager-I/O engine: scheduler / optimizer / namespace
overlay / prefetcher / executor.

Architecture (one op's life, left to right)::

        submit / try_fuse / prepare_unlink / prepare_rmtree
                        |
        +---------------v-----------------------------------------+
        |  OpScheduler (core/scheduler.py)                        |
        |  per-path FIFO + cross-path DAG edges; submission state |
        |  AND ready queues sharded by path hash; in-flight       |
        |  budget; poison/close; per-shard LOW-PRIORITY lane for  |
        |  speculative ops (submit_speculative: no DAG edges,     |
        |  drained only when the normal lanes are dry)            |
        +---------------+-----------------------------------------+
                        | pending tip / chain, under shard+op locks
        +---------------v-----------------------------------------+
        |  Fuser (core/fusion.py)                                 |
        |  peephole pass over the pending stream:                 |
        |    coalesce write_at -> one vectored write_vec          |
        |      (cap ~2x the backend's per-op-class cost_hint BDP  |
        |       when adaptive, else FusionPolicy.max_bytes)       |
        |    fold chmod/utimens/truncate to last-wins             |
        |    elide create+write chains unlinked in-window         |
        |    retarget renames on copy+delete media: a still-      |
        |      pending source chain replays at the destination    |
        |      (cost-gated via cost_hint("rename") vs "create")   |
        |    collapse cross-path unlink/rmdir -> one remove_tree  |
        |      (provisional dirs fuse too: the op re-verifies the |
        |       overlay claim at exec via a RemoveWitness)        |
        +------+--------+-----------------------------------------+
               |        | per-shard ready deques
        +------v------+ |   +-------------------------------------+
        | Namespace   | +--->  PoolExecutor | ThreadPerOp         |
        | Overlay     |     |  (core/executor.py)                 |
        | (namespace  |     |  worker i of W owns shards s with   |
        |  .py)       |     |  s % W == i, steals from the rest   |
        +------^------+     |  when dry, parks when all empty;    |
          mirrors every     |  completion releases dependents     |
          admitted op as a  +-------------------------------------+
          directory-tree delta; readdir/stat/exists/walk answered
          here never seal a chain; cached listings are LRU-bounded
          (OverlayPolicy.max_cached_listings; eviction demotes
          completeness only, never pending membership)
               |
        +------v---------------------------------------------------+
        |  MetadataPrefetcher (core/prefetch.py)                   |
        |  speculative pipeline for COLD trees: a readdir/walk miss|
        |  seeds a bounded BFS frontier; batched readdir_plus_vec  |
        |  reads (ONE roundtrip per batch, width ~2x BDP) install  |
        |  listings into the overlay at LRU-cold recency without   |
        |  sealing; SpeculationTickets cancel on racing mutations; |
        |  consumers latch onto in-flight batches (demand          |
        |  promotion) instead of duplicating the fetch             |
        +------+---------------------------------------------------+
               |
        +------v---------------------------------------------------+
        |  Read-side data plane (core/readahead.py)                |
        |  ReadAheadManager: a sequential pread registers a        |
        |  ticketed per-file page buffer and pipelines speculative |
        |  read_vec windows (~2x BDP) ahead of the consumer —      |
        |  page hits skip the backend, an outrun consumer latches  |
        |  onto the in-flight window, racing admitted mutations    |
        |  cancel the run.  StatVecBatcher: transactional          |
        |  create/write existence probes fuse into ONE speculative |
        |  stat_vec per batch, consumed single-shot at execution   |
        |  time with a sync-stat fallback                          |
        +------+---------------------------------------------------+
               |
        +------v---------------------------------------------------+
        |  Backend zoo + CostModel (core/backend.py,               |
        |  core/objectstore.py, core/remote.py, core/faults.py)    |
        |  the StorageBackend decorator stack bottoms out at a     |
        |  storage class with its own cost structure: Local /      |
        |  InMemory (no cost opinion), LatencyBackend (measured    |
        |  RTT+bandwidth EWMAs, seeded from the model's nominals), |
        |  ObjectStoreBackend (flat keyspace: paginated            |
        |  list_by_prefix, whole-object PUT, rename=copy+delete,   |
        |  per-request billing) and RemoteStreamBackend (high RTT, |
        |  cheap streaming, native rename).  Every backend answers |
        |  cost_hint(op, nbytes) -> CostHint(rtt_s, bytes_per_s,   |
        |  per_request_overhead_s) | None; fault/quota decorators  |
        |  delegate the question inward, so the fuser, prefetcher, |
        |  read-ahead manager and stat batcher size their batches  |
        |  and arm cost-gated rules from the storage actually at   |
        |  the bottom of the stack                                 |
        +------+---------------------------------------------------+
               |
        +------v---------------------------------------------------+
        |  Durability spill (core/durability.py)                   |
        |  SpillManager taps submit (admit records) and _execute   |
        |  (done/fail records, per-segment write checksums) and    |
        |  appends an epoch-stamped, checksummed record log to the |
        |  backend itself; chunks ride the scheduler's LOW-        |
        |  PRIORITY speculative lane (durability never serializes  |
        |  the hot path) and every barrier/drain CUTs: synchronous |
        |  flush of outstanding chunks + COMMIT-style marker       |
        |  stamp.  After a kill, CannyFS.resume(spill_dir) re-     |
        |  proves the optimization window from the log — journal   |
        |  reinstalled, durable ops elided/diverted on re-run,     |
        |  uncertain in-flight ops repaired against the backend —  |
        |  instead of redoing the whole job from scratch           |
        +------+---------------------------------------------------+
               |
        +------v---------------------------------------------------+
        |  Tenancy (core/tenancy.py, PR 10)                        |
        |  CannyFS.tenant(name, prefix, weight, quota) carves N    |
        |  isolated jobs out of ONE engine.  Every tenant op is    |
        |  confined to its root prefix and tagged with a           |
        |  _TenantState that scopes (1) dispatch: a deficit-       |
        |  weighted-round-robin credit on every ready-lane pop     |
        |  (a burst cannot starve a neighbour's latency) plus a    |
        |  weight-share slice of the in-flight budget — at         |
        |  saturation admission control sheds speculative lanes    |
        |  first, then backpressures only the over-share tenant;   |
        |  (2) the failure domain: tenant-tagged ledger entries,   |
        |  tenant-scoped poison/rollback/retry-backoff, and an     |
        |  optional per-tenant spill journal, so one tenant's      |
        |  fault storm or ProcessKilled preemption leaves the      |
        |  neighbours' optimization windows open and convergent;   |
        |  (3) resources: an optional TenantQuota byte+inode       |
        |  budget enforced at submit.  EngineStats.tenants[name]   |
        |  is the per-tenant observability sub-snapshot            |
        +----------------------------------------------------------+

Semantics (paper §2–§3):

* Every operation is routed through per-path FIFO order; ops on disjoint
  paths run concurrently.  *Eager* ops are acknowledged immediately;
  non-eager ops and all data reads block the caller (the read barrier).
* Reads, barriers and transaction commit are the observation points.
  Between them the pending stream is *rewritable*: the optimizer may
  coalesce, fold and delete ops as long as commit-visible state is
  unchanged.  Observation classification is per-*answer*: a namespace
  read (readdir/stat/exists) whose answer is fully determined by the
  transaction's own writes is served by the **namespace overlay**
  (``core/namespace.py``) and seals nothing; only an overlay miss takes
  the sync path, which *seals* the ops it waits on — freezing them
  against further rewriting — so results are exactly what a synchronous
  execution would have produced at every read.  The overlay is populated
  at submission, invalidated per-path when a background op fails,
  cleared by transaction rollback and dropped at commit.
* Fusion is controlled by ``FusionPolicy`` (``fusion=`` argument: a
  policy, True/None for defaults, False to disable); the overlay by
  ``OverlayPolicy`` (``overlay=`` argument, default derived from the
  legacy mock_stat/readdir_prefetch/negative_stat_cache flags).
  ``EngineStats`` reports ``fused_writes`` (writes absorbed into a
  pending vectored op), ``folded_meta`` (last-wins metadata folds),
  ``elided_ops``/``bytes_elided`` (ops/bytes deleted by elision),
  ``renames_retargeted`` (renames rewritten to build-at-destination on
  copy+delete media),
  ``overlay_readdirs``/``overlay_seals_avoided`` (namespace reads that
  never reached the backend / that left pending chains rewritable),
  ``bulk_removes`` (cross-path removal collapses),
  ``bulk_reverify_promoted``/``bulk_reverify_demoted`` (fused removals
  confirmed / fallen back at execution time), ``steals``/``parks``
  (dispatch-layer load balancing), ``adaptive_max_bytes`` (the latest
  BDP-derived coalescing clamp),
  ``prefetch_{issued,batches,hits,wasted,cancelled}`` (the speculative
  metadata-prefetch pipeline's accounting),
  ``readahead_{windows,hits,latched,bytes,wasted,cancelled}`` and
  ``stat_{batches,probes,probe_hits,probe_fallbacks}`` (the vectored
  read-side data plane, ``core/readahead.py``, controlled by
  ``ReadPolicy`` via the ``readahead=`` argument — same
  policy/True/None/False convention), and
  ``spill_{records,flushes,bytes,cuts}`` /
  ``resume{s,_elided_ops,_replayed_ops,_repairs}`` (the durability
  spill and crash-resume path, ``core/durability.py``, engaged by
  ``CannyFS.enable_spill``/``CannyFS.resume``), ``admission_sheds``
  (speculative ops cancelled to admit real work at budget
  saturation) and ``tenants`` (name -> ``TenantStats`` per-tenant
  sub-snapshots: ops/executed/fused/deferred_errors/credits_spent/
  steals_served/retries/rollbacks/resumes/quota headroom).
* Failures of background ops land in the ErrorLedger; optional
  abort_on_error poisons the engine.  ``max_inflight`` bounds queued ops
  (fused absorptions don't consume new slots — coalescing is also
  backpressure relief, bounded by ``FusionPolicy.max_bytes``).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from .backend import StorageBackend, StatResult, norm_path
from .errors import ErrorLedger, OpCancelledError
from .executor import make_executor
from .flags import EagerFlags
from .fusion import Fuser, FusionPolicy, MetaPayload, WritePayload
from .namespace import NamespaceOverlay, OverlayPolicy
from .prefetch import MetadataPrefetcher, PrefetchPolicy
from .readahead import (INVALIDATING_KINDS, ReadAheadManager, ReadPolicy,
                        StatVecBatcher)
from .scheduler import NEEDS_CHILDREN, STRUCTURAL, OpScheduler, _Op
from .simclock import SimClock


@dataclass
class TenantStats:
    """Per-tenant observability sub-snapshot (``EngineStats.tenants``).

    Counters are bumped under the scheduler locks noted in
    ``core/scheduler.py``'s lock-order docs (credits/steals under a
    ready-queue rlock, the rest under the control lock or the GIL-atomic
    fs layer), so they are exact in sim mode and monotone-approximate
    under real threads — same contract as the global counters."""

    name: str = ""
    weight: float = 1.0
    ops: int = 0                  # ops admitted for this tenant
    executed: int = 0             # ...that completed (incl. cancellations)
    fused: int = 0                # writes/meta absorbed without a new op
    deferred_errors: int = 0      # ledger entries attributed to the tenant
    credits_spent: int = 0        # DWRR dispatch credits consumed
    steals_served: int = 0        # tenant ops dispatched via a work steal
    retries: int = 0              # run_transaction resubmissions (scoped)
    rollbacks: int = 0            # Transaction.rollback() on this tenant
    resumes: int = 0              # CannyFS.resume() on the tenant's spill
    poison_trips: int = 0         # abort_on_error trips scoped to this
    #                               tenant (False->True transitions)
    quota_bytes_used: int = 0     # TenantQuota high-water byte charge
    quota_bytes_budget: int = 0   # 0 = unbudgeted
    quota_inodes_used: int = 0
    last_complete_s: float = 0.0  # sim/monotonic stamp of the latest
    #                               completion — per-tenant makespan probe


@dataclass
class EngineStats:
    submitted: int = 0
    eager_acks: int = 0
    sync_ops: int = 0
    executed: int = 0
    cancelled: int = 0
    mocked_stats: int = 0
    prefetched_stats: int = 0
    barrier_waits: int = 0
    max_queue_depth: int = 0
    ack_latency_s: float = 0.0   # total caller-visible latency of eager ops
    exec_latency_s: float = 0.0  # total background execution time
    # -- fusion / optimizer counters --------------------------------------
    fused_writes: int = 0        # write_at calls absorbed into a pending op
    folded_meta: int = 0         # chmod/utimens/truncate last-wins folds
    elided_ops: int = 0          # pending ops deleted by unlink/bulk elision
    bytes_elided: int = 0        # write payload bytes that never hit storage
    renames_retargeted: int = 0  # renames rewritten to build-at-destination
    #                              (cost-gated: copy+delete media only)
    # -- namespace overlay counters ---------------------------------------
    overlay_readdirs: int = 0    # readdirs answered from the overlay
    overlay_seals_avoided: int = 0  # of those, with pending ops underneath
    bulk_removes: int = 0        # cross-path removals fused to remove_tree
    bulk_reverify_promoted: int = 0  # fused removals whose provisional dirs
    #                                  all proved fresh at execution time
    bulk_reverify_demoted: int = 0   # ...that fell back per-entry instead
    # -- dispatch counters (sharded ready queues + work stealing) ----------
    steals: int = 0              # ops popped from a non-owned shard's deque
    parks: int = 0               # worker waits in the all-shards-empty lot
    # -- speculative metadata prefetch (core/prefetch.py) ------------------
    prefetch_issued: int = 0     # dirs sent in speculative batches
    prefetch_batches: int = 0    # vectored readdir_plus_vec calls submitted
    prefetch_hits: int = 0       # overlay reads served by a speculative
    #                              listing (first consumption per dir)
    prefetch_wasted: int = 0     # fetched but uninstallable (failed batch,
    #                              stale vs a sync miss, evicted at insert)
    prefetch_cancelled: int = 0  # invalidated by racing mutations/teardown
    # -- vectored read-side data plane (core/readahead.py) -----------------
    readahead_windows: int = 0   # speculative read_vec windows submitted
    readahead_hits: int = 0      # preads served from installed pages
    readahead_latched: int = 0   # consumers that waited on an in-flight window
    readahead_bytes: int = 0     # bytes landed into page buffers
    readahead_wasted: int = 0    # windows fetched but uninstallable
    readahead_cancelled: int = 0  # page runs dropped by racing mutations
    stat_batches: int = 0        # speculative stat_vec batches submitted
    stat_probes: int = 0         # write-path existence probes enqueued
    stat_probe_hits: int = 0     # probes consumed with a landed answer
    stat_probe_fallbacks: int = 0  # probes that fell back to a sync stat
    # -- adaptive fusion sizing --------------------------------------------
    adaptive_max_bytes: int = 0  # latest BDP-derived write-coalescing clamp
    # -- durability spill / crash-resume (core/durability.py) -------------
    spill_records: int = 0       # admit/done/fail/journal records appended
    spill_flushes: int = 0       # record chunks landed on the backend
    spill_bytes: int = 0         # journal bytes written
    spill_cuts: int = 0          # barrier/commit cuts that stamped the marker
    resumes: int = 0             # CannyFS.resume() invocations
    resume_elided_ops: int = 0   # re-run ops skipped as provably durable
    resume_replayed_ops: int = 0  # done records replayed into the caches
    resume_repairs: int = 0      # uncertain in-flight ops repaired on resume
    # -- fault / trace counters (chaos + error-path observability) --------
    deferred_errors: int = 0     # background failures recorded in the ledger
    injected_faults: int = 0     # of those, carried an `.injected` tag
    rollbacks: int = 0           # Transaction.rollback() invocations
    rollback_leftovers: int = 0  # paths a verified rollback failed to remove
    retries: int = 0             # run_transaction resubmissions
    # -- multi-tenancy (core/tenancy.py) ----------------------------------
    admission_sheds: int = 0     # speculative ops shed at budget saturation
    tenants: dict = field(default_factory=dict)  # name -> TenantStats
    op_counts: dict = field(default_factory=dict)     # kind -> submitted
    error_counts: dict = field(default_factory=dict)  # kind -> deferred errs


class _StatCache:
    """Write-through metadata cache.

    The paper mocks stat with default values; we can do strictly better
    because the engine *knows* every pending mutation — sizes/mtimes are
    tracked as writes are queued, so an eager-mode ``stat`` is answered
    exactly without flushing."""

    def __init__(self):
        self._lock = threading.Lock()
        self._entries: dict[str, StatResult] = {}

    def get(self, path: str) -> Optional[StatResult]:
        with self._lock:
            return self._entries.get(path)

    def put(self, path: str, st: StatResult) -> None:
        with self._lock:
            self._entries[path] = st

    def on_op(self, kind: str, paths: tuple[str, ...], **kw) -> None:
        now = time.time()
        with self._lock:
            if kind == "mkdir":
                self._entries[paths[0]] = StatResult(True, is_dir=True,
                                                     mtime=now, mocked=True)
            elif kind == "create":
                self._entries[paths[0]] = StatResult(True, size=0, mtime=now,
                                                     mocked=True)
            elif kind == "symlink":
                self._entries[paths[0]] = StatResult(True, is_symlink=True,
                                                     mtime=now, mocked=True)
            elif kind in ("unlink", "rmdir"):
                self._entries[paths[0]] = StatResult(False, mocked=True)
            elif kind == "rename":
                src, dst = paths
                ent = self._entries.pop(src, None)
                if ent is not None:
                    self._entries[dst] = ent
                self._entries[src] = StatResult(False, mocked=True)
            elif kind == "write":
                prev = self._entries.get(paths[0])
                end = kw.get("offset", 0) + kw.get("nbytes", 0)
                size = max(end, prev.size if prev and prev.exists else 0)
                self._entries[paths[0]] = StatResult(True, size=size,
                                                     mtime=now, mocked=True)
            elif kind in ("truncate", "fallocate"):
                self._entries[paths[0]] = StatResult(True, size=kw.get("size", 0),
                                                     mtime=now, mocked=True)
            elif kind == "chmod":
                prev = self._entries.get(paths[0])
                if prev and prev.exists:
                    self._entries[paths[0]] = StatResult(
                        True, is_dir=prev.is_dir, is_symlink=prev.is_symlink,
                        size=prev.size, mtime=prev.mtime,
                        mode=kw.get("mode", prev.mode), mocked=True)
            elif kind == "remove_tree":
                # one fused removal covers every listed path
                for p in paths:
                    self._entries[p] = StatResult(False, mocked=True)

    def invalidate(self, path: str) -> None:
        with self._lock:
            self._entries.pop(path, None)

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()


class EagerIOEngine:
    def __init__(self, backend: StorageBackend, *,
                 flags: EagerFlags | None = None,
                 max_inflight: int = 300,
                 workers: int = 32,
                 executor: str = "pool",          # "pool" | "thread_per_op"
                 abort_on_error: bool = False,
                 ledger: ErrorLedger | None = None,
                 fusion: FusionPolicy | bool | None = None,
                 overlay: OverlayPolicy | bool | None = None,
                 prefetch: PrefetchPolicy | bool | None = None,
                 readahead: "ReadPolicy | bool | None" = None,
                 work_stealing: bool = True,
                 clock=None):
        self.backend = backend
        self.flags = flags or EagerFlags()
        self.max_inflight = int(max_inflight)
        self.abort_on_error = abort_on_error
        # explicit None-check: an empty ErrorLedger is falsy (__len__ == 0),
        # so `ledger or ...` would silently discard a caller-provided ledger
        self.ledger = ledger if ledger is not None else ErrorLedger()
        self.stats = EngineStats()
        self.stat_cache = _StatCache()
        # the durability spill manager (core/durability.py), installed by
        # CannyFS.enable_spill/resume; duck-typed so the engine layer does
        # not import the durability module
        self.spill = None
        # registered tenants (core/tenancy.py): name -> scheduler-side
        # _TenantState.  Empty for single-job engines — every tenancy
        # branch gates on registration so legacy schedules stay identical.
        self._tenant_states: dict = {}
        if fusion is None or fusion is True:
            self.fusion = FusionPolicy()
        elif fusion is False:
            self.fusion = FusionPolicy.off()
        else:
            self.fusion = fusion
        # the write-back namespace overlay; None when disabled (then all
        # namespace reads hit the backend, as before PR 3)
        if overlay is None:
            ov_policy = OverlayPolicy.from_flags(self.flags)
        elif overlay is True:
            ov_policy = OverlayPolicy()
        elif overlay is False:
            ov_policy = OverlayPolicy.off()
        else:
            ov_policy = overlay
        self.overlay: NamespaceOverlay | None = (
            NamespaceOverlay(ov_policy) if ov_policy.enabled else None)
        # discrete-event mode (core/simclock.py): engaged by an explicit
        # ``clock=SimClock(...)`` or by discovering one on the backend's
        # decorator stack (LatencyBackend exposes ``.clock``; the fault /
        # quota decorators delegate unknown attrs inward).  The driver
        # (this constructing thread) and every pool worker become actors
        # of the simulation; all blocking waits below are bracketed so
        # the event queue can advance virtual time past them.
        clk = clock if clock is not None else getattr(backend, "clock", None)
        self.sim: SimClock | None = clk if isinstance(clk, SimClock) else None
        if self.sim is not None and executor != "pool":
            raise ValueError(
                "SimClock requires the pool executor: thread_per_op spawns "
                "an unbounded, timing-dependent thread set the event queue "
                "cannot schedule deterministically")
        self._sched = OpScheduler(self.stats, max_inflight=self.max_inflight,
                                  work_stealing=work_stealing, sim=self.sim)
        # adaptive fusion sizing: the backend's CostModel protocol
        # (``cost_hint`` — per-op-class RTT/bandwidth/overhead, decorators
        # delegate it inward) is the preferred signal; the older scalar
        # ``bdp_bytes`` probe is kept as the fallback for latency-only
        # stacks.  Without either the fixed FusionPolicy bounds stand.
        bdp = getattr(backend, "bdp_bytes", None)
        cost = getattr(backend, "cost_hint", None)
        self._fuser = Fuser(self.fusion, self.stats,
                            bdp_source=bdp if callable(bdp) else None,
                            cost_source=cost if callable(cost) else None)
        # the speculative metadata prefetcher pipelines cold-tree walks
        # through batched readdir_plus_vec reads; it rides the overlay's
        # speculation tickets, so it exists only when the overlay does
        if prefetch is None or prefetch is True:
            pf_policy = PrefetchPolicy()
        elif prefetch is False:
            pf_policy = PrefetchPolicy.off()
        else:
            pf_policy = prefetch
        self.prefetch_policy = pf_policy
        self.prefetcher: MetadataPrefetcher | None = (
            MetadataPrefetcher(self, pf_policy)
            if pf_policy.enabled and self.overlay is not None else None)
        # the vectored read-side data plane (core/readahead.py): BDP-sized
        # speculative read-ahead for sequential consumers plus stat_vec
        # batching for the write path's journaling existence probes
        if readahead is None or readahead is True:
            ra_policy = ReadPolicy()
        elif readahead is False:
            ra_policy = ReadPolicy.off()
        else:
            ra_policy = readahead
        self.read_policy = ra_policy
        # admissions-in-flight guard: on_admit (the cancellation hook) runs
        # BEFORE the scheduler publishes the op to the per-path maps, so a
        # speculation registering in that window would see a quiescent path
        # whose cancellation hook has already fired.  Registration declines
        # while any invalidating admission is mid-flight (see
        # _admitting_invalidators / readahead.py's registration checks).
        self._adm_lock = threading.Lock()
        self._admitting = 0
        self.readahead: ReadAheadManager | None = (
            ReadAheadManager(self, ra_policy) if ra_policy.enabled else None)
        self.stat_batcher: StatVecBatcher | None = (
            StatVecBatcher(self, ra_policy)
            if ra_policy.enabled and ra_policy.stat_batching else None)
        self._closed = False
        self._executor = executor
        self._sim_driver_ident = 0
        if self.sim is not None:
            # the driver attaches FIRST (token holder from the start), then
            # the pool spawns and every worker registers before any op is
            # submitted — the actor set is identical at every driver yield
            # point, run to run, which is what makes the schedule a pure
            # function of the op stream and the latency model's seed
            self.sim.attach()
            self._sim_driver_ident = threading.get_ident()
        self._exec = make_executor(executor, self._sched, self._execute,
                                   workers, sim=self.sim)
        if self.sim is not None:
            self.sim.wait_attached(self._exec.nworkers + 1)

    # ------------------------------------------------------------------
    # tenancy
    # ------------------------------------------------------------------

    def register_tenant(self, name: str, weight: float = 1.0):
        """Register one tenant: creates the ``EngineStats.tenants[name]``
        sub-snapshot and the scheduler-side DWRR/budget/poison state.
        Returns the scheduler state handle — opaque to callers;
        ``CannyFS.tenant`` threads it through every submit."""
        tstats = TenantStats(name=name, weight=float(weight))
        ts = self._sched.register_tenant(name, weight, tstats)
        self.stats.tenants[name] = tstats
        self._tenant_states[name] = ts
        return ts

    def _spill_for(self, tenant):
        """The spill journal an op records to: a tenant's own journal (or
        none — tenants never write into the shared engine journal, that
        would re-entangle the failure domains), else the engine's."""
        if tenant is not None:
            return tenant.spill
        return self.spill

    # ------------------------------------------------------------------
    # submission
    # ------------------------------------------------------------------

    def submit(self, kind: str, paths: tuple[str, ...],
               fn: Callable[[], Any], *, eager: bool,
               cache_kw: dict | None = None,
               region: object = None,
               payload: object = None,
               tenant=None) -> Any:
        """Route one op through the DAG.  Eager → returns None immediately;
        sync → waits and returns the op's result (re-raising its error).
        ``tenant`` (a registered ``_TenantState``) scopes the op's poison
        gate, budget slice, DWRR credit, ledger tag and spill journal."""
        t0 = time.monotonic()
        paths = tuple(norm_path(p) for p in paths)
        sp = self._spill_for(tenant)
        if sp is not None:
            # admit-before-schedule: a kill can now strike with the op
            # recorded but unsettled, which resume treats as uncertain
            # and repairs by probing — never the reverse (landed but
            # unrecorded would be invisible)
            sp.record_admit(kind, paths)
        # write-through cache + namespace-overlay updates ride on_admit —
        # after the budget admits the op but before the DAG publishes it,
        # so a fast-failing op's error-path invalidation (at completion,
        # strictly later) always wins over the ACK-time mocked entry
        ra, sb = self.readahead, self.stat_batcher
        if cache_kw is None and ra is None and sb is None:
            on_admit = None
        else:
            def on_admit():
                if cache_kw is not None:
                    self.stat_cache.on_op(kind, paths, **cache_kw)
                    if self.overlay is not None:
                        self.overlay.on_op(kind, paths, **cache_kw)
                # the data plane's speculation is admission-cancelled too:
                # pages/probes must die before the mutating op can execute
                if ra is not None:
                    ra.on_op(kind, paths)
                if sb is not None:
                    sb.on_op(kind, paths)
        guard = ((ra is not None or sb is not None)
                 and kind in INVALIDATING_KINDS)
        if guard:
            with self._adm_lock:
                self._admitting += 1
        try:
            op = self._sched.submit(kind, paths, fn, eager=eager,
                                    region=region, payload=payload,
                                    tenant=tenant, on_admit=on_admit)
        finally:
            if guard:
                with self._adm_lock:
                    self._admitting -= 1
        if eager:
            self.stats.eager_acks += 1
            self.stats.ack_latency_s += time.monotonic() - t0
            return None
        self.stats.sync_ops += 1
        if self.sim is not None:
            self.sim.wait_event(op.done)
        else:
            op.done.wait()
        self.stats.ack_latency_s += time.monotonic() - t0
        if op.error is not None:
            raise op.error
        return op.result

    # ------------------------------------------------------------------
    # optimizer entry points (called by the fs layer before submitting)
    # ------------------------------------------------------------------

    def try_fuse_write(self, path: str, offset: int, data: bytes, *,
                       region: object = None,
                       cache_kw: dict | None = None) -> bool:
        """Absorb one write into the path's pending vectored write op.
        True → the write is ACKed (no new op); caller must not submit."""
        if self._sched.poisoned:
            return False   # fall through to submit's fail-fast raise
        path = norm_path(path)
        on_absorb = (None if cache_kw is None else
                     lambda: self.stat_cache.on_op("write", (path,),
                                                   **cache_kw))
        return self._fuser.absorb_write(self._sched, path, offset, data,
                                        region, on_absorb)

    def try_fuse_meta(self, kind: str, path: str, args: tuple, *,
                      region: object = None,
                      cache_kw: dict | None = None) -> bool:
        """Fold a chmod/utimens/truncate into the path's pending same-kind
        op (last-wins).  True → folded; caller must not submit."""
        if self._sched.poisoned:
            return False   # fall through to submit's fail-fast raise
        path = norm_path(path)
        on_absorb = (None if cache_kw is None else
                     lambda: self.stat_cache.on_op(kind, (path,),
                                                   **cache_kw))
        return self._fuser.absorb_meta(self._sched, kind, path, args, region,
                                       on_absorb)

    def prepare_unlink(self, path: str, *, region: object = None) -> bool:
        """Elide the path's pending create/write/metadata chain ahead of an
        unlink.  Returns True iff anything was elided — the unlink must
        then tolerate the file's absence (its creating ops are gone)."""
        if self._sched.poisoned:
            return False   # the unlink submit will fail fast instead
        return self._fuser.elide_for_unlink(self._sched, norm_path(path),
                                            region)

    def prepare_rmtree(self, path: str, *, region: object = None):
        """Cross-path bulk-remove peephole: collapse the pending removals
        under ``path`` into one vectored ``remove_tree`` call.  Returns
        the fused op's ``BulkRemovePayload`` (covered co-paths: dependency
        edges and error-invalidation scope; per-entry fallback manifest;
        re-verification witness) when the overlay proves — or, with
        ``FusionPolicy.reverify_provisional``, provisionally claims — the
        subtree, or None when the caller must submit a plain rmdir."""
        if self._sched.poisoned or self.overlay is None:
            return None
        return self._fuser.prepare_bulk_remove(self._sched, self.overlay,
                                               norm_path(path), region)

    def rename_retarget_wanted(self) -> bool:
        """Is the cost-gated rename-retarget rule armed for this backend?
        (``FusionPolicy.retarget_renames``: "auto" consults the cost
        model — fires only on copy+delete media like the object store.)"""
        return (not self._sched.poisoned
                and self._fuser.rename_retarget_wanted())

    def prepare_rename_retarget(self, src: str, *,
                                region: object = None) -> list | None:
        """Capture the source's entire pending chain (all-or-nothing, must
        bottom at its pending ``create``) so the fs layer can replay the
        payloads at the destination instead of paying the backend's
        copy+delete rename.  Returns the captured ops oldest-first (already
        marked elided), or None when the chain is not fully capturable and
        the plain backend rename must run."""
        if self._sched.poisoned:
            return None
        return self._fuser.capture_for_rename(self._sched, norm_path(src),
                                              region)

    def run_bulk_remove(self, payload) -> int:
        """Execute one fused removal (called from the fused op's fn on a
        worker thread).  The op's DAG edges ordered it after every mkdir
        it depends on, so the witness verdict is final here: promoted (or
        no witness — the tree was backend-proven at fuse time) runs the
        single vectored ``remove_tree``; demoted falls back to per-entry
        removals, byte-identical to the unfused execution — children
        before parents, absence-tolerant (elided creates mean an entry may
        never have existed), with the final rmdir of the root left to
        fail ENOTEMPTY exactly as the plain rmdir would have when the
        demoted directory turns out to hold pre-existing entries."""
        ov = self.overlay
        w = payload.witness
        verdict = ("clean" if w is None or ov is None
                   else ov.resolve_witness(w))
        if verdict != "demoted":
            if verdict == "promoted":
                with self._sched._ctl:
                    self.stats.bulk_reverify_promoted += 1
            return self.backend.remove_tree(payload.root)
        with self._sched._ctl:
            self.stats.bulk_reverify_demoted += 1
        b = self.backend
        removed = 0
        for p, is_dir in payload.fallback_order():
            try:
                (b.rmdir if is_dir else b.unlink)(p)
                removed += 1
            except OSError:
                # per-entry failures are independent, as unfused execution's
                # would have been: a surviving entry (ENOTEMPTY on a demoted
                # subdir, EACCES, ...) keeps the root non-empty, so the
                # final rmdir below reports the failure for the whole op —
                # aborting here would strand siblings the unfused rmdirs
                # would still have removed
                pass
        try:
            b.rmdir(payload.root)
            removed += 1
        except FileNotFoundError:
            pass
        return removed

    # ------------------------------------------------------------------
    # barriers
    # ------------------------------------------------------------------

    def barrier(self, path: str, tenant=None) -> None:
        """Wait until every op submitted so far on ``path`` has executed.
        An observation point: the waited-on op is sealed against fusion."""
        op = self._sched.seal_path(norm_path(path))
        if op is not None:
            self.stats.barrier_waits += 1
            if self.sim is not None:
                self.sim.wait_event(op.done)
            else:
                op.done.wait()
        sp = self._spill_for(tenant)
        if sp is not None:
            # observation seal = durability cut: what the caller can now
            # see is also what a resume can now prove
            sp.cut()

    def drain(self) -> None:
        """Global barrier: wait for the whole DAG to execute.  The
        speculative prefetcher is quiesced first (frontier dropped,
        in-flight batches allowed to land) so the barrier doesn't chase a
        self-refilling pipeline, and resumed after."""
        pf = self.prefetcher
        if pf is not None:
            pf.quiesce()
        try:
            self._sched.drain()
        finally:
            if pf is not None:
                pf.resume()
        if self.spill is not None:
            self.spill.cut()
        # a global barrier seals every tenant's observation window too
        for ts in self._tenant_states.values():
            if ts.spill is not None:
                ts.spill.cut()

    # ------------------------------------------------------------------
    # error / lifecycle
    # ------------------------------------------------------------------

    @property
    def poisoned(self) -> bool:
        return self._sched.poisoned

    def reset_poison(self, tenant=None) -> None:
        """Clear the poisoned state after a transaction rollback handled the
        failure (the retry path of run_transaction).  With ``tenant``,
        clears only that tenant's flag — the global flag and every other
        tenant's are untouched."""
        self._sched.reset_poison(tenant)

    def close(self) -> None:
        """Orderly teardown: drain, then report the ledger (paper's global
        destructor double-report)."""
        if self._closed:
            return
        self.drain()
        self._closed = True
        self._sched.close()
        self.ledger.report()
        if self.sim is not None:
            # quiesce the simulation before anyone reads the clock: every
            # worker's exit path (final wakeup charge, detach) lands on the
            # virtual timeline *before* close returns, so makespan reads
            # are stable and run-to-run identical.  Only the attaching
            # driver detaches itself; a close from another thread joins
            # without touching the actor registry.
            if threading.get_ident() == self._sim_driver_ident:
                self.sim.block_begin()
                self._exec.join()
                self.sim.block_end()
                self.sim.detach()
            else:
                self._exec.join()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- introspection (chaos tests assert the engine ends quiescent) ----

    @property
    def _inflight(self) -> int:
        return self._sched.inflight

    @property
    def _last_op(self) -> dict:
        return self._sched.merged_last_op()

    @property
    def _pending_children(self) -> dict:
        return self._sched.merged_pending_children()

    # ------------------------------------------------------------------
    # execution (called from executor worker threads)
    # ------------------------------------------------------------------

    def _execute(self, op: _Op) -> None:
        op.started_at = time.monotonic()
        with op.flock:
            # claiming freezes the op: the optimizer can no longer absorb
            # new work into its payload or elide it from the stream
            op.claimed = True
            elided = op.elided
        tname = op.tenant.name if op.tenant is not None else None
        if op.cancelled or (self._sched.poisoned and self.abort_on_error) \
                or (op.tenant is not None and op.tenant.poisoned
                    and self.abort_on_error):
            op.error = OpCancelledError(f"{op.kind}{op.paths}")
            op.cancelled = True
            # a cancelled eager op was ACKed but never executed — without a
            # ledger entry a transaction commit (region-tagged) or the
            # checkpoint manager's path scan (untagged) would conclude the
            # I/O landed when it was silently dropped.  Speculative ops
            # were never ACKed to anyone — dropping them is their contract
            if op.eager and not op.speculative:
                self.ledger.record(op.seq, op.kind, op.paths, op.error,
                                   region=op.region, tenant=tname)
        elif elided:
            pass  # proven invisible at every observation point: no backend
        else:
            try:
                op.result = op.fn()
            except BaseException as e:  # noqa: BLE001
                op.error = e
                # the ledger exists for errors the caller never saw (paper:
                # "not properly reported back"); sync ops re-raise directly.
                # Speculative ops are advisory — their faults never reach
                # the ledger and must not poison (a ProcessKilled escaping
                # an advisory batch fn would otherwise nuke every tenant)
                if op.eager and not op.speculative:
                    self.ledger.record(op.seq, op.kind, op.paths, e,
                                       region=op.region, tenant=tname)
                    if self.abort_on_error:
                        # blast radius: a tenant op's failure poisons only
                        # its own tenant — neighbours' windows stay open
                        self._sched.poison(op.tenant)
        op.finished_at = time.monotonic()
        sp = self._spill_for(op.tenant)
        if sp is not None and not op.speculative:
            # outcome settles here, before the error-path invalidation and
            # outside every scheduler lock (recording may chunk-flush via
            # the speculative lane, which takes the scheduler control lock)
            if op.error is None and not op.cancelled:
                sp.record_done(op, elided)
            else:
                sp.record_fail(op)
        if op.error is not None and not op.speculative:
            # the write-through cache and the namespace overlay recorded
            # this op's effect at ACK time; it never materialized (failed
            # or cancelled), so every claim is wrong — drop them and let
            # the backend answer again.  (A speculative op claimed
            # nothing at admission: nothing to invalidate.)  Overlay
            # FIRST: its invalidate cancels speculation tickets under its
            # own lock — where speculative installs also warm the stat
            # cache — so by the time the cache is cleared below, no late
            # warming write can race back in behind the invalidation.
            for p in op.paths:
                if self.overlay is not None:
                    self.overlay.invalidate(p)
                self.stat_cache.invalidate(p)
                if self.readahead is not None:
                    self.readahead.invalidate(p)
                if self.stat_batcher is not None:
                    self.stat_batcher.invalidate(p)
        if self.overlay is not None:
            # a fused removal's re-verification witness is spent once the
            # op is done (ran, fell back, was elided into a parent, failed
            # or was cancelled) — unhook it from the overlay's watchers
            self.overlay.release_witness(getattr(op.payload, "witness",
                                                 None))
        if op.cancelled and op.payload is not None:
            # a speculative batch cancelled before it ran still holds its
            # overlay tickets and an in-flight-window slot — release them
            cb = getattr(op.payload, "on_cancelled", None)
            if cb is not None:
                cb()
        with self._sched._ctl:   # exact counters (see scheduler lock note)
            self.stats.exec_latency_s += op.finished_at - op.started_at
            self.stats.executed += 1
            if op.cancelled:
                self.stats.cancelled += 1
            elif op.error is not None and op.eager:
                self.stats.deferred_errors += 1
                self.stats.error_counts[op.kind] = \
                    self.stats.error_counts.get(op.kind, 0) + 1
                if getattr(op.error, "injected", False):
                    self.stats.injected_faults += 1
            if op.tenant is not None:
                tst = op.tenant.stats
                tst.executed += 1
                # per-tenant makespan probe: last completion on the shared
                # timeline (virtual seconds in sim mode)
                tst.last_complete_s = (self.sim.now()
                                       if self.sim is not None
                                       else time.monotonic())
                if not op.cancelled and op.error is not None and op.eager:
                    tst.deferred_errors += 1
        self._sched.on_complete(op)


__all__ = ["EagerIOEngine", "EngineStats", "TenantStats", "FusionPolicy",
           "MetaPayload", "NamespaceOverlay", "OverlayPolicy", "ReadPolicy",
           "WritePayload", "NEEDS_CHILDREN", "STRUCTURAL"]
