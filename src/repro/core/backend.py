"""Storage backends.

The engine interposes at the API level (the in-container analogue of the
paper's FUSE layer) and talks to a pluggable ``StorageBackend``:

* ``LocalBackend``   — a rooted local directory (the "fast" medium).
* ``InMemoryBackend``— dict-based filesystem; the property-test oracle.
* ``LatencyBackend`` — decorator injecting per-op latency + a bandwidth cap
  + bounded server concurrency, calibrated to the paper's NFS-over-GbE
  environment.  This is what the paper benchmarks run against.
"""
from __future__ import annotations

import heapq
import io
import os
import posixpath
import random
import threading
import time
from dataclasses import dataclass, field
from typing import Iterable, Optional


def norm_path(path: str) -> str:
    """Normalize to a rooted-relative posix path ('' is the root)."""
    p = posixpath.normpath("/" + str(path).replace("\\", "/")).lstrip("/")
    return "" if p == "." else p


def parent_of(path: str) -> str:
    p = norm_path(path)
    if not p:
        return ""
    head = posixpath.dirname(p)
    return head


def is_under(path: str, root: str) -> bool:
    """True iff ``path`` is ``root`` or lies inside its subtree (both
    already normalized)."""
    return path == root or path.startswith(root + "/")


@dataclass(frozen=True)
class StatResult:
    exists: bool
    is_dir: bool = False
    is_symlink: bool = False
    size: int = 0
    mtime: float = 0.0
    mode: int = 0o644
    mocked: bool = False  # answered from the write-through cache


@dataclass(frozen=True)
class CostHint:
    """Per-op-class cost estimate a backend advertises to the optimizer.

    The CostModel protocol: ``backend.cost_hint(op, nbytes=0)`` returns a
    ``CostHint`` (or ``None`` when the backend has no opinion — local and
    in-memory storage — in which case callers fall back to their fixed
    policy bounds).  Decorator backends (latency, faults, quota) delegate
    the question inward so the hint always reflects the storage actually
    at the bottom of the stack.

    * ``rtt_s``                 — expected round-trip time for one request
      of this op class, excluding payload transfer.
    * ``bytes_per_s``           — achievable streaming rate for payload
      bytes once the request is in flight.
    * ``per_request_overhead_s``— fixed extra cost charged per wire
      request beyond the first (pipelined continuation pages, per-key
      sub-requests of a composite op such as rename-as-copy+delete).

    ``cost_s(nbytes)`` collapses the triple to one number so callers can
    *compare* op classes (is a rename materially more expensive than a
    create?) without caring which term dominates.
    """

    rtt_s: float
    bytes_per_s: float
    per_request_overhead_s: float = 0.0

    def cost_s(self, nbytes: int = 0) -> float:
        c = self.rtt_s + self.per_request_overhead_s
        if nbytes > 0 and self.bytes_per_s > 0:
            c += nbytes / self.bytes_per_s
        return c

    def bdp_bytes(self) -> float:
        """Bandwidth-delay product implied by this hint: the payload size
        past which streaming, not latency, dominates one request."""
        return (self.rtt_s + self.per_request_overhead_s) * self.bytes_per_s


class StorageBackend:
    """Synchronous primitive I/O operations (one per eagerness flag)."""

    # --- namespace ---
    def mkdir(self, path: str) -> None: raise NotImplementedError
    def rmdir(self, path: str) -> None: raise NotImplementedError
    def create(self, path: str) -> None: raise NotImplementedError
    def unlink(self, path: str) -> None: raise NotImplementedError
    def rename(self, src: str, dst: str) -> None: raise NotImplementedError
    def symlink(self, target: str, path: str) -> None: raise NotImplementedError
    def link(self, src: str, dst: str) -> None: raise NotImplementedError
    def readlink(self, path: str) -> str: raise NotImplementedError
    # --- data ---
    def write_at(self, path: str, offset: int, data: bytes) -> int: raise NotImplementedError

    def write_vec(self, path: str, segments: list[tuple[int, bytes]]) -> int:
        """Vectored write: apply (offset, data) segments in order; returns
        total bytes written.  The default is a loop over ``write_at`` so
        every backend (and every test double overriding ``write_at``)
        composes; a short segment write stops the vector and returns the
        partial total — callers treat that as a torn op.  Decorator
        backends override this to pay their cost once per *fused* call."""
        total = 0
        for off, data in segments:
            n = self.write_at(path, off, data)
            total += n
            if n < len(data):
                break
        return total

    def remove_tree(self, path: str) -> int:
        """Vectored subtree removal: delete everything at/under ``path``
        and return the number of entries removed.  Absence-tolerant by
        contract (``rm -rf`` semantics): a missing root or entries that
        vanished (e.g. their creating ops were elided) are not errors —
        the cross-path bulk-remove pass relies on this.  The default is a
        walk over the primitive ops so every backend (and every test
        double overriding ``unlink``/``rmdir``) composes; decorator
        backends override it to pay their cost once per *fused* call."""
        path = norm_path(path)
        try:
            st = self.stat(path)
        except OSError:
            return 0
        if not st.exists:
            return 0
        removed = 0
        if st.is_dir and not st.is_symlink:
            try:
                names = self.readdir(path)
            except FileNotFoundError:
                return 0
            for name in names:
                removed += self.remove_tree(f"{path}/{name}" if path else name)
            try:
                self.rmdir(path)
                removed += 1
            except FileNotFoundError:
                pass
        else:
            try:
                self.unlink(path)
                removed += 1
            except FileNotFoundError:
                pass
        return removed

    def read_at(self, path: str, offset: int, size: int) -> bytes: raise NotImplementedError
    def truncate(self, path: str, size: int) -> None: raise NotImplementedError
    def fallocate(self, path: str, size: int) -> None: raise NotImplementedError
    def fsync(self, path: str) -> None: raise NotImplementedError
    # --- metadata ---
    def chmod(self, path: str, mode: int) -> None: raise NotImplementedError
    def chown(self, path: str, uid: int, gid: int) -> None: raise NotImplementedError
    def utimens(self, path: str, atime: float, mtime: float) -> None: raise NotImplementedError
    def setxattr(self, path: str, key: str, value: bytes) -> None: raise NotImplementedError
    def removexattr(self, path: str, key: str) -> None: raise NotImplementedError
    def stat(self, path: str) -> StatResult: raise NotImplementedError
    def readdir(self, path: str) -> list[str]: raise NotImplementedError

    def readdir_plus(self, path: str) -> list[tuple[str, Optional[StatResult]]]:
        """Listing with attributes — the NFS READDIRPLUS analogue the
        overlay uses to warm membership *and* the stat cache in one
        backend call.  Per-entry stat failures are advisory (the entry is
        returned with ``None`` attrs); a failing ``readdir`` still
        raises.  Decorator backends override this to pay one roundtrip
        for the whole listing."""
        path = norm_path(path)
        out: list[tuple[str, Optional[StatResult]]] = []
        for name in self.readdir(path):
            child = f"{path}/{name}" if path else name
            try:
                out.append((name, self.stat(child)))
            except OSError:
                out.append((name, None))
        return out

    def readdir_plus_vec(
            self, paths: list[str],
    ) -> dict[str, list[tuple[str, Optional[StatResult]]]]:
        """Vectored READDIRPLUS: list several directories in one backend
        call — the speculative metadata prefetch pipeline's primitive
        (``core/prefetch.py``).  Returns ``{path: listing}`` keyed by the
        normalized path.  Per-*directory* failures are advisory (a
        directory that cannot be listed — removed, permission-denied — is
        simply omitted from the result), mirroring ``readdir_plus``'s
        per-entry tolerance: the whole batch is a speculative read and
        must never fail a caller.  The default is a loop over
        ``readdir_plus`` so every backend (and every test double
        overriding ``readdir``/``stat``) composes; decorator backends
        override it to pay their cost once per *fused* batch."""
        out: dict[str, list[tuple[str, Optional[StatResult]]]] = {}
        for p in paths:
            p = norm_path(p)
            try:
                out[p] = self.readdir_plus(p)
            except OSError:
                pass
        return out

    def stat_vec(self, paths: list[str]) -> dict[str, StatResult]:
        """Vectored stat: attributes for several paths in one backend
        call — the existence-batching primitive behind ``makedirs``
        parent probes and the write path's journaling stats
        (``core/readahead.py``).  Returns ``{path: StatResult}`` keyed by
        the normalized path.  Per-path failures are advisory (a path
        whose stat raises is simply omitted), mirroring
        ``readdir_plus_vec``: the whole batch is a speculative probe and
        must never fail a caller — a missing entry means "ask
        synchronously".  The default is a loop over ``stat`` so every
        backend (and every test double overriding ``stat``) composes;
        decorator backends override it to pay their cost once per
        *fused* batch."""
        out: dict[str, StatResult] = {}
        for p in paths:
            p = norm_path(p)
            try:
                out[p] = self.stat(p)
            except OSError:
                pass
        return out

    def read_vec(self, path: str, spans: list[tuple[int, int]]) -> list[bytes]:
        """Vectored read: fetch (offset, size) extents of one file in a
        single backend call — the read-ahead layer's primitive (the
        read-side mirror of ``write_vec``, after WTF's file-slice
        composition).  Returns one ``bytes`` per span, in order; a span
        past EOF comes back short or empty exactly as ``read_at`` would
        return it.  Unlike the speculative ``*_vec`` probes this CAN
        raise (a missing file is a real error the caller must see).  The
        default is a loop over ``read_at`` so every backend composes;
        decorator backends override it to pay their cost once per fused
        batch."""
        return [self.read_at(path, off, size) for off, size in spans]

    def cost_hint(self, op: str, nbytes: int = 0) -> Optional[CostHint]:
        """The CostModel protocol (see ``CostHint``).  ``op`` is an op
        *class* name (``"write"``, ``"read"``, ``"rename"``, ``"stat"``,
        ``"readdir"``, ``"remove_tree"``, ...); ``nbytes`` lets a backend
        whose cost structure is size-dependent specialize the hint.  The
        base returns ``None`` — local/in-memory storage has no cost
        opinion and callers keep their fixed policy bounds.  Decorator
        backends MUST override this with an explicit inward delegation:
        because they subclass ``StorageBackend``, this very definition
        would otherwise shadow their ``__getattr__`` fallthrough and
        silently hide the wrapped backend's model."""
        return None


# ---------------------------------------------------------------------------


class LocalBackend(StorageBackend):
    """Rooted local-directory backend (mirrors the host FS like the paper's
    fusexmp-derived passthrough)."""

    def __init__(self, root: str):
        self.root = os.path.abspath(root)
        os.makedirs(self.root, exist_ok=True)

    def _abs(self, path: str) -> str:
        p = norm_path(path)
        out = os.path.join(self.root, p) if p else self.root
        # containment check — the mount must not escape its root
        if not os.path.abspath(out).startswith(self.root):
            raise PermissionError(f"path escapes mount root: {path}")
        return out

    def mkdir(self, path): os.mkdir(self._abs(path))
    def rmdir(self, path): os.rmdir(self._abs(path))

    def create(self, path):
        fd = os.open(self._abs(path), os.O_CREAT | os.O_WRONLY | os.O_TRUNC, 0o644)
        os.close(fd)

    def unlink(self, path): os.unlink(self._abs(path))
    def rename(self, src, dst): os.rename(self._abs(src), self._abs(dst))
    def symlink(self, target, path): os.symlink(target, self._abs(path))
    def link(self, src, dst): os.link(self._abs(src), self._abs(dst))
    def readlink(self, path): return os.readlink(self._abs(path))

    def write_at(self, path, offset, data):
        fd = os.open(self._abs(path), os.O_CREAT | os.O_WRONLY, 0o644)
        try:
            os.lseek(fd, offset, os.SEEK_SET)
            return os.write(fd, data)
        finally:
            os.close(fd)

    def write_vec(self, path, segments):
        # one open per fused batch instead of one per write — the local
        # analogue of the single-roundtrip win on remote backends
        fd = os.open(self._abs(path), os.O_CREAT | os.O_WRONLY, 0o644)
        total = 0
        try:
            for off, data in segments:
                n = os.pwrite(fd, data, off)
                total += n
                if n < len(data):
                    break
        finally:
            os.close(fd)
        return total

    def read_at(self, path, offset, size):
        fd = os.open(self._abs(path), os.O_RDONLY)
        try:
            os.lseek(fd, offset, os.SEEK_SET)
            if size < 0:
                chunks = []
                while True:
                    c = os.read(fd, 1 << 20)
                    if not c:
                        break
                    chunks.append(c)
                return b"".join(chunks)
            # a single os.read may return short of ``size`` (pipe-buffer
            # sized chunks on some filesystems) — accumulate until EOF or
            # the request is satisfied, like the size < 0 branch
            chunks = []
            remaining = size
            while remaining > 0:
                c = os.read(fd, min(remaining, 1 << 20))
                if not c:
                    break
                chunks.append(c)
                remaining -= len(c)
            return b"".join(chunks)
        finally:
            os.close(fd)

    def read_vec(self, path, spans):
        # one open per fused batch instead of one per read — the local
        # analogue of the single-roundtrip win on remote backends
        fd = os.open(self._abs(path), os.O_RDONLY)
        out = []
        try:
            for off, size in spans:
                chunks = []
                remaining = size
                while remaining > 0:
                    c = os.pread(fd, min(remaining, 1 << 20), off)
                    if not c:
                        break
                    chunks.append(c)
                    off += len(c)
                    remaining -= len(c)
                out.append(b"".join(chunks))
        finally:
            os.close(fd)
        return out

    def truncate(self, path, size):
        with open(self._abs(path), "r+b") as f:
            f.truncate(size)

    def fallocate(self, path, size):
        with open(self._abs(path), "ab") as f:
            f.truncate(max(size, os.fstat(f.fileno()).st_size))

    def fsync(self, path):
        fd = os.open(self._abs(path), os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)

    def chmod(self, path, mode): os.chmod(self._abs(path), mode)

    def chown(self, path, uid, gid):  # no-op off-root; permission-free CI
        pass

    def utimens(self, path, atime, mtime):
        os.utime(self._abs(path), (atime, mtime))

    def setxattr(self, path, key, value):
        try:
            os.setxattr(self._abs(path), f"user.{key}", value)
        except OSError:
            pass  # xattrs unsupported on some mounts — metadata-only op

    def removexattr(self, path, key):
        try:
            os.removexattr(self._abs(path), f"user.{key}")
        except OSError:
            pass

    def stat(self, path):
        try:
            st = os.lstat(self._abs(path))
        except FileNotFoundError:
            return StatResult(exists=False)
        import stat as stat_mod
        return StatResult(
            exists=True,
            is_dir=stat_mod.S_ISDIR(st.st_mode),
            is_symlink=stat_mod.S_ISLNK(st.st_mode),
            size=st.st_size,
            mtime=st.st_mtime,
            mode=stat_mod.S_IMODE(st.st_mode),
        )

    def readdir(self, path):
        return sorted(os.listdir(self._abs(path)))

    def readdir_plus(self, path):
        # one scandir pass: names + attrs without a stat syscall per entry
        import stat as stat_mod
        out = []
        with os.scandir(self._abs(path)) as it:
            for de in it:
                try:
                    st = de.stat(follow_symlinks=False)
                    out.append((de.name, StatResult(
                        exists=True,
                        is_dir=stat_mod.S_ISDIR(st.st_mode),
                        is_symlink=stat_mod.S_ISLNK(st.st_mode),
                        size=st.st_size,
                        mtime=st.st_mtime,
                        mode=stat_mod.S_IMODE(st.st_mode),
                    )))
                except OSError:
                    out.append((de.name, None))
        return sorted(out)

    # readdir_plus_vec: the StorageBackend loop default already pays one
    # scandir pass per directory through this class's readdir_plus

    def remove_tree(self, path):
        # one bottom-up walk instead of one syscall chain per engine op —
        # the local analogue of the single-roundtrip win on remote media
        root = self._abs(path)
        if os.path.islink(root) or os.path.isfile(root):
            try:
                os.unlink(root)
                return 1
            except FileNotFoundError:
                return 0
        if not os.path.isdir(root):
            return 0
        removed = 0
        for cur, dirs, files in os.walk(root, topdown=False):
            for name in files + [d for d in dirs
                                 if os.path.islink(os.path.join(cur, d))]:
                try:
                    os.unlink(os.path.join(cur, name))
                    removed += 1
                except FileNotFoundError:
                    pass
            for name in dirs:
                p = os.path.join(cur, name)
                if os.path.islink(p):
                    continue
                try:
                    os.rmdir(p)
                    removed += 1
                except FileNotFoundError:
                    pass
        try:
            os.rmdir(root)
            removed += 1
        except FileNotFoundError:
            pass
        return removed


# ---------------------------------------------------------------------------


class InMemoryBackend(StorageBackend):
    """Dict filesystem — the sequential oracle for property tests, and a
    zero-latency medium for engine micro-benchmarks.

    All methods raise the same OSErrors a POSIX fs would for the cases the
    engine/test-suite cares about (missing parent, missing file, non-empty
    rmdir)."""

    def __init__(self):
        self._lock = threading.RLock()
        self._files: dict[str, bytearray] = {}
        self._dirs: set[str] = {""}
        self._symlinks: dict[str, str] = {}
        self._meta: dict[str, dict] = {}
        # derived index: parent dir -> child basenames, kept in lockstep
        # with the three tables above so readdir/rmdir cost O(children)
        # instead of a full-table scan — the simulation sweeps walk
        # 10k-directory trees, where the scan is quadratic in tree size
        self._children: dict[str, set[str]] = {"": set()}

    # -- helpers --
    def _check_parent(self, path: str) -> None:
        par = parent_of(path)
        if par not in self._dirs:
            raise FileNotFoundError(f"no such directory: {par!r}")

    def _add_entry(self, path: str) -> None:
        self._children.setdefault(parent_of(path), set()).add(
            posixpath.basename(path))

    def _drop_entry(self, path: str) -> None:
        kids = self._children.get(parent_of(path))
        if kids is not None:
            kids.discard(posixpath.basename(path))

    def _scan_children(self, path: str) -> set[str]:
        """Brute-force recomputation of one directory's child basenames
        from the primary tables (tests cross-check the index with this)."""
        out = set()
        for pool in (self._files, self._dirs, self._symlinks):
            for k in pool:
                if k and parent_of(k) == path:
                    out.add(posixpath.basename(k))
        return out

    def _exists(self, path: str) -> bool:
        return path in self._files or path in self._dirs or path in self._symlinks

    def snapshot(self) -> dict:
        """Full state (for oracle comparison)."""
        with self._lock:
            return {
                "files": {k: bytes(v) for k, v in self._files.items()},
                "dirs": set(self._dirs),
                "symlinks": dict(self._symlinks),
            }

    # -- namespace --
    def mkdir(self, path):
        with self._lock:
            path = norm_path(path)
            self._check_parent(path)
            if self._exists(path):
                raise FileExistsError(path)
            self._dirs.add(path)
            self._add_entry(path)
            self._children.setdefault(path, set())

    def rmdir(self, path):
        with self._lock:
            path = norm_path(path)
            if path not in self._dirs:
                raise FileNotFoundError(path)
            if self._children.get(path):
                raise OSError(39, "directory not empty", path)
            self._dirs.discard(path)
            self._children.pop(path, None)
            self._drop_entry(path)

    def create(self, path):
        with self._lock:
            path = norm_path(path)
            self._check_parent(path)
            if path in self._dirs:
                raise IsADirectoryError(path)
            self._files[path] = bytearray()
            self._add_entry(path)

    def unlink(self, path):
        with self._lock:
            path = norm_path(path)
            if path in self._symlinks:
                del self._symlinks[path]
            elif path in self._files:
                del self._files[path]
            else:
                raise FileNotFoundError(path)
            self._drop_entry(path)

    def rename(self, src, dst):
        with self._lock:
            src, dst = norm_path(src), norm_path(dst)
            if not self._exists(src):
                raise FileNotFoundError(src)
            self._check_parent(dst)
            if src in self._files:
                self._files[dst] = self._files.pop(src)
                self._drop_entry(src)
                self._add_entry(dst)
            elif src in self._symlinks:
                self._symlinks[dst] = self._symlinks.pop(src)
                self._drop_entry(src)
                self._add_entry(dst)
            else:  # directory rename: move the whole subtree
                if self._exists(dst):
                    raise FileExistsError(dst)
                prefix = src + "/"
                for table in (self._files, self._symlinks):
                    for k in [k for k in table if k == src or k.startswith(prefix)]:
                        table[dst + k[len(src):]] = table.pop(k)
                for d in [d for d in self._dirs if d == src or d.startswith(prefix)]:
                    self._dirs.discard(d)
                    self._dirs.add(dst + d[len(src):])
                # the children index moves with the subtree: bucket keys
                # shift wholesale, membership only changes at the roots
                for k in [k for k in self._children
                          if k == src or k.startswith(prefix)]:
                    self._children[dst + k[len(src):]] = self._children.pop(k)
                self._drop_entry(src)
                self._add_entry(dst)

    def symlink(self, target, path):
        with self._lock:
            path = norm_path(path)
            self._check_parent(path)
            if self._exists(path):
                raise FileExistsError(path)
            self._symlinks[path] = target
            self._add_entry(path)

    def link(self, src, dst):
        with self._lock:
            src, dst = norm_path(src), norm_path(dst)
            if src not in self._files:
                raise FileNotFoundError(src)
            self._check_parent(dst)
            self._files[dst] = self._files[src]  # shared bytearray = hardlink
            self._add_entry(dst)

    def readlink(self, path):
        with self._lock:
            path = norm_path(path)
            if path not in self._symlinks:
                raise OSError(22, "not a symlink", path)
            return self._symlinks[path]

    # -- data --
    def write_at(self, path, offset, data):
        with self._lock:
            path = norm_path(path)
            if path not in self._files:
                self._check_parent(path)
                self._files[path] = bytearray()
                self._add_entry(path)
            buf = self._files[path]
            if len(buf) < offset:
                buf.extend(b"\0" * (offset - len(buf)))
            buf[offset:offset + len(data)] = data
            return len(data)

    def read_at(self, path, offset, size):
        with self._lock:
            path = norm_path(path)
            if path not in self._files:
                raise FileNotFoundError(path)
            buf = self._files[path]
            return bytes(buf[offset:] if size < 0 else buf[offset:offset + size])

    def truncate(self, path, size):
        with self._lock:
            path = norm_path(path)
            if path not in self._files:
                raise FileNotFoundError(path)
            buf = self._files[path]
            if len(buf) > size:
                del buf[size:]
            else:
                buf.extend(b"\0" * (size - len(buf)))

    def fallocate(self, path, size):
        with self._lock:
            path = norm_path(path)
            if path in self._files and len(self._files[path]) < size:
                self._files[path].extend(b"\0" * (size - len(self._files[path])))

    def fsync(self, path):
        pass

    # -- metadata --
    def _meta_set(self, path, **kw):
        path = norm_path(path)
        if not self._exists(path):
            raise FileNotFoundError(path)
        self._meta.setdefault(path, {}).update(kw)

    def chmod(self, path, mode):
        with self._lock:
            self._meta_set(path, mode=mode)

    def chown(self, path, uid, gid):
        with self._lock:
            self._meta_set(path, uid=uid, gid=gid)

    def utimens(self, path, atime, mtime):
        with self._lock:
            self._meta_set(path, mtime=mtime)

    def setxattr(self, path, key, value):
        with self._lock:
            self._meta_set(path, **{f"x:{key}": value})

    def removexattr(self, path, key):
        with self._lock:
            path = norm_path(path)
            self._meta.get(path, {}).pop(f"x:{key}", None)

    def stat(self, path):
        with self._lock:
            path = norm_path(path)
            meta = self._meta.get(path, {})
            if path in self._dirs:
                return StatResult(exists=True, is_dir=True,
                                  mode=meta.get("mode", 0o755),
                                  mtime=meta.get("mtime", 0.0))
            if path in self._files:
                return StatResult(exists=True, size=len(self._files[path]),
                                  mode=meta.get("mode", 0o644),
                                  mtime=meta.get("mtime", 0.0))
            if path in self._symlinks:
                return StatResult(exists=True, is_symlink=True,
                                  size=len(self._symlinks[path]))
            return StatResult(exists=False)

    def readdir(self, path):
        with self._lock:
            path = norm_path(path)
            if path not in self._dirs:
                raise FileNotFoundError(path)
            return sorted(self._children.get(path, ()))


# ---------------------------------------------------------------------------


METADATA_OPS = {
    "mkdir", "rmdir", "create", "unlink", "rename", "symlink", "link",
    "readlink", "truncate", "fallocate", "chmod", "chown", "utimens",
    "setxattr", "removexattr", "stat", "readdir", "fsync", "remove_tree",
}


class Clock:
    """Time source for latency simulation.  ``RealClock`` sleeps for real;
    ``VirtualClock`` only advances a counter, so latency+fault schedules
    replay deterministically and orders of magnitude faster in tests."""

    def now(self) -> float: raise NotImplementedError
    def sleep(self, dt: float) -> None: raise NotImplementedError


class RealClock(Clock):
    def now(self) -> float:
        return time.monotonic()

    def sleep(self, dt: float) -> None:
        if dt > 0:
            time.sleep(dt)


class VirtualClock(Clock):
    """Lock-protected simulated time.  ``sleep`` returns immediately after
    crediting the virtual elapsed time; ``now()`` is the total simulated
    seconds 'slept' so far across all threads (an upper bound on what a
    serial execution would have waited — per-op schedules stay exact).

    Virtual elapsed time is additionally accounted *per thread*:
    ``makespan()`` is the busiest single thread's accumulated wait, i.e.
    the parallel schedule's critical path when the executor keeps its
    workers balanced.  ``ops / makespan()`` is therefore a deterministic
    dispatch-throughput measure that genuinely rewards spreading ready
    ops across workers (the dispatch_guard benchmark) without a single
    real sleep."""

    def __init__(self, start: float = 0.0):
        self._lock = threading.Lock()
        self._now = float(start)
        self._per_thread: dict[int, float] = {}

    def now(self) -> float:
        with self._lock:
            return self._now

    def sleep(self, dt: float) -> None:
        if dt <= 0:
            return
        tid = threading.get_ident()
        with self._lock:
            self._now += dt
            self._per_thread[tid] = self._per_thread.get(tid, 0.0) + dt

    def makespan(self) -> float:
        """The longest per-thread accumulated virtual wait (0.0 when no
        thread has slept yet)."""
        with self._lock:
            return max(self._per_thread.values(), default=0.0)

    def thread_seconds(self) -> dict[int, float]:
        """Per-thread virtual seconds slept (thread ident -> seconds)."""
        with self._lock:
            return dict(self._per_thread)


@dataclass
class LatencyModel:
    """Calibrated to the paper's environment: NFSv3 over a single GbE port
    against NAS under varying cluster load.

    * per-op latency ~ lognormal(median=meta_ms, sigma=jitter_sigma)
    * data ops additionally pay size/bandwidth
    * the 'server' admits at most ``server_slots`` concurrent requests
      (client RPC slot table) — overlap beyond that queues, which is what
      bounds CannyFS's speedup to the bandwidth/concurrency roofline rather
      than letting it look infinitely good.
    * ``load`` scales the median (1.0 = quiet cluster; the paper's runs show
      ~5x spread between quiet and loaded — benchmark sweeps use 1..6).
    """

    meta_ms: float = 2.0
    data_ms: float = 2.0
    bandwidth_mb_s: float = 110.0   # GbE payload rate
    jitter_sigma: float = 0.45
    server_slots: int = 64
    load: float = 1.0
    seed: int = 0

    def latency_s(self, rng: random.Random, kind: str, nbytes: int) -> float:
        base_ms = self.meta_ms if kind in METADATA_OPS else self.data_ms
        lat = rng.lognormvariate(0.0, self.jitter_sigma) * base_ms * self.load / 1e3
        if nbytes > 0:
            lat += nbytes / (self.bandwidth_mb_s * 1e6)
        return lat


class LatencyBackend(StorageBackend):
    """Decorator that makes any backend behave like remote storage.

    Besides injecting the delays it also *measures* them: every executed
    call updates an EWMA of the round-trip time (metadata ops: the whole
    latency) and of the achieved bandwidth (data ops: payload over the
    service time past the RTT).  ``bdp_bytes()`` exposes the resulting
    bandwidth-delay product, which the optimizer uses to size write
    coalescing and bulk-remove batching to ~2x BDP instead of a fixed
    constant (ROADMAP item i) — the transactional window stays just wide
    enough that one fused op keeps the pipe full."""

    BDP_ALPHA = 0.2   # EWMA smoothing for the measured RTT / bandwidth

    def __init__(self, inner: StorageBackend, model: LatencyModel | None = None,
                 clock: Clock | None = None):
        self.inner = inner
        self.model = model or LatencyModel()
        self.clock = clock or RealClock()
        self._rng = random.Random(self.model.seed)
        self._rng_lock = threading.Lock()
        self._slots = threading.Semaphore(self.model.server_slots)
        # discrete-event mode (clock.discrete_event, core/simclock.py):
        # the semaphore would deadlock the cooperative scheduler (the one
        # running thread real-blocking on slot holders that only advance
        # when it yields), so server concurrency is modelled on the
        # virtual timeline instead — a heap of slot busy-until times; a
        # request arriving with all slots busy starts when the earliest
        # slot frees (M/G/c queueing, same roofline the semaphore enforced
        # in real time).  Guarded by _rng_lock like the other accounting.
        self._slot_heap: list[float] = []
        self.op_count = 0
        self.busy_s = 0.0  # total server-side service time (for utilization)
        # The RTT/bandwidth EWMAs are *seeded* from the model's nominal
        # figures (the lognormal's median RTT and the advertised payload
        # rate) rather than starting at None: before the seeding, the
        # fuser's first adaptive clamp saw a degenerate BDP and under-sized
        # the first cold fused batch.  Measured samples then pull the
        # estimate toward reality at BDP_ALPHA per op, exactly as before.
        self._rtt_ewma: Optional[float] = (
            self.model.meta_ms * self.model.load / 1e3)
        self._bw_ewma: Optional[float] = self.model.bandwidth_mb_s * 1e6

    def _delay(self, kind: str, nbytes: int = 0):
        a = self.BDP_ALPHA
        with self._rng_lock:
            lat = self.model.latency_s(self._rng, kind, nbytes)
            self.op_count += 1
            self.busy_s += lat
            if nbytes > 0:
                # bandwidth sample = payload over the service time past the
                # RTT; a jittered-down draw can land under the RTT EWMA,
                # and dividing by that sliver would explode the estimate —
                # skip non-positive samples instead
                svc = lat - (self._rtt_ewma or 0.0)
                if svc > 0:
                    bw = nbytes / svc
                    self._bw_ewma = (bw if self._bw_ewma is None
                                     else (1 - a) * self._bw_ewma + a * bw)
            else:
                self._rtt_ewma = (lat if self._rtt_ewma is None
                                  else (1 - a) * self._rtt_ewma + a * lat)
            if getattr(self.clock, "discrete_event", False):
                now = self.clock.now()
                heap = self._slot_heap
                while heap and heap[0] <= now:
                    heapq.heappop(heap)
                if len(heap) >= self.model.server_slots:
                    start = max(now, heapq.heappop(heap))
                else:
                    start = now
                heapq.heappush(heap, start + lat)
                wait = (start - now) + lat
            else:
                wait = -1.0
        if wait >= 0.0:
            self.clock.sleep(wait)
            return
        with self._slots:
            self.clock.sleep(lat)

    def bdp_bytes(self) -> Optional[float]:
        """Measured bandwidth-delay product in bytes.  The EWMAs are
        seeded from the model's nominal RTT and rate, so even the first
        cold call returns a usable estimate; measured samples refine it.
        Lock-free reads: float loads are atomic and a slightly stale EWMA
        only shifts the adaptive clamp by one smoothing step."""
        rtt = self._rtt_ewma
        if rtt is None:
            return None
        bw = self._bw_ewma
        if bw is None:
            bw = self.model.bandwidth_mb_s * 1e6
        return rtt * bw

    def cost_hint(self, op: str, nbytes: int = 0) -> Optional[CostHint]:
        """Per-op-class hint from the live EWMAs.  Data-plane classes use
        the calibrated bandwidth; metadata classes stream nothing.  The
        wrapped backend gets the first word: if the inner storage has its
        own cost model (object store behind a latency shaper), its
        structural costs (rename = copy+delete, paginated listings)
        dominate the shaper's uniform RTT and are what the fuser must
        hear about."""
        inner = getattr(self.inner, "cost_hint", None)
        if callable(inner):
            hint = inner(op, nbytes)
            if hint is not None:
                return hint
        rtt = self._rtt_ewma or (self.model.meta_ms * self.model.load / 1e3)
        bw = self._bw_ewma or (self.model.bandwidth_mb_s * 1e6)
        return CostHint(rtt_s=rtt, bytes_per_s=bw)

    def __getattr__(self, name):  # delegate non-op attrs
        return getattr(self.inner, name)

    # each primitive: pay the roundtrip, then do the real thing
    def mkdir(self, path): self._delay("mkdir"); self.inner.mkdir(path)
    def rmdir(self, path): self._delay("rmdir"); self.inner.rmdir(path)
    def create(self, path): self._delay("create"); self.inner.create(path)
    def unlink(self, path): self._delay("unlink"); self.inner.unlink(path)
    def rename(self, s, d): self._delay("rename"); self.inner.rename(s, d)
    def symlink(self, t, p): self._delay("symlink"); self.inner.symlink(t, p)
    def link(self, s, d): self._delay("link"); self.inner.link(s, d)
    def readlink(self, p): self._delay("readlink"); return self.inner.readlink(p)
    def write_at(self, p, o, data):
        self._delay("write", len(data)); return self.inner.write_at(p, o, data)
    def write_vec(self, p, segments):
        # one roundtrip for the whole fused vector: per-op latency is paid
        # once, bandwidth for the total payload — this is the coalescing win
        self._delay("write", sum(len(d) for _, d in segments))
        return self.inner.write_vec(p, segments)
    def read_at(self, p, o, size):
        out = self.inner.read_at(p, o, size)
        self._delay("read", len(out)); return out
    def truncate(self, p, s): self._delay("truncate"); self.inner.truncate(p, s)
    def fallocate(self, p, s): self._delay("fallocate"); self.inner.fallocate(p, s)
    def fsync(self, p): self._delay("fsync"); self.inner.fsync(p)
    def chmod(self, p, m): self._delay("chmod"); self.inner.chmod(p, m)
    def chown(self, p, u, g): self._delay("chown"); self.inner.chown(p, u, g)
    def utimens(self, p, a, m): self._delay("utimens"); self.inner.utimens(p, a, m)
    def setxattr(self, p, k, v): self._delay("setxattr"); self.inner.setxattr(p, k, v)
    def removexattr(self, p, k): self._delay("removexattr"); self.inner.removexattr(p, k)
    def stat(self, p): self._delay("stat"); return self.inner.stat(p)
    def readdir(self, p): self._delay("readdir"); return self.inner.readdir(p)
    def readdir_plus(self, p):
        # READDIRPLUS: one roundtrip returns names *and* attributes —
        # the overlay's whole-directory warm-up costs one op, not 1+N
        self._delay("readdir")
        return self.inner.readdir_plus(p)
    def readdir_plus_vec(self, paths):
        # ONE roundtrip for the whole batch of listings — the prefetch
        # pipeline's win: a cold walk pays dirs/batch RTTs, not dirs.
        # (The *batch width* is sized by the prefetcher from this
        # backend's live RTT/bandwidth EWMAs via bdp_bytes().)
        self._delay("readdir")
        return self.inner.readdir_plus_vec(paths)
    def stat_vec(self, paths):
        # ONE roundtrip for the whole batch of stats — the existence
        # batcher's win: a manifest-driven extract pays files/batch RTTs
        # for its journaling probes, not files (cf. readdir_plus_vec)
        self._delay("stat")
        return self.inner.stat_vec(paths)
    def read_vec(self, p, spans):
        # one roundtrip for the whole fused extent vector: per-op latency
        # once, bandwidth for the payload actually returned — the
        # read-side mirror of write_vec (ordering matches read_at: the
        # inner read resolves the true sizes, then the delay is paid)
        out = self.inner.read_vec(p, spans)
        self._delay("read", sum(len(b) for b in out))
        return out
    def remove_tree(self, p):
        # one roundtrip for the whole fused subtree removal — this is the
        # cross-path bulk-remove win (cf. write_vec for coalesced writes)
        self._delay("remove_tree")
        return self.inner.remove_tree(p)
