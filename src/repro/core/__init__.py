"""repro.core — CannyFS: the paper's transactional eager-I/O engine.

Public API:

    backend  = LocalBackend(root) | InMemoryBackend() | LatencyBackend(...)
    fs       = CannyFS(backend, flags=EagerFlags(), max_inflight=4000)
    with Transaction(fs) as txn:
        fs.mkdir("out"); fs.write_file("out/x.bin", b"...")
    # txn.commit() ran at exit; on deferred error -> rollback + retry via
    # run_transaction(fs, body)
"""
from .backend import (InMemoryBackend, LatencyBackend, LatencyModel,
                      LocalBackend, StatResult, StorageBackend, norm_path,
                      parent_of)
from .engine import EagerIOEngine, EngineStats
from .errors import (CannyError, EnginePoisonedError, ErrorLedger,
                     LedgerEntry, OpCancelledError, TransactionFailedError)
from .flags import EagerFlags, N_FLAGS
from .fs import CannyFS, CannyFile
from .transaction import Transaction, run_transaction

__all__ = [
    "CannyError", "CannyFS", "CannyFile", "EagerFlags", "EagerIOEngine",
    "EngineStats", "EnginePoisonedError", "ErrorLedger", "InMemoryBackend",
    "LatencyBackend", "LatencyModel", "LedgerEntry", "LocalBackend", "N_FLAGS",
    "OpCancelledError", "StatResult", "StorageBackend", "Transaction",
    "TransactionFailedError", "norm_path", "parent_of", "run_transaction",
]
