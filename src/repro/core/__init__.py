"""repro.core — CannyFS: the paper's transactional eager-I/O engine.

Public API:

    backend  = LocalBackend(root) | InMemoryBackend() | LatencyBackend(...)
    fs       = CannyFS(backend, flags=EagerFlags(), max_inflight=4000)
    with Transaction(fs) as txn:
        fs.mkdir("out"); fs.write_file("out/x.bin", b"...")
    # txn.commit() ran at exit; on deferred error -> rollback + retry via
    # run_transaction(fs, body)

Backend decorator stack
-----------------------

Backends compose as decorators around a base store; each layer adds one
orthogonal behaviour and delegates the rest::

    base    = InMemoryBackend()                 # or LocalBackend(root)
    remote  = LatencyBackend(base, LatencyModel(load=4.0),
                             clock=VirtualClock())   # NFS-like delays
    quota   = QuotaBackend(remote, budget_bytes=64 << 20)   # EDQUOT budget
    chaos   = FaultInjectingBackend(quota, FaultPlan([
                  FaultRule(error="EIO", ops=("write",),
                            path_glob="out/*", probability=0.01)], seed=0))
    fs      = CannyFS(chaos, abort_on_error=True)

* ``LatencyBackend``        — per-op latency, bandwidth cap, server slots;
  pass ``clock=VirtualClock()`` for deterministic, near-instant replay, or
  ``clock=SimClock()`` (``core/simclock.py``) for the discrete-event mode:
  the engine's driver and pool workers become actors of a cooperative
  event-queue simulation, every makespan/steal/park count is a pure
  function of the op stream and the model's seed, and the guards run at
  full scale in milliseconds (see the benchmarks).
* ``QuotaBackend``          — byte budget; quota exhaustion (EDQUOT)
  emerges organically mid-write and is *released* by rollback's unlinks.
* ``FaultInjectingBackend`` — seeded ``FaultPlan`` of ``FaultRule`` clauses
  (match op kind / path glob / call window / probability; raise EACCES,
  ENOSPC, EDQUOT, EIO or connection loss).  Same seed, same schedule —
  fault tests replay bit-identically.

Backend zoo + CostModel protocol
--------------------------------

The stack can bottom out at a production-shaped storage class instead of
``Local``/``InMemory`` (both new backends delegate their *state* to an
internal ``InMemoryBackend`` oracle, so the property suites compare them
against POSIX byte-for-byte while the *billing* diverges)::

    store  = ObjectStoreBackend(model=ObjectStoreModel(
                 rtt_ms=25.0, per_request_ms=2.0,
                 bandwidth_mb_s=200.0, list_page_size=1000))
    sftp   = RemoteStreamBackend(model=RemoteStreamModel(
                 rtt_ms=40.0, per_item_ms=0.5, bandwidth_mb_s=110.0))

* ``ObjectStoreBackend`` (``core/objectstore.py``) — S3-style: flat
  keyspace with paginated ``list_by_prefix`` (S3 continuation tokens)
  instead of readdir, whole-object PUT (a non-covering ``write_at`` is a
  read-modify-write GET+PUT, so ``write_vec`` coalescing is mandatory),
  rename = server-side COPY+DELETE per key, ``remove_tree`` = LIST pages
  + ONE bulk DELETE, per-request + per-byte billing
  (``request_count``/``requests_by_class``/``whole_object_puts``/
  ``rmw_gets`` counters).
* ``RemoteStreamBackend`` (``core/remote.py``) — SFTP/WebDAV-style:
  every op is one high-RTT round-trip, payload streaming is cheap,
  vectored ops pay ONE round-trip plus a per-item pipeline overhead,
  rename is native.

Every backend answers the **CostModel protocol**: ``cost_hint(op,
nbytes) -> CostHint(rtt_s, bytes_per_s, per_request_overhead_s) | None``
(``None`` = no opinion; fixed policy bounds stand).  Decorators delegate
the question inward, so the hint reflects the storage at the bottom of
the stack.  Consumers: the fuser sizes write coalescing from the
"write" class and bulk-remove batching from "remove_tree", arms the
cost-gated rename-retarget rule by comparing "rename" vs "create"
(``FusionPolicy.retarget_renames="auto"``, ``rename_cost_ratio``); the
prefetcher sizes listing batches from "readdir"; the read-ahead window
from "read"; the stat batcher from "stat" (policy caps always win).
``LatencyBackend`` answers from its live RTT/bandwidth EWMAs — which are
seeded from the model's nominal figures, so the very first fused batch
is already BDP-sized.

Injected failures flow through the normal deferred-error machinery: the
ErrorLedger records them, ``abort_on_error`` poisons the engine, and
``run_transaction`` rolls back (restoring namespace *and* quota) and
resubmits — the paper's transactional story, now exercisable end to end.

Engine layers (see ``core/engine.py`` for the diagram)
------------------------------------------------------

The engine itself is scheduler (``core/scheduler.py``: path-hash-sharded
per-path FIFO + DAG, with per-shard ready deques and work stealing on
the dispatch path — ``CannyFS(work_stealing=False)`` pins workers to
their own shards) / optimizer (``core/fusion.py``: the transactional
op-fusion pass — coalesce writes into ``write_vec`` sized to ~2x the
backend's measured bandwidth-delay product when adaptive, fold metadata
last-wins, elide chains unlinked in-window, collapse cross-path removals
into one ``remove_tree`` with exec-time re-verification under
still-provisional mkdirs; control via ``CannyFS(fusion=
FusionPolicy(...))`` or ``fusion=False``) / namespace overlay
(``core/namespace.py``: the write-back directory-tree delta that answers
``readdir``/``stat``/``exists``/``walk`` from pending state without
sealing chains, cached listings LRU-bounded; control via
``CannyFS(overlay=OverlayPolicy(...))`` or ``overlay=False``) /
prefetcher (``core/prefetch.py``: the speculative metadata-prefetch
pipeline for *cold* trees — a readdir/walk miss seeds a bounded BFS
frontier fetched in batched ``readdir_plus_vec`` reads, ONE roundtrip
per batch sized to ~2x the measured BDP, installed into the overlay
without sealing and cancelled by racing mutations so semantics stay
byte-identical; control via ``CannyFS(prefetch=PrefetchPolicy(...))``
or ``prefetch=False``) / read-side data plane (``core/readahead.py``:
a sequential reader's first sync ``pread`` registers a ticketed
per-file page buffer and pipelines speculative ``read_vec`` windows
sized to ~2x the measured BDP ahead of the consumer — page hits skip
the backend, racing admitted mutations cancel the run — while the
transactional write path's journaling existence probes fuse into ONE
speculative ``stat_vec`` per batch with a sync-stat fallback; control
via ``CannyFS(readahead=ReadPolicy(...))`` or ``readahead=False``) /
executor (``core/executor.py``: pool | thread_per_op).  Fault rules
fire per *fused* backend call (one ``write_vec``, ``readdir_plus_vec``,
``stat_vec``, ``read_vec`` or ``remove_tree`` of N engine ops is a
single match — speculative batch faults are advisory and never reach
the ledger), and torn writes surface as ``ShortWriteError``.

Durability spill + resume (``core/durability.py``)
--------------------------------------------------

``fs.enable_spill(spill_dir)`` attaches a ``SpillManager`` that
incrementally persists the open transaction's region journal and the
namespace-overlay membership delta as an append-only, crc32-checksummed
record log on the backend itself.  Spill chunks ride the scheduler's
*speculative* low-priority lane (they never serialize the hot path); a
COMMIT-style cut marker is stamped at every ``barrier``/``drain`` seal.
After a ``ProcessKilled`` preemption (``FaultRule(outcome="kill")``), a
fresh mount calls ``CannyFS.resume(spill_dir)`` instead of rolling the
whole window back: the overlay delta is reinstalled without re-walking,
the journal is replayed, in-flight ops at the kill point are probed and
repaired, and re-executed ops that are provably durable (content
verified against recorded per-segment checksums) are elided.
``run_transaction`` treats ``ProcessKilled`` as preemption — no
rollback, no retry — and its transient-fault retry loop now charges a
seeded full-jitter exponential backoff on the injected clock.

Tenancy (``core/tenancy.py``)
-----------------------------

One engine serves N concurrent jobs.  ``fs.tenant(name, root_prefix,
weight, quota)`` returns a ``Tenant`` — a ``CannyFS``-shaped view that
shares the parent's engine but scopes four things:

* **namespace** — ops are confined to ``root_prefix``
  (PermissionError outside it); a tenant's commit/rollback clears the
  shared namespace overlay only under its prefix
  (``NamespaceOverlay.clear_under``), so neighbours' optimization
  windows stay open.
* **failure domain** — ledger entries carry a tenant tag
  (``ErrorLedger.entries_for_tenant``), poison / rollback / retry +
  backoff / spill-resume journals are per-tenant, and
  ``abort_on_error`` cancels only the faulting tenant's queued ops.
  ``FaultInjectingBackend(kill_scope="tA/*")`` models one tenant's
  worker dying while neighbours' calls keep flowing.
* **resources** — an optional ``TenantQuota`` (bytes + inodes,
  EDQUOT/ENOSPC at ACK time) plus deficit-weighted-round-robin
  dispatch credit in the scheduler's ready lanes and steal path, so a
  bursty tenant cannot starve a neighbour's latency.
* **admission control** — at global in-flight saturation the
  scheduler sheds speculative lanes first, then backpressures only
  the over-share tenant's submits.

Per-tenant observability lives in ``EngineStats.tenants[name]``
(``TenantStats``: ops, fused, deferred errors, steals served, credits
spent, retries/rollbacks/resumes, quota headroom) and
``QuotaBackend.usage()`` / ``TenantQuota.usage()``.
"""
from .backend import (Clock, CostHint, InMemoryBackend, LatencyBackend,
                      LatencyModel, LocalBackend, RealClock, StatResult,
                      StorageBackend, VirtualClock, is_under, norm_path,
                      parent_of)
from .durability import SpillImage, SpillManager, commit_marker_ok
from .engine import EagerIOEngine, EngineStats, TenantStats
from .errors import (CannyError, EnginePoisonedError, ErrorLedger,
                     LedgerEntry, OpCancelledError, ProcessKilled,
                     RollbackLeakError, ShortWriteError,
                     TransactionFailedError)
from .faults import (FaultInjectingBackend, FaultPlan, FaultRule,
                     QuotaBackend, make_fault)
from .flags import EagerFlags, N_FLAGS
from .fs import CannyFS, CannyFile
from .fusion import FusionPolicy
from .namespace import (NamespaceOverlay, OverlayPolicy, RemoveWitness,
                        SpeculationTicket)
from .objectstore import ObjectStoreBackend, ObjectStoreModel
from .prefetch import MetadataPrefetcher, PrefetchPolicy
from .readahead import ReadAheadManager, ReadPolicy, StatVecBatcher
from .remote import RemoteStreamBackend, RemoteStreamModel
from .simclock import SimClock
from .tenancy import Tenant, TenantQuota
from .transaction import Transaction, run_transaction

__all__ = [
    "CannyError", "CannyFS", "CannyFile", "Clock", "CostHint", "EagerFlags",
    "EagerIOEngine", "EngineStats", "EnginePoisonedError", "ErrorLedger",
    "FaultInjectingBackend", "FaultPlan", "FaultRule", "FusionPolicy",
    "InMemoryBackend",
    "LatencyBackend", "LatencyModel", "LedgerEntry", "LocalBackend",
    "MetadataPrefetcher", "N_FLAGS",
    "NamespaceOverlay", "ObjectStoreBackend", "ObjectStoreModel",
    "OpCancelledError", "OverlayPolicy",
    "PrefetchPolicy", "ProcessKilled", "QuotaBackend",
    "RemoteStreamBackend", "RemoteStreamModel",
    "ReadAheadManager", "ReadPolicy", "RealClock", "RemoveWitness",
    "RollbackLeakError", "SimClock",
    "ShortWriteError", "SpeculationTicket", "SpillImage", "SpillManager",
    "StatResult", "StatVecBatcher",
    "StorageBackend", "Tenant", "TenantQuota", "TenantStats",
    "Transaction", "TransactionFailedError", "VirtualClock",
    "commit_marker_ok", "is_under", "make_fault", "norm_path", "parent_of",
    "run_transaction",
]
