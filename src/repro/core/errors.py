"""Error model for the transactional eager-I/O engine.

The paper defers error reporting: background failures are recorded in a
ledger, printed twice (at occurrence and at orderly teardown), and surfaced
at the transaction boundary.  An optional abort-on-error mode poisons the
engine so every later access fails fast.
"""
from __future__ import annotations

import sys
import threading
import time
from dataclasses import dataclass, field


class CannyError(Exception):
    """Base class for engine errors."""


class EnginePoisonedError(CannyError):
    """Raised on new submissions after abort_on_error tripped."""


class OpCancelledError(CannyError):
    """A queued op was cancelled (engine poisoned before execution)."""


class RollbackLeakError(CannyError):
    """Rollback verified that some transaction outputs could not be
    removed.  Recorded in the ledger (untagged) when the job ultimately
    succeeds anyway, so teardown reporting still surfaces the leak."""


class ProcessKilled(CannyError):
    """Simulated SIGKILL: the process (and with it the backend connection)
    died mid-job.  Deliberately NOT an OSError — an in-process retry
    cannot clear it (the process is 'gone'), so ``run_transaction`` must
    neither roll back nor resubmit; recovery is a fresh mount's
    ``CannyFS.resume(spill_dir)`` against the durable spill journal
    (``core/durability.py``).  Raised by ``FaultInjectingBackend`` when a
    ``FaultRule(outcome="kill")`` fires, and by every later call against
    the dead backend."""


class ShortWriteError(OSError, CannyError):
    """A (possibly fused/vectored) write landed fewer bytes than submitted
    — a torn op.  Carries errno EIO so the transactional retry loop treats
    it as transient: the torn file is journaled (rollback removes it) and
    the resubmitted job rewrites it whole."""

    def __init__(self, path: str, expected: int, written: int):
        import errno as _errno
        super().__init__(_errno.EIO,
                         f"short write: {written}/{expected} bytes", path)
        self.expected = expected
        self.written = written


class TransactionFailedError(CannyError):
    """Commit found deferred errors in the ledger."""

    def __init__(self, entries: list["LedgerEntry"]):
        self.entries = entries
        lines = "; ".join(str(e) for e in entries[:8])
        more = "" if len(entries) <= 8 else f" (+{len(entries) - 8} more)"
        super().__init__(f"{len(entries)} deferred I/O error(s): {lines}{more}")


@dataclass(frozen=True)
class LedgerEntry:
    """One deferred failure: which op, on what path(s), what went wrong.

    ``region`` identifies the transaction that was active when the op was
    *submitted* (None for non-transactional work).  Record order cannot be
    scoped positionally — op ``seq`` is assigned at submission, ops finish
    out of order, and concurrent regions interleave — so the tag is what
    attributes an entry exactly."""

    seq: int
    kind: str
    paths: tuple[str, ...]
    error: BaseException
    wallclock: float
    region: object = None
    tenant: str | None = None

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return f"op#{self.seq} {self.kind}({', '.join(self.paths)}): {self.error!r}"


class ErrorLedger:
    """Thread-safe record of deferred I/O failures.

    Mirrors the paper's behaviour: every failure is printed to stderr when it
    happens, and the full ledger is printed again at orderly teardown so the
    user is "notified of any I/O errors that were not properly reported back
    to the calling process".
    """

    def __init__(self, *, echo: bool = True):
        self._lock = threading.Lock()
        self._entries: list[LedgerEntry] = []
        self._echo = echo

    def record(self, seq: int, kind: str, paths: tuple[str, ...],
               error: BaseException, region: object = None,
               tenant: str | None = None) -> LedgerEntry:
        with self._lock:
            entry = LedgerEntry(seq=seq, kind=kind, paths=paths, error=error,
                                wallclock=time.time(), region=region,
                                tenant=tenant)
            self._entries.append(entry)
        # cancellations are secondary effects of one poisoning failure —
        # echoing thousands of them per rollback drowns the root cause
        if self._echo and not isinstance(error, OpCancelledError):
            print(f"cannyfs: deferred error: {entry}", file=sys.stderr)
        return entry

    def entries(self) -> list[LedgerEntry]:
        with self._lock:
            return list(self._entries)

    def entries_for(self, region: object) -> list[LedgerEntry]:
        """Entries from ops submitted while ``region`` was the active
        transaction."""
        with self._lock:
            return [e for e in self._entries if e.region is region]

    def clear_where(self, pred) -> list["LedgerEntry"]:
        """Drop (and return) every entry matching ``pred`` — for callers
        that handled a scoped set of failures themselves (the checkpoint
        manager's per-directory commit check)."""
        with self._lock:
            dropped = [e for e in self._entries if pred(e)]
            self._entries = [e for e in self._entries if not pred(e)]
            return dropped

    def clear_region(self, region: object) -> list["LedgerEntry"]:
        """Drop (and return) exactly one region's entries.

        This is the transaction-scoped clear: a rollback must forget the
        failed region's errors without touching entries from earlier work
        (region None) or from another region that opened concurrently —
        serial ranges of interleaved regions overlap, tags don't."""
        return self.clear_where(lambda e: e.region is region)

    def entries_for_tenant(self, tenant: str | None) -> list[LedgerEntry]:
        """Entries attributed to ``tenant`` (the tenant name stamped at
        submission; ``None`` selects untenanted work)."""
        with self._lock:
            return [e for e in self._entries if e.tenant == tenant]

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()

    def report(self) -> None:
        """Teardown-time second report (the paper's global destructor).
        Cancellations are summarized as one count line, not spelled out."""
        entries = self.entries()
        if not entries or not self._echo:
            return
        real = [e for e in entries
                if not isinstance(e.error, OpCancelledError)]
        n_cancelled = len(entries) - len(real)
        print(f"cannyfs: {len(entries)} deferred I/O error(s) at teardown:",
              file=sys.stderr)
        for e in real:
            print(f"cannyfs:   {e}", file=sys.stderr)
        if n_cancelled:
            print(f"cannyfs:   (+{n_cancelled} op(s) cancelled by poisoning)",
                  file=sys.stderr)
