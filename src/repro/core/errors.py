"""Error model for the transactional eager-I/O engine.

The paper defers error reporting: background failures are recorded in a
ledger, printed twice (at occurrence and at orderly teardown), and surfaced
at the transaction boundary.  An optional abort-on-error mode poisons the
engine so every later access fails fast.
"""
from __future__ import annotations

import sys
import threading
import time
from dataclasses import dataclass, field


class CannyError(Exception):
    """Base class for engine errors."""


class EnginePoisonedError(CannyError):
    """Raised on new submissions after abort_on_error tripped."""


class OpCancelledError(CannyError):
    """A queued op was cancelled (engine poisoned before execution)."""


class TransactionFailedError(CannyError):
    """Commit found deferred errors in the ledger."""

    def __init__(self, entries: list["LedgerEntry"]):
        self.entries = entries
        lines = "; ".join(str(e) for e in entries[:8])
        more = "" if len(entries) <= 8 else f" (+{len(entries) - 8} more)"
        super().__init__(f"{len(entries)} deferred I/O error(s): {lines}{more}")


@dataclass(frozen=True)
class LedgerEntry:
    """One deferred failure: which op, on what path(s), what went wrong."""

    seq: int
    kind: str
    paths: tuple[str, ...]
    error: BaseException
    wallclock: float

    def __str__(self) -> str:  # pragma: no cover - cosmetic
        return f"op#{self.seq} {self.kind}({', '.join(self.paths)}): {self.error!r}"


class ErrorLedger:
    """Thread-safe record of deferred I/O failures.

    Mirrors the paper's behaviour: every failure is printed to stderr when it
    happens, and the full ledger is printed again at orderly teardown so the
    user is "notified of any I/O errors that were not properly reported back
    to the calling process".
    """

    def __init__(self, *, echo: bool = True):
        self._lock = threading.Lock()
        self._entries: list[LedgerEntry] = []
        self._echo = echo

    def record(self, seq: int, kind: str, paths: tuple[str, ...],
               error: BaseException) -> LedgerEntry:
        entry = LedgerEntry(seq=seq, kind=kind, paths=paths, error=error,
                            wallclock=time.time())
        with self._lock:
            self._entries.append(entry)
        if self._echo:
            print(f"cannyfs: deferred error: {entry}", file=sys.stderr)
        return entry

    def entries(self) -> list[LedgerEntry]:
        with self._lock:
            return list(self._entries)

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()

    def report(self) -> None:
        """Teardown-time second report (the paper's global destructor)."""
        entries = self.entries()
        if not entries or not self._echo:
            return
        print(f"cannyfs: {len(entries)} deferred I/O error(s) at teardown:",
              file=sys.stderr)
        for e in entries:
            print(f"cannyfs:   {e}", file=sys.stderr)
