"""Optimizer layer: the transactional op-fusion pass.

The paper's thesis is that batch I/O is a transaction whose only
observation points are reads, barriers and commit.  Between observation
points the pending op stream is therefore not just deferrable — it is
*rewritable*: the engine may coalesce, fold and delete pending ops as long
as commit-visible state is unchanged.  This module implements that pass as
peephole rules over each path's pending chain:

* **coalesce** — adjacent ``write_at`` ops on one path merge into a single
  vectored ``write_vec`` backend call (contiguous segments concatenate
  without copying until execution);
* **fold** — adjacent same-kind ``chmod``/``utimens``/``truncate`` ops
  collapse to last-wins (only the final value is observable at commit);
* **elide** — a ``create``+``write``(+metadata) chain whose path is
  unlinked inside the same unobserved window never touches the backend at
  all (the extract-then-rmtree workload); the trailing unlink becomes
  tolerant of the file's absence so the stream stays error-free;
* **rename retarget** (cost-gated) — on storage where rename is a
  server-side copy+delete (object stores), a rename whose source's whole
  backend lifetime is still pending (create+write+metadata chain, all
  unexecuted) is rewritten to *build the file at the destination
  instead*: the source chain is captured atomically
  (``OpScheduler.capture_chain``) and its payloads replayed at the
  destination path, so the expensive copy+delete never happens.  The
  rule arms itself from the backend's ``cost_hint`` (``retarget_renames
  = "auto"``): it fires only when a rename costs at least
  ``rename_cost_ratio`` times a create, so POSIX-shaped media with a
  one-roundtrip rename are never rewritten;
* **bulk remove** (cross-path, keyed by directory prefix) — when an
  ``rmdir`` arrives and the namespace overlay proves its whole subtree is
  known *and* ends empty after the pending removals, those pending
  unlinks/rmdirs/child-``remove_tree``s are elided and replaced by ONE
  vectored ``remove_tree`` backend call on the common root.  Collapses
  roll up: leaf directories fuse first, parents then absorb their
  children's fused removals, so a readdir-driven ``rmtree`` converges to
  a single backend op for the whole tree.  Subtrees resting on
  *provisional* directories (mkdir admitted, not yet executed) fuse too:
  the fused op carries a ``RemoveWitness`` and re-verifies the claim at
  execution time, falling back per-entry byte-identically when a mkdir
  was demoted (``FusionPolicy.reverify_provisional``).

Safety comes from the scheduler's per-op flags: fusion only ever mutates
the pending *tip* op of a path while it is unclaimed (no executor owns
it), unsealed (no observation point waits on it) and in the same
transaction region (so a fused failure is attributed to exactly one
region's ledger scope).  Fault semantics are defined per *fused* backend
call: one ``write_vec`` of N coalesced writes is a single match for a
``FaultRule``, and a short (torn) outcome tears the fused op as a unit —
see ``faults.FaultInjectingBackend.write_vec``.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass

from .backend import is_under

# op kinds whose effects on a path are invisible at commit once the path
# is unlinked in the same unobserved window
ELIDABLE_KINDS = frozenset({
    "create", "write", "chmod", "utimens", "truncate", "fallocate",
    "setxattr",
})

# pending removal ops a bulk remove_tree on an ancestor subsumes: their
# whole duty transfers to the fused call, so they can leave the stream
REMOVAL_KINDS = frozenset({"unlink", "rmdir", "remove_tree"})

# pending ops the rename-retarget rule can replay at the destination:
# their payloads (WritePayload / MetaPayload / the bare create) carry the
# full arguments.  fallocate/setxattr are elidable-on-unlink but their
# submitted fns close over their args with no payload — not replayable.
RETARGET_KINDS = frozenset({"create", "write", "chmod", "utimens",
                            "truncate"})


@dataclass(frozen=True)
class FusionPolicy:
    """Which peephole rules run, and the coalescing bounds.

    ``max_segments``/``max_bytes`` cap one fused op's payload so a writer
    streaming into a single file still rotates ops (and re-enters the
    engine's in-flight budget) instead of growing one op without bound.

    With ``adaptive_max_bytes`` on and a backend that measures its own
    bandwidth-delay product (``LatencyBackend.bdp_bytes``), the effective
    write-coalescing cap is ``bdp_multiplier`` x BDP instead of the fixed
    ``max_bytes`` — one fused op is sized to keep the pipe full for about
    two round trips, no larger.  The policy bounds always win: the
    adaptive value is clamped to [``min_adaptive_bytes``, ``max_bytes``].
    Bulk-remove batching is clamped the same way (a fused ``remove_tree``
    covers at most ``bdp_multiplier`` x BDP worth of directory entries at
    ~256 bytes each, within [``min_remove_entries``,
    ``max_remove_entries``]).

    ``reverify_provisional`` lets the bulk-remove pass fuse under
    *provisional* directories (mkdir admitted, not yet executed); the
    fused op then re-verifies the overlay claim at execution time and
    falls back to per-entry removal byte-identically when any mkdir was
    demoted (see ``namespace.RemoveWitness``)."""

    enabled: bool = True
    coalesce_writes: bool = True
    fold_metadata: bool = True
    elide_unlinked: bool = True
    bulk_remove: bool = True     # cross-path unlink/rmdir -> remove_tree
    max_segments: int = 128
    max_bytes: int = 32 << 20
    # -- adaptive bandwidth-delay sizing (ROADMAP i) --
    adaptive_max_bytes: bool = True
    bdp_multiplier: float = 2.0
    min_adaptive_bytes: int = 64 << 10
    # -- bulk-remove batching bounds --
    max_remove_entries: int = 1 << 20
    min_remove_entries: int = 4096
    # -- exec-time re-verification for provisional subtrees (ROADMAP m) --
    reverify_provisional: bool = True
    # -- rule 5: cost-gated rename retarget (ROADMAP r) --
    # "auto": fire iff cost_hint says rename >= rename_cost_ratio x create
    # (object stores: copy+delete, ratio ~2 -> fires; POSIX media: ~1 ->
    # never).  True/False force the rule on/off regardless of cost.
    retarget_renames: object = "auto"
    rename_cost_ratio: float = 1.5

    @classmethod
    def off(cls) -> "FusionPolicy":
        return cls(enabled=False)


class WritePayload:
    """Segments of one (possibly fused) write op.

    Contiguous appends extend the previous segment as a chunk list —
    concatenation is deferred to ``segments()`` at execution time, so the
    hot ACK path never copies payload bytes.  Mutated only under the
    owning op's ``flock`` (scheduler guarantee); frozen once claimed."""

    __slots__ = ("_segs", "nbytes")

    def __init__(self, offset: int, data: bytes):
        self._segs: list[list] = [[offset, [data], len(data)]]
        self.nbytes = len(data)

    def add(self, offset: int, data: bytes) -> None:
        last = self._segs[-1]
        if offset == last[0] + last[2]:
            last[1].append(data)
            last[2] += len(data)
        else:
            self._segs.append([offset, [data], len(data)])
        self.nbytes += len(data)

    @property
    def n_segments(self) -> int:
        return len(self._segs)

    def segments(self) -> list[tuple[int, bytes]]:
        return [(off, chunks[0] if len(chunks) == 1 else b"".join(chunks))
                for off, chunks, _ in self._segs]


class MetaPayload:
    """Arguments of one foldable metadata op (chmod/utimens/truncate);
    last-wins replacement under the owning op's flock."""

    __slots__ = ("args",)

    def __init__(self, args: tuple):
        self.args = args


class BulkRemovePayload:
    """One fused cross-path removal: the root, the covered paths (the
    fused op's co-paths — dependency edges and error-invalidation scope),
    the per-entry manifest for the demoted fallback, and the overlay
    witness that re-verifies provisional directories at execution time.

    ``witness`` is None when the subtree was fully backend-proven at fuse
    time (the PR 3 case) — the fused ``remove_tree`` runs unconditionally.
    Otherwise the executor asks the overlay whether every watched mkdir
    was *promoted* (created its directory fresh): promoted -> the single
    vectored ``remove_tree``; demoted -> a byte-identical per-entry
    fallback over ``entries`` (children before parents, absence-tolerant,
    ENOTEMPTY propagating exactly as the unfused rmdir would have)."""

    __slots__ = ("root", "covered", "entries", "witness")

    def __init__(self, root: str, covered: list[str],
                 entries: dict[str, bool], witness):
        self.root = root
        self.covered = covered              # sorted co-paths of the op
        self.entries = entries              # path -> is_dir
        self.witness = witness              # namespace.RemoveWitness | None

    def fallback_order(self) -> list[tuple[str, bool]]:
        """Entries deepest-first so children go before their parents."""
        return sorted(self.entries.items(),
                      key=lambda kv: (-kv[0].count("/"), kv[0]))


class Fuser:
    """The peephole pass.  Stateless apart from its counters; the
    scheduler provides the locking context (``fuse_tip``/``elide_chain``).

    ``cost_source`` is the backend's CostModel entry point
    (``StorageBackend.cost_hint`` — may return None) and is the preferred
    sizing signal: each clamp asks for its own op *class* ("write",
    "remove_tree", "rename"), so a backend whose rename is structurally
    expensive sizes rename elision differently from write coalescing.
    ``bdp_source`` is the older single-number probe
    (``LatencyBackend.bdp_bytes``), kept as the fallback for backends
    predating the protocol."""

    def __init__(self, policy: FusionPolicy, stats, bdp_source=None,
                 cost_source=None):
        self.policy = policy
        self.stats = stats
        self._bdp = bdp_source
        self._cost = cost_source
        self._slock = threading.Lock()   # exact counters across shards

    # -- adaptive cost-model sizing ------------------------------------

    def _bdp_for(self, op: str):
        """Bandwidth-delay product for one op class: the cost hint when
        the backend has one, else the legacy scalar probe, else None."""
        if self._cost is not None:
            hint = self._cost(op, 0)
            if hint is not None:
                return hint.bdp_bytes()
        if self._bdp is not None:
            return self._bdp()
        return None

    def effective_max_bytes(self) -> int:
        """The write-coalescing byte cap for one fused op: ~2x the
        measured BDP, clamped so the policy bounds always win."""
        pol = self.policy
        if not pol.adaptive_max_bytes:
            return pol.max_bytes
        bdp = self._bdp_for("write")
        if not bdp:
            return pol.max_bytes
        eff = max(pol.min_adaptive_bytes,
                  min(int(pol.bdp_multiplier * bdp), pol.max_bytes))
        self.stats.adaptive_max_bytes = eff   # latest clamp, observability
        return eff

    def effective_remove_entries(self) -> int:
        """How many directory entries one fused ``remove_tree`` may cover:
        ~2x BDP worth of ~256-byte dirents, within the policy bounds."""
        pol = self.policy
        if not pol.adaptive_max_bytes:
            return pol.max_remove_entries
        bdp = self._bdp_for("remove_tree")
        if not bdp:
            return pol.max_remove_entries
        return max(pol.min_remove_entries,
                   min(int(pol.bdp_multiplier * bdp / 256),
                       pol.max_remove_entries))

    # -- rule 1: write coalescing --------------------------------------

    def absorb_write(self, sched, path: str, offset: int, data: bytes,
                     region: object, on_absorb=None) -> bool:
        """``on_absorb`` runs under the op's lock on success — the engine
        updates its write-through stat cache there, so a fast-failing
        fused op's error-path invalidation (at completion, strictly after
        the lock is released) always wins over the mocked entry."""
        pol = self.policy
        if not (pol.enabled and pol.coalesce_writes):
            return False

        def attempt(op) -> bool:
            pl = op.payload
            if (op.kind != "write" or not isinstance(pl, WritePayload)
                    or op.region is not region):
                return False
            if (pl.n_segments >= pol.max_segments
                    or pl.nbytes + len(data) > self.effective_max_bytes()):
                return False
            pl.add(offset, data)
            with self._slock:
                self.stats.fused_writes += 1
            if on_absorb is not None:
                on_absorb()
            return True

        return sched.fuse_tip(path, attempt)

    # -- rule 2: metadata folding --------------------------------------

    def absorb_meta(self, sched, kind: str, path: str, args: tuple,
                    region: object, on_absorb=None) -> bool:
        if not (self.policy.enabled and self.policy.fold_metadata):
            return False

        def attempt(op) -> bool:
            pl = op.payload
            if (op.kind != kind or not isinstance(pl, MetaPayload)
                    or op.region is not region):
                return False
            # truncate is only last-wins when it keeps shrinking: a shrink
            # followed by a grow zero-pads the cut region, which the grow
            # alone would not (chmod/utimens are pure last-wins)
            if kind == "truncate" and args[0] > pl.args[0]:
                return False
            pl.args = args
            with self._slock:
                self.stats.folded_meta += 1
            if on_absorb is not None:
                on_absorb()
            return True

        return sched.fuse_tip(path, attempt)

    # -- rule 3: unlink elision ----------------------------------------

    def elide_for_unlink(self, sched, path: str, region: object) -> bool:
        """Remove the pending create/write/metadata chain on ``path`` from
        the op stream ahead of its unlink.  Returns True iff anything was
        elided — the caller must then make the unlink tolerant of the
        file's absence (the create that would have produced it is gone,
        and an implicit-create write may be gone too)."""
        if not (self.policy.enabled and self.policy.elide_unlinked):
            return False

        def eligible(op) -> bool:
            return op.kind in ELIDABLE_KINDS and op.region is region

        elided = sched.elide_chain(path, eligible)
        if not elided:
            return False
        dropped = sum(op.payload.nbytes for op in elided
                      if isinstance(op.payload, WritePayload))
        with self._slock:
            self.stats.elided_ops += len(elided)
            self.stats.bytes_elided += dropped
        return True

    # -- rule 4: cross-path bulk remove --------------------------------

    def prepare_bulk_remove(self, sched, overlay, root: str,
                            region: object) -> BulkRemovePayload | None:
        """Collapse the pending removals under ``root`` into one vectored
        ``remove_tree`` backend call.

        Fires only when the namespace overlay proves the subtree: every
        reachable directory's membership is overlay-known, and no entry is
        still *present* (present entries carry no pending removal — an
        admitted unlink/rmdir marks its path absent immediately — so a
        present entry means the rmdir would correctly fail ENOTEMPTY and
        must not be rewritten).  Same-region pending unlink/rmdir/child-
        remove_tree ops directly under the known directories are elided —
        their removal duty transfers to the fused call; ineligible ones
        (sealed, claimed, another region's) simply run first, ordered by
        the fused op's dependency edges, and the tolerant ``remove_tree``
        mops up what remains.

        With ``reverify_provisional`` the proof may rest on *provisional*
        directories — mkdirs admitted but not yet executed (the
        extract-then-rmtree-in-one-breath shape).  The overlay then hands
        back a ``RemoveWitness`` watching those mkdirs; the fused op's DAG
        edges already order it after every one of them, so by execution
        time each has been promoted (created fresh) or demoted
        (pre-existing / failed) and the executor picks the vectored call
        or the byte-identical per-entry fallback accordingly.  A child
        fused removal absorbed by this one donates its witness: the
        parent inherits every still-unproven directory underneath.

        Returns the fused op's ``BulkRemovePayload`` (covered paths give
        it its dependency edges and error-invalidation scope), or None
        when the per-entry path must be taken."""
        pol = self.policy
        if not (pol.enabled and pol.bulk_remove):
            return None
        sub = overlay.subtree_for_removal(
            root, allow_provisional=pol.reverify_provisional)
        if sub is None:
            return None
        files, dirs, witness = sub

        def decline():
            if witness is not None:
                overlay.release_witness(witness)
            return None

        if files:
            return decline()  # will not be empty: plain rmdir reports it
        covered: set[str] = set()
        entries: dict[str, bool] = {}    # path -> is_dir, for the fallback
        candidates: dict[int, object] = {}
        for d in (root, *dirs):
            for op in sched.pending_structural_children(d):
                if op.kind not in REMOVAL_KINDS or id(op) in candidates:
                    continue
                if not all(p != root and is_under(p, root)
                           for p in op.paths):
                    continue
                candidates[id(op)] = op
                covered.update(op.paths)
                if op.kind == "unlink":
                    entries.setdefault(op.paths[0], False)
                elif op.kind == "rmdir":
                    entries[op.paths[0]] = True
                else:   # a child fused remove_tree: absorb its manifest
                    pl = op.payload
                    if isinstance(pl, BulkRemovePayload):
                        entries.update(pl.entries)
                        entries[pl.root] = True
                        if pl.witness is not None:
                            witness = overlay.merge_witness(witness,
                                                            pl.witness)
                    else:
                        entries[op.paths[0]] = True
        if dirs and not set(dirs) <= covered:
            return decline()  # a present dir with no pending removal
        if len(covered) > self.effective_remove_entries():
            return decline()  # batch larger than the adaptive clamp allows
        elided = 0
        for op in candidates.values():
            with op.flock:
                if (op.completed or op.claimed or op.sealed or op.cancelled
                        or op.elided or op.region is not region):
                    continue
                op.elided = True
                elided += 1
        if not elided:
            return decline()  # nothing rewritable: plain rmdir is as good
        with self._slock:
            self.stats.bulk_removes += 1
            self.stats.elided_ops += elided
        return BulkRemovePayload(root, sorted(covered), entries, witness)

    # -- rule 5: cost-gated rename retarget ----------------------------

    def rename_retarget_wanted(self) -> bool:
        """Is the retarget rule armed?  ``retarget_renames=True`` forces
        it, False disables it; the default ``"auto"`` consults the cost
        model: fire only when a rename round-trip genuinely costs at
        least ``rename_cost_ratio`` times a create (copy+delete media)."""
        pol = self.policy
        if not (pol.enabled and pol.elide_unlinked):
            return False
        if pol.retarget_renames is True:
            return True
        if pol.retarget_renames != "auto":
            return False
        if self._cost is None:
            return False
        rename = self._cost("rename", 0)
        create = self._cost("create", 0)
        if rename is None or create is None:
            return False
        base = create.cost_s() or 1e-9
        return rename.cost_s() >= pol.rename_cost_ratio * base

    def capture_for_rename(self, sched, path: str,
                           region: object) -> list | None:
        """Capture the source path's entire pending chain for a rename
        retarget: every pending op must be elidable and same-region, and
        the chain must bottom at the pending ``create`` (the file's whole
        backend lifetime is still unexecuted — nothing exists at the
        source for a backend rename to move).  All-or-nothing via
        ``OpScheduler.capture_chain``: on success the ops are already
        marked elided and returned oldest-first for the caller to replay
        at the destination; on any ineligible op nothing is touched and
        the plain backend rename proceeds."""
        def eligible(op) -> bool:
            return op.kind in RETARGET_KINDS and op.region is region

        chain = sched.capture_chain(path, eligible, anchor_kind="create")
        if not chain:
            return None
        with self._slock:
            self.stats.renames_retargeted += 1
            self.stats.elided_ops += len(chain)
        return chain
