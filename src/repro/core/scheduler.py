"""Scheduler layer: per-path FIFO + cross-path DAG edges, sharded by path.

This is the bottom third of the engine split (scheduler / optimizer /
executor).  It owns *which op may run when* and nothing else:

* per-path FIFO order via a ``last_op`` map (two ops touching the same path
  execute in submission order);
* cross-path edges for the cases per-path order cannot see (create under a
  pending mkdir, readdir racing child creation, rename spanning two paths);
* the in-flight budget (submission blocks at ``max_inflight``), the
  per-shard ready queues the executor drains, and the poison/close
  lifecycle.

Dispatch architecture
---------------------

PR 2 sharded *submission* state; dispatch still funnelled every ready op
through one global deque + condition variable, so at high worker counts
the scheduler itself became the latency the engine claims to hide.  Ready
ops now live in per-shard deques aligned to the path-hash shards:

* a ready op is enqueued on its first path's home shard;
* pool worker ``i`` of ``W`` owns the shards ``s`` with ``s % W == i`` and
  pops from them FIFO (every shard has exactly one owner, so stealing off
  still drains everything);
* a worker whose owned shards are dry *steals* from the tail of a victim
  shard's deque (``stats.steals``); stealing is what keeps uneven per-shard
  load balanced across the pool;
* each shard additionally carries a **low-priority lane** (``rq_lo``) for
  *speculative* ops — the metadata-prefetch pipeline's advisory batch
  reads (``submit_speculative``): budget-counted and drained like any
  other op (poison/close/drain all see them), but taking and granting no
  DAG edges, popped (and stolen) only when every normal lane in reach is
  dry, and never recorded in the ledger — prefetch work fills
  otherwise-idle workers and nothing else;
* only when every shard is empty does a worker fall back to the single
  parking lot — one condition variable on the control lock
  (``stats.parks``).  Producers take the control lock only to wake parked
  workers, so the busy-pool fast path never touches a global lock to pop.

Multi-tenant fair dispatch + admission control (PR 10)
------------------------------------------------------

With tenants registered (``register_tenant``; a zero-tenant engine takes
exactly the legacy paths above, byte-identical schedules included), every
ready-lane pop — owned-shard head and steal tail alike — honours a
**deficit-weighted round-robin credit** per tenant: an op whose tenant
holds ``deficit >= 1`` dispatches and spends one credit
(``TenantStats.credits_spent``); when a lane holds only broke tenants'
ops, every tenant's deficit is replenished in proportion to its weight
(the lowest weight maps to exactly one credit per round; accumulation is
capped at four rounds so an idle tenant cannot bank an unbounded burst)
and the scan re-runs — one replenish always funds a pop.  Untenanted
ops (engine-internal work: spill chunks, prefetch batches) always
dispatch.  A tenant's burst therefore cannot starve a neighbour's
latency: each round interleaves dispatch weight-proportionally however
deep any single backlog runs.  A steal that dispatches a tenant's op
additionally counts ``TenantStats.steals_served`` — the cross-worker
capacity the engine donated to that tenant.

Admission control composes two releases ahead of blocking.  At global
in-flight saturation the submitter first **sheds** the oldest queued
speculative op — the low-priority lanes are advisory by contract
(prefetch/read-ahead/spill chunks re-issue or degrade, never corrupt) —
retiring it cancelled and taking its budget slot
(``stats.admission_sheds``).  Only when nothing is sheddable does the
submitter block, and then **per-tenant backpressure** applies: a tenant
over its weight-share of the budget keeps waiting while an under-share
tenant is parked on the budget too, so one tenant saturating the window
backpressures its own submits, never a neighbour's (completions
broadcast the budget condition in tenant mode so the under-share waiter
always gets its look).

Lock architecture
-----------------

The seed engine serialized *all* submit/complete traffic under one global
lock.  Here submission state is sharded by path hash: each shard's lock
protects only that shard's ``last_op`` and ``pending_children`` maps, so
disjoint-path submissions and completions proceed in parallel.  A small
control lock remains for the in-flight budget, the parking lot and
lifecycle flags; it is held only for counter updates and parking, never
while wiring dependencies.

Lock order (never acquired in reverse): shard locks (ascending index)
-> per-op ``flock`` -> control lock -> per-shard ready-queue ``rlock``
(the deepest leaf: a parked worker rescans the ready deques while holding
the control lock, so an rlock holder must never wait on anything).  Leaf
locks (stat cache, ledger, fusion stats) nest under any of these.

PR 10 additions keep that order: per-tenant DWRR ``deficit`` counters
(and the credit/steal tallies on ``TenantStats``) are mutated only while
holding a ready-queue ``rlock`` — cooperatively serialized in sim mode,
advisory under real threads — and never take another lock; per-tenant
``inflight``/``waiting``/``poisoned`` bookkeeping lives strictly under
the control lock, exactly like the global budget it refines.  The
admission-control shed pops a speculative lane under ctl -> rlock, the
already-legal rescan nesting.

Per-op flags (``claimed``/``sealed``/``elided``/``completed``) live under
the op's own ``flock`` so the optimizer can mutate a pending op's payload
race-free against the executor claiming it:

* ``claimed``  — an executor owns the op; its payload is frozen.
* ``sealed``   — an observation point (read / barrier / any sync op) has
  scheduled a wait on this op; it must execute exactly as submitted.
  Observation classification is per-*answer*, not per-call: a readdir or
  stat satisfied by the namespace overlay (core/namespace.py) never
  reaches the scheduler and seals nothing; only an overlay miss submits
  the sync op that pins its dependencies.
* ``elided``   — the optimizer proved the op's effects are invisible at
  every observation point (e.g. writes to a path unlinked in the same
  window); the executor completes it without touching the backend.
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from typing import Any, Callable, Optional

from .backend import is_under, norm_path, parent_of
from .errors import EnginePoisonedError

# ops that change the namespace under their parent directory — a readdir /
# rmdir / rename of the parent must wait for *all* of these (siblings do not
# chain with each other, so per-path order alone cannot express this).
STRUCTURAL = {"mkdir", "rmdir", "create", "unlink", "rename", "symlink",
              "link", "remove_tree"}
# ops that must observe a complete namespace under their own path.  A fused
# remove_tree lists every covered entry in its paths, so this edge also
# orders it after any pending straggler beneath the tree.
NEEDS_CHILDREN = {"rmdir", "readdir", "rename", "remove_tree"}

DEFAULT_SHARDS = 16


class _TenantState:
    """Scheduler-side record of one registered tenant: the DWRR credit,
    the per-tenant slice of the in-flight budget, and the tenant-scoped
    poison flag.  ``stats`` is the engine's ``TenantStats`` sub-snapshot
    (a leaf: counters bumped under rlock/ctl, never read under a lock
    the snapshot path takes).  See the module docstring for which lock
    guards which field."""

    __slots__ = ("name", "weight", "stats", "deficit", "inflight",
                 "waiting", "poisoned", "spill")

    def __init__(self, name: str, weight: float, stats):
        self.name = name
        self.weight = max(1e-6, float(weight))
        self.stats = stats
        self.deficit = 1.0      # DWRR credit (rlock; see docstring)
        self.inflight = 0       # admitted, not yet completed (ctl)
        self.waiting = 0        # submitters parked on the budget (ctl)
        self.poisoned = False   # tenant-scoped abort_on_error (ctl)
        self.spill = None       # the tenant's own SpillManager, if armed


class _Op:
    __slots__ = ("seq", "kind", "paths", "fn", "done", "error", "result",
                 "remaining_deps", "dependents", "cancelled", "submitted_at",
                 "started_at", "finished_at", "eager", "region",
                 "flock", "completed", "claimed", "sealed", "elided",
                 "payload", "prev_same_path", "wired", "speculative",
                 "tenant")

    def __init__(self, seq: int, kind: str, paths: tuple[str, ...],
                 fn: Callable[[], Any], eager: bool = True,
                 region: object = None, payload: object = None,
                 tenant: Optional[_TenantState] = None):
        self.seq = seq
        self.kind = kind
        self.paths = paths
        self.fn = fn
        self.eager = eager
        self.region = region  # active Transaction at submission, if any
        self.done = threading.Event()
        self.error: BaseException | None = None
        self.result: Any = None
        self.remaining_deps = 0
        self.dependents: list[_Op] = []
        self.cancelled = False
        self.submitted_at = time.monotonic()
        self.started_at = 0.0
        self.finished_at = 0.0
        # -- optimizer state (guarded by flock) --
        self.flock = threading.Lock()
        self.completed = False        # dependents released; op is history
        self.claimed = False          # an executor owns it; payload frozen
        self.sealed = False           # an observation point pinned it
        self.elided = False           # optimizer removed it from the stream
        self.payload = payload        # fusable payload (fusion.py), or None
        self.prev_same_path: Optional[_Op] = None  # chain link for peepholes
        # wiring stamp: drawn while the op still holds its shard locks at
        # the end of dependency wiring.  Cross-shard edges added *outside*
        # an op's own locked region (the rename chain-tip pass) may only
        # point at ops with a smaller stamp — every edge then strictly
        # decreases the stamp, which keeps the DAG acyclic (0 = unwired).
        self.wired = 0
        # speculative (advisory) op: rides the low-priority ready deques,
        # takes and grants no DAG edges, never lands in the ledger
        self.speculative = False
        # owning tenant's _TenantState (None = engine-internal work):
        # scopes DWRR credit, the budget slice, poison and the ledger tag
        self.tenant = tenant


class _Shard:
    __slots__ = ("lock", "last_op", "pending_children", "rlock", "rq",
                 "rq_lo")

    def __init__(self):
        self.lock = threading.Lock()
        self.last_op: dict[str, _Op] = {}       # last pending op per path
        # every pending structural op, grouped by parent dir (seq -> op)
        self.pending_children: dict[str, dict[int, _Op]] = {}
        # the shard's ready deque: owner pops the head, thieves the tail
        self.rlock = threading.Lock()
        self.rq: deque[_Op] = deque()
        # low-priority lane: speculative (prefetch) ops, drained only when
        # rq is dry — real work always dispatches first
        self.rq_lo: deque[_Op] = deque()


class OpScheduler:
    """Sharded DAG scheduler.  ``stats`` is the engine's EngineStats — the
    scheduler updates submitted/executed/queue-depth counters under its
    control lock so they stay exact under concurrency."""

    def __init__(self, stats, *, max_inflight: int = 300,
                 shards: int = DEFAULT_SHARDS, work_stealing: bool = True,
                 sim=None):
        self.stats = stats
        self.max_inflight = int(max_inflight)
        self.work_stealing = bool(work_stealing)
        # discrete-event mode (core/simclock.py): every real wait in this
        # class is bracketed with sim.block_begin()/block_end() so the
        # simulation can advance virtual time past a blocked worker, and
        # park-wakeup / steal-probe costs are charged on the virtual
        # timeline.  block_begin is always called while still holding the
        # condition's underlying lock (no lost wakeups: the next token
        # holder cannot complete a notify until our wait begins), and
        # block_end only after releasing it (a token-less thread must not
        # hold a lock a running thread can contend).
        self._sim = sim
        self._shards = [_Shard() for _ in range(max(1, int(shards)))]
        self._nshards = len(self._shards)
        self._seq = itertools.count(1)
        self._wire_seq = itertools.count(1)   # wiring stamps (see _Op.wired)
        # control lock: budget + parking lot + lifecycle (held briefly)
        self._ctl = threading.Lock()
        self._ready_cv = threading.Condition(self._ctl)   # the parking lot
        self._idle_cv = threading.Condition(self._ctl)
        self._budget_cv = threading.Condition(self._ctl)
        self._slock = threading.Lock()    # exact steal counter (leaf)
        self._parked = 0                  # workers waiting in the lot
        self._inflight = 0
        self._poisoned = False
        self._closed = False
        # multi-tenant state (empty dict = legacy single-job engine; every
        # tenancy branch below gates on it so zero-tenant schedules stay
        # byte-identical to pre-PR 10)
        self._tenants: dict[str, _TenantState] = {}
        self._total_weight = 0.0
        self._min_weight = 1.0

    # ------------------------------------------------------------------
    # tenancy
    # ------------------------------------------------------------------

    def register_tenant(self, name: str, weight: float,
                        stats) -> _TenantState:
        """Register one tenant and return its scheduler-side state.
        ``stats`` is the engine's ``TenantStats`` for this tenant (the
        scheduler bumps credits_spent / steals_served on it)."""
        with self._ctl:
            if name in self._tenants:
                raise ValueError(f"tenant {name!r} already registered")
            ts = _TenantState(name, weight, stats)
            self._tenants[name] = ts
            self._total_weight = sum(
                t.weight for t in self._tenants.values())
            self._min_weight = min(
                t.weight for t in self._tenants.values())
            return ts

    def _tenant_share(self, ts: _TenantState) -> int:
        """The tenant's weight-proportional slice of the in-flight budget
        (its backpressure threshold — never an absolute cap: an alone
        tenant may use the whole window)."""
        return max(1, int(self.max_inflight * ts.weight
                          / max(self._total_weight, 1e-9)))

    def _must_defer(self, ts: Optional[_TenantState]) -> bool:
        """Caller holds ctl.  True when ``ts`` is over its weight-share of
        the budget while some *under-share* tenant is parked waiting for a
        slot — the over-budget tenant alone backpressures."""
        if ts is None or not self._tenants:
            return False
        if ts.inflight < self._tenant_share(ts):
            return False
        for t in self._tenants.values():
            if (t is not ts and t.waiting > 0
                    and t.inflight < self._tenant_share(t)):
                return True
        return False

    def _replenish_credits(self) -> None:
        """DWRR replenish (caller holds an rlock): every tenant gains
        weight-proportional credit — the lowest weight earns exactly one
        op per round, so one replenish always funds the next pop —
        accumulation capped at four rounds of burst."""
        mw = self._min_weight
        for t in self._tenants.values():
            gain = t.weight / mw
            t.deficit = min(t.deficit + gain, 4.0 * max(1.0, gain))

    def _pop_lane(self, dq: deque, *, tail: bool) -> Optional[_Op]:
        """Pop one op from a ready lane (caller holds its rlock): plain
        FIFO head / steal tail with no tenants registered, else the first
        op — in the same scan direction — whose tenant can afford a DWRR
        credit (untenanted and poisoned-tenant ops always dispatch: the
        former are engine-internal, the latter drain as cancellations and
        must not rot in the lane)."""
        if not dq:
            return None
        if not self._tenants:
            return dq.pop() if tail else dq.popleft()
        for _round in (0, 1):
            order = (range(len(dq) - 1, -1, -1) if tail
                     else range(len(dq)))
            for i in order:
                ts = dq[i].tenant
                if ts is None or ts.poisoned or ts.deficit >= 1.0:
                    op = dq[i]
                    del dq[i]
                    if ts is not None and not ts.poisoned:
                        ts.deficit -= 1.0
                        ts.stats.credits_spent += 1
                    return op
            self._replenish_credits()
        return dq.pop() if tail else dq.popleft()   # unreachable backstop

    # ------------------------------------------------------------------
    # sharding helpers
    # ------------------------------------------------------------------

    def _shard_of(self, path: str) -> _Shard:
        return self._shards[hash(path) % self._nshards]

    def _lock_shards(self, paths) -> list[_Shard]:
        """Acquire the shards covering ``paths`` in ascending index order
        (deadlock-free for multi-path ops like rename)."""
        idx = sorted({hash(p) % self._nshards for p in paths})
        shards = [self._shards[i] for i in idx]
        for s in shards:
            s.lock.acquire()
        return shards

    @staticmethod
    def _unlock_shards(shards) -> None:
        for s in reversed(shards):
            s.lock.release()

    # ------------------------------------------------------------------
    # submission
    # ------------------------------------------------------------------

    def submit(self, kind: str, paths: tuple[str, ...],
               fn: Callable[[], Any], *, eager: bool,
               region: object = None, payload: object = None,
               tenant: Optional[_TenantState] = None,
               on_admit: Callable[[], None] | None = None) -> _Op:
        """Admit one op: budget gate, dependency wiring, ready enqueue.
        Paths must already be normalized.  ``on_admit`` runs after the
        budget admits the op but before it is published to the DAG — i.e.
        strictly before the op can possibly execute (the engine updates
        its write-through stat cache there, so a fast-failing op's
        error-path invalidation, which happens at completion, always wins
        over the ACK-time mocked entry).  ``tenant`` scopes the op to a
        registered tenant: its poison gate, its budget slice, its DWRR
        credit."""
        while True:
            hooked = False
            shed: Optional[_Op] = None
            with self._ctl:
                if self._poisoned or (tenant is not None
                                      and tenant.poisoned):
                    raise EnginePoisonedError(
                        "cannyfs engine poisoned by an earlier deferred error")
                if self._closed:
                    raise RuntimeError("engine is closed")
                # budget: block the *caller* — the paper's in-flight cap.
                # In tenant mode an over-share tenant additionally yields
                # to under-share waiters (per-tenant backpressure).
                if (self._inflight < self.max_inflight
                        and not self._must_defer(tenant)):
                    seq = next(self._seq)
                    self._inflight += 1
                    if tenant is not None:
                        tenant.inflight += 1
                        tenant.stats.ops += 1
                    self.stats.submitted += 1
                    self.stats.op_counts[kind] = \
                        self.stats.op_counts.get(kind, 0) + 1
                    self.stats.max_queue_depth = max(
                        self.stats.max_queue_depth, self._inflight)
                    break
                # saturated: shed the oldest queued speculative op before
                # blocking anyone — advisory lanes degrade, real work
                # proceeds (tenant mode only; legacy engines keep the
                # exact pre-PR 10 blocking behaviour)
                if self._tenants and self._inflight >= self.max_inflight:
                    shed = self._take_sheddable_locked()
                    if shed is not None:
                        self._inflight -= 1
                        if shed.tenant is not None:
                            shed.tenant.inflight -= 1
                        self.stats.admission_sheds += 1
                        self.stats.cancelled += 1
                if shed is None:
                    if tenant is not None:
                        tenant.waiting += 1
                    if self._sim is not None:
                        self._sim.block_begin(self._budget_cv)
                        hooked = True
                    self._budget_cv.wait()
                    if tenant is not None:
                        tenant.waiting -= 1
            if shed is not None:
                self._retire_shed(shed)
                continue
            if hooked:
                self._sim.block_end()
        op = _Op(seq, kind, paths, fn, eager=eager, region=region,
                 payload=payload, tenant=tenant)
        if on_admit is not None:
            on_admit()

        relevant = set(paths)
        for p in paths:
            relevant.add(parent_of(p))
        deps: list[_Op] = []
        seen: set[int] = set()

        def add_dep(d: Optional[_Op]) -> None:
            if d is None or id(d) in seen:
                return
            seen.add(id(d))
            with d.flock:
                if d.completed:
                    return
                d.dependents.append(op)
                # observation point: a sync op waiting on d pins it —
                # the optimizer may no longer rewrite or remove it
                if not eager:
                    d.sealed = True
            deps.append(d)

        shards = self._lock_shards(relevant)
        try:
            for p in paths:
                shard = self._shard_of(p)
                prev = shard.last_op.get(p)
                if prev is not None and len(paths) == 1:
                    op.prev_same_path = prev   # peephole chain link
                add_dep(prev)
                # an op under a directory whose creation/rename is pending
                # must wait for it
                add_dep(self._shard_of(parent_of(p)).last_op.get(parent_of(p)))
            if kind in NEEDS_CHILDREN:
                for p in paths:
                    kids = self._shard_of(p).pending_children.get(p, {})
                    for d in list(kids.values()):
                        add_dep(d)
            for p in paths:
                self._shard_of(p).last_op[p] = op
            if kind in STRUCTURAL:
                for p in paths:
                    par = parent_of(p)
                    self._shard_of(par).pending_children.setdefault(
                        par, {})[op.seq] = op
            op.wired = next(self._wire_seq)   # stamped inside the region
        finally:
            self._unlock_shards(shards)
        # rename subtree-tail pass: a rename moves *content*, so it must
        # run after every pending op anywhere under either endpoint —
        # structural or not.  Discovery is a per-prefix sweep of each
        # shard's last_op map: every pending op, whatever its kind,
        # publishes its chain tip there, so any path under either root is
        # found directly — including a non-structural op on a path whose
        # structural ancestors already drained (e.g. a chmod three levels
        # down whose create left the window; PR 5 closed PR 4's gap here,
        # whose BFS over pending_children could reach paths only through
        # pending *structural* anchors).  Depending on the eligible tip
        # orders after its whole chain transitively.  One shard lock at a
        # time; only ops wired strictly before this one are eligible — a
        # tip wired later may already depend on this op through the
        # parent-directory edge, and the stamp guard is what keeps the
        # DAG acyclic (see _Op.wired).
        if kind == "rename":
            for sh in self._shards:
                with sh.lock:
                    for kp, tip in list(sh.last_op.items()):
                        if kp in relevant:
                            continue
                        if not any(is_under(kp, r) for r in paths):
                            continue
                        cur = tip
                        while cur is not None and not 0 < cur.wired < op.wired:
                            cur = cur.prev_same_path
                        add_dep(cur)
        # publish the dep count last: deps completing mid-wiring have
        # already decremented remaining_deps below zero, so the sum
        # lands on the true outstanding count exactly once
        with op.flock:
            op.remaining_deps += len(deps)
            ready_now = op.remaining_deps == 0
        if ready_now:
            self._push_ready(op)
        return op

    def submit_speculative(self, kind: str, paths: tuple[str, ...],
                           fn: Callable[[], Any],
                           payload: object = None) -> Optional[_Op]:
        """Admit one *advisory* op: budget-counted and drained like any
        other, but it takes no DAG edges, publishes nothing to the
        per-path maps, and rides the low-priority ready lane — real work
        always dispatches first and never waits on it (racing-mutation
        correctness is the overlay's speculation tickets' job, not the
        scheduler's).  Returns None — never blocks, never raises — when
        the engine is poisoned/closed or the in-flight budget is full:
        speculation yields instead of backpressuring the caller."""
        with self._ctl:
            if (self._poisoned or self._closed
                    or self._inflight >= self.max_inflight):
                return None
            seq = next(self._seq)
            self._inflight += 1
            self.stats.submitted += 1
            self.stats.op_counts[kind] = self.stats.op_counts.get(kind, 0) + 1
            self.stats.max_queue_depth = max(self.stats.max_queue_depth,
                                             self._inflight)
        op = _Op(seq, kind, paths, fn, eager=True, payload=payload)
        op.speculative = True
        self._push_ready(op)
        return op

    def _take_sheddable_locked(self) -> Optional[_Op]:
        """Caller holds ctl.  Remove and return the oldest queued
        speculative op across every low-priority lane (ctl -> rlock is
        the legal rescan nesting), or None when the lanes are dry."""
        for sh in self._shards:
            with sh.rlock:
                if sh.rq_lo:
                    return sh.rq_lo.popleft()
        return None

    def _retire_shed(self, op: _Op) -> None:
        """Finish a shed speculative op outside ctl: it left the lane, no
        worker will ever claim it, so the completion bookkeeping the
        executor would have done happens here.  Speculative ops hold no
        DAG edges and publish nothing to the per-path maps, so cancel +
        payload callback + done is the whole protocol."""
        op.cancelled = True
        cb = getattr(op.payload, "on_cancelled", None)
        if cb is not None:
            cb()
        op.done.set()
        if self._sim is not None:
            self._sim.wake(op.done)

    def _home_shard(self, op: _Op) -> _Shard:
        return self._shards[hash(op.paths[0]) % self._nshards]

    def _enqueue_ready(self, op: _Op) -> None:
        """Append to the op's home-shard ready deque (rlock is the deepest
        leaf: never held while taking any other lock).  Speculative ops
        land on the low-priority lane."""
        sh = self._home_shard(op)
        with sh.rlock:
            (sh.rq_lo if op.speculative else sh.rq).append(op)

    def _notify_ready(self, n: int) -> None:
        """Wake parked workers for ``n`` newly enqueued ops.  Caller holds
        the control lock.  With stealing on, any worker can take any op,
        so waking exactly ``n`` avoids a thundering herd; with stealing
        off an arbitrary woken worker may not own the op's shard and
        would re-park, so broadcast."""
        if not self._parked:
            return
        if self.work_stealing:
            if self._sim is not None:
                # sim mode: the parked workers' READY transitions happen
                # HERE, on the notifier's (token-holding) side, via the
                # wake channel — a woken worker mutates no sim state
                # between its real wait returning and its block_end(), so
                # every handoff lands in deterministic token order
                woken = self._sim.wake(self._ready_cv, n)
                self._parked -= woken
                self._ready_cv.notify(woken)
            else:
                self._ready_cv.notify(n)
        else:
            if self._sim is not None:
                self._sim.wake(self._ready_cv)
                self._parked = 0
            self._ready_cv.notify_all()

    def _push_ready(self, op: _Op) -> None:
        self._enqueue_ready(op)
        with self._ctl:
            self._notify_ready(1)

    # ------------------------------------------------------------------
    # optimizer hooks
    # ------------------------------------------------------------------

    def fuse_tip(self, path: str, attempt: Callable[[_Op], bool]) -> bool:
        """Offer the pending tip op on ``path`` to the optimizer.

        ``attempt(op)`` runs under the shard lock *and* the op's flock with
        the op guaranteed unclaimed/unsealed/uncompleted — it may mutate the
        op's payload and must return True iff it absorbed the new work."""
        shard = self._shard_of(path)
        with shard.lock:
            tip = shard.last_op.get(path)
            if tip is None:
                return False
            with tip.flock:
                if (tip.completed or tip.claimed or tip.sealed
                        or tip.cancelled or tip.elided):
                    return False
                return attempt(tip)

    def elide_chain(self, path: str, eligible: Callable[[_Op], bool]) -> list[_Op]:
        """Walk the pending same-path chain backwards from the tip, marking
        every op ``eligible`` accepts as elided (stops at the first claimed,
        sealed, completed, cancelled or rejected op).  Returns the ops
        elided, newest first.  Elided ops still flow through the DAG — the
        executor completes them without running their fn."""
        shard = self._shard_of(path)
        out: list[_Op] = []
        with shard.lock:
            cur = shard.last_op.get(path)
            while cur is not None and cur.paths == (path,):
                with cur.flock:
                    if (cur.completed or cur.claimed or cur.sealed
                            or cur.cancelled or cur.elided):
                        break
                    if not eligible(cur):
                        break
                    cur.elided = True
                    nxt = cur.prev_same_path
                out.append(cur)
                cur = nxt
        return out

    def capture_chain(self, path: str, eligible: Callable[[_Op], bool],
                      anchor_kind: str) -> Optional[list[_Op]]:
        """All-or-nothing elision of the *entire* pending chain on
        ``path``: succeeds only when every pending op is ``eligible`` and
        the oldest one is an ``anchor_kind`` op (the path's whole backend
        lifetime is still pending), in which case all of them are marked
        elided atomically and returned oldest-first; otherwise nothing is
        touched and None is returned.

        Unlike ``elide_chain`` — which may stop partway, safe for unlink
        (dropping a suffix of the chain loses only work that would be
        deleted anyway) — a partial capture would LOSE DATA for the
        rename-retarget rule: the caller replays the captured payloads at
        another path, so it must own the chain completely or not at all.
        The flocks of the whole chain are therefore acquired and *held*
        together (under the shard lock, tip→oldest) before any op is
        marked: a bottom-of-chain op that is ready can be claimed by a
        worker under its flock alone, and a mark-then-rollback scheme
        would race it.  Holding multiple flocks is deadlock-free here:
        every other code path takes at most one flock at a time and
        never acquires a shard lock while holding one."""
        shard = self._shard_of(path)
        chain: list[_Op] = []
        held: list[_Op] = []
        with shard.lock:
            try:
                cur = shard.last_op.get(path)
                while cur is not None:
                    cur.flock.acquire()
                    held.append(cur)
                    if (cur.completed or cur.claimed or cur.sealed
                            or cur.cancelled or cur.elided
                            or cur.paths != (path,) or not eligible(cur)):
                        return None
                    chain.append(cur)
                    cur = cur.prev_same_path
                if not chain or chain[-1].kind != anchor_kind:
                    return None
                for op in chain:
                    op.elided = True
            finally:
                for op in held:
                    op.flock.release()
        chain.reverse()
        return chain

    def pending_structural_children(self, path: str) -> list[_Op]:
        """Snapshot of the pending structural ops directly under ``path``
        (the bulk-remove pass scans these for collapsible removals)."""
        shard = self._shard_of(path)
        with shard.lock:
            return list(shard.pending_children.get(path, {}).values())

    def has_pending_under(self, path: str) -> bool:
        """True when ``path`` has a pending tip or pending structural
        children — i.e. an observation at ``path`` answered by the
        namespace overlay genuinely avoided sealing something."""
        shard = self._shard_of(path)
        with shard.lock:
            if shard.last_op.get(path) is not None:
                return True
            return bool(shard.pending_children.get(path))

    def seal_path(self, path: str) -> Optional[_Op]:
        """Pin the pending tip on ``path`` (an observation point is about
        to wait on it) and return it, or None if the path is quiescent."""
        shard = self._shard_of(path)
        with shard.lock:
            op = shard.last_op.get(path)
            if op is not None:
                with op.flock:
                    op.sealed = True
        return op

    # ------------------------------------------------------------------
    # executor interface
    # ------------------------------------------------------------------

    def _owned_shards(self, worker: int, workers: int) -> range | tuple:
        """Worker ``worker`` of ``workers`` owns the shards congruent to it
        mod the pool size — every shard has exactly one owner while the
        pool is no wider than the shard count."""
        n = self._nshards
        if workers <= 0 or workers > n:
            return (worker % n,)
        return range(worker % workers, n, workers)

    def _pop_ready(self, worker: int,
                   workers: int) -> tuple[Optional[_Op], bool]:
        """Non-blocking pop: owned shards FIFO first (normal lane, then
        the low-priority speculative lane), then (with stealing on) the
        tail of the first non-empty victim shard — again normal lanes
        before any speculative one, so prefetch work only ever fills
        otherwise-idle workers.  Returns ``(op, stolen)`` — the caller
        charges the steal-probe cost to the virtual timeline, never this
        method, because the parked-worker rescan runs under the control
        lock and sleeping there would deadlock the simulation."""
        shards = self._shards
        owned = self._owned_shards(worker, workers)
        for s in owned:
            sh = shards[s]
            with sh.rlock:
                op = self._pop_lane(sh.rq, tail=False)
            if op is not None:
                return op, False
        for s in owned:
            sh = shards[s]
            with sh.rlock:
                op = self._pop_lane(sh.rq_lo, tail=False)
            if op is not None:
                return op, False
        if not self.work_stealing:
            return None, False
        mine = set(owned)
        n = self._nshards
        for k in range(n):
            s = (worker + k) % n
            if s in mine:
                continue
            sh = shards[s]
            with sh.rlock:
                op = self._pop_lane(sh.rq, tail=True)
            if op is not None:
                with self._slock:
                    self.stats.steals += 1
                    if op.tenant is not None:
                        op.tenant.stats.steals_served += 1
                return op, True
        for k in range(n):
            s = (worker + k) % n
            if s in mine:
                continue
            sh = shards[s]
            with sh.rlock:
                op = self._pop_lane(sh.rq_lo, tail=True)
            if op is not None:
                with self._slock:
                    self.stats.steals += 1
                    if op.tenant is not None:
                        op.tenant.stats.steals_served += 1
                return op, True
        return None, False

    def next_ready(self, worker: int = 0, workers: int = 1) -> Optional[_Op]:
        """Blocking pop for pool worker ``worker`` of ``workers``; None once
        the scheduler is closed and every shard is drained.  Parks on the
        control-lock condition only when all shards are dry; the re-scan
        under the control lock closes the race with producers (who take the
        control lock after enqueueing, so either they see us parked or we
        see their op).  In sim mode the park is bracketed for the event
        queue and the wakeup / steal-probe costs are charged to the virtual
        timeline (outside every lock)."""
        sim = self._sim
        while True:
            op, stolen = self._pop_ready(worker, workers)
            if op is None:
                hooked = False
                with self._ctl:
                    # rescan while holding ctl: rlocks nest under the
                    # control lock, so a producer's enqueue either landed
                    # before this scan or its notify comes after our wait
                    # begins
                    op, stolen = self._pop_ready(worker, workers)
                    if op is None:
                        if self._closed:
                            return None
                        self._parked += 1
                        self.stats.parks += 1
                        if sim is not None:
                            sim.block_begin(self._ready_cv)
                            hooked = True
                        self._ready_cv.wait()
                        if sim is None:
                            self._parked -= 1
                        # sim mode: _notify_ready/close already debited
                        # _parked on the notifier's side (see there)
                if hooked:
                    sim.block_end()
                    if sim.wake_latency_s > 0:
                        sim.sleep(sim.wake_latency_s)
                if op is None:
                    continue
            if sim is not None and stolen and sim.steal_probe_s > 0:
                sim.sleep(sim.steal_probe_s)
            return op

    def on_complete(self, op: _Op) -> None:
        """Release dependents, clean the shard maps, retire the budget
        slot.  Called by the engine after the op ran (or was skipped)."""
        with op.flock:
            op.completed = True
            dependents = op.dependents
            op.dependents = []
            op.prev_same_path = None   # don't anchor the whole chain
        newly_ready: list[_Op] = []
        for d in dependents:
            with d.flock:
                d.remaining_deps -= 1
                if d.remaining_deps == 0:
                    newly_ready.append(d)
        shards = self._lock_shards(
            set(op.paths) | {parent_of(p) for p in op.paths})
        try:
            for p in op.paths:
                shard = self._shard_of(p)
                if shard.last_op.get(p) is op:
                    del shard.last_op[p]
            if op.kind in STRUCTURAL:
                for p in op.paths:
                    par = parent_of(p)
                    kids = self._shard_of(par).pending_children.get(par)
                    if kids is not None:
                        kids.pop(op.seq, None)
                        if not kids:
                            del self._shard_of(par).pending_children[par]
        finally:
            self._unlock_shards(shards)
        for d in newly_ready:
            self._enqueue_ready(d)
        with self._ctl:
            if newly_ready:
                self._notify_ready(len(newly_ready))
            self._inflight -= 1
            if op.tenant is not None:
                op.tenant.inflight -= 1
            if self._tenants:
                # broadcast in tenant mode: a single notify could keep
                # waking the over-share tenant's deferred submitter while
                # the under-share waiter it must yield to sleeps on
                if self._sim is not None:
                    self._sim.wake(self._budget_cv)
                self._budget_cv.notify_all()
            else:
                if self._sim is not None:
                    self._sim.wake(self._budget_cv, 1)
                self._budget_cv.notify()
            if self._inflight == 0:
                if self._sim is not None:
                    self._sim.wake(self._idle_cv)
                self._idle_cv.notify_all()
        op.done.set()
        if self._sim is not None:
            self._sim.wake(op.done)

    # ------------------------------------------------------------------
    # barriers / lifecycle
    # ------------------------------------------------------------------

    def pending_tip(self, path: str) -> Optional[_Op]:
        shard = self._shard_of(path)
        with shard.lock:
            return shard.last_op.get(path)

    def drain(self) -> None:
        sim = self._sim
        while True:
            hooked = False
            with self._idle_cv:
                if self._inflight == 0:
                    return
                if sim is not None:
                    sim.block_begin(self._idle_cv)
                    hooked = True
                self._idle_cv.wait()
            if hooked:
                sim.block_end()

    @property
    def poisoned(self) -> bool:
        return self._poisoned

    def poison(self, tenant: Optional[_TenantState] = None) -> None:
        """Poison the engine — or, given a tenant, only that tenant's
        failure domain: its flag trips, its queued ops cancel, and every
        other tenant's window stays open and convergent."""
        with self._ctl:
            if tenant is None:
                self._poisoned = True
            elif not tenant.poisoned:
                tenant.poisoned = True
                tenant.stats.poison_trips += 1
            # cancel everything not yet started; their dependents cascade
            queued: list[_Op] = []
            for sh in self._shards:
                with sh.rlock:
                    for dq in (sh.rq, sh.rq_lo):
                        for op in dq:
                            if tenant is None or op.tenant is tenant:
                                queued.append(op)
        for op in queued:
            op.cancelled = True

    def reset_poison(self, tenant: Optional[_TenantState] = None) -> None:
        with self._ctl:
            if tenant is None:
                self._poisoned = False
            else:
                tenant.poisoned = False

    def close(self) -> None:
        with self._ctl:
            self._closed = True
            if self._sim is not None:
                self._sim.wake(self._ready_cv)
                self._parked = 0   # notifier-side accounting (sim mode)
            self._ready_cv.notify_all()

    @property
    def inflight(self) -> int:
        return self._inflight

    # -- merged debugging/introspection views (tests assert on these) ----

    def merged_last_op(self) -> dict[str, _Op]:
        out: dict[str, _Op] = {}
        for s in self._shards:
            with s.lock:
                out.update(s.last_op)
        return out

    def merged_pending_children(self) -> dict[str, dict[int, _Op]]:
        out: dict[str, dict[int, _Op]] = {}
        for s in self._shards:
            with s.lock:
                out.update(s.pending_children)
        return out
