"""Deterministic fault injection for the storage stack.

The paper's central bet — every I/O can be eagerly ACKed because a failure
"will frequently warrant the resubmission of a full job" — is only testable
if the stack can *produce* failures on demand.  This module provides:

* ``FaultRule``  — one failure clause: match by op kind, path glob, call
  window and/or probability; raise a chosen errno (``EACCES``/``ENOSPC``/
  ``EDQUOT``/``EIO``) or a connection loss.  Besides raising, a rule can
  fire as a *torn op* (``outcome="short"``: ``write_at``/``write_vec``
  return a short byte count instead of raising — the engine surfaces the
  tear as a deferred ``ShortWriteError``) or a *latency spike*
  (``outcome="delay"``: the op sleeps ``delay_s`` on the backend's clock
  and then succeeds — slow ops, not failed ops, for the straggler/
  backpressure path) or as a *process death* (``outcome="kill"``: the op
  raises ``ProcessKilled`` and the backend goes dead — every later call
  fails the same way until ``revive()`` — the deterministic SIGKILL
  simulation behind the preemption/resume harness).
* ``FaultPlan``  — a seeded, thread-safe collection of rules.  The same
  seed always yields the same fault schedule, so ledger contents and
  rollback behaviour replay bit-identically in tests.
* ``FaultInjectingBackend`` — decorator that consults a plan before every
  primitive op.  Composable with the other decorators:

      FaultInjectingBackend(QuotaBackend(LatencyBackend(InMemoryBackend())))

* ``QuotaBackend`` — enforces a byte budget (and, with ``max_inodes``, an
  inode budget) so disk-quota exhaustion (a headline error class in the
  paper) emerges organically mid-write/mid-create instead of being
  scripted; rollback's unlinks/rmdirs — and a fused ``remove_tree`` —
  release the charges, which is exactly why the paper's
  roll-back-and-resubmit loop converges.
"""
from __future__ import annotations

import errno as _errno
import fnmatch
import random
import threading
from dataclasses import dataclass, field

from .backend import Clock, RealClock, StorageBackend, is_under, norm_path
from .errors import ProcessKilled

# errno spellings accepted by FaultRule.error (connection loss raises a
# ConnectionResetError, which the engine defers like any other OSError).
ERRNOS = {
    "EACCES": _errno.EACCES,
    "ENOSPC": _errno.ENOSPC,
    "EDQUOT": _errno.EDQUOT,
    "EIO": _errno.EIO,
    "ECONNRESET": _errno.ECONNRESET,
}


OUTCOMES = ("raise", "short", "delay", "kill")


def make_fault(error: str, path: str, *, outcome: str = "raise",
               short_fraction: float = 0.5, delay_s: float = 0.0) -> OSError:
    """Build the fault token for one injected failure, tagged ``.injected``.

    The token is always an OSError (the ``raise`` outcome raises it
    verbatim); for ``short``/``delay`` outcomes it carries the outcome
    parameters and ``FaultInjectingBackend`` interprets it instead of
    raising."""
    if error not in ERRNOS:
        raise ValueError(f"unknown fault error {error!r}; one of {sorted(ERRNOS)}")
    if error == "ECONNRESET":
        exc: OSError = ConnectionResetError(
            ERRNOS[error], "injected connection loss", path)
    else:
        exc = OSError(ERRNOS[error], f"injected {error}", path)
    exc.injected = True  # lets tests/ledgers distinguish chaos from real bugs
    exc.outcome = outcome
    exc.short_fraction = short_fraction
    exc.delay_s = delay_s
    return exc


@dataclass(frozen=True)
class FaultRule:
    """One failure clause.  A rule *matches* an op when every constraint
    holds; whether a matching call actually *fires* is then decided by the
    call-count window, ``probability`` (seeded plan RNG) and the remaining
    ``max_failures`` budget.

    ``outcome`` selects what firing does: ``"raise"`` (default) raises the
    errno, ``"short"`` makes a write land only ``short_fraction`` of its
    bytes and return the short count (torn op; matches write ops only),
    ``"delay"`` stalls the op ``delay_s`` seconds on the backend's clock
    and then lets it succeed (latency spike), ``"kill"`` raises
    ``ProcessKilled`` *before* the op applies and leaves the backend dead
    (preemption mid-flight: the admitted op never lands).  Fault matching
    is per
    *backend call*: N engine writes coalesced into one ``write_vec`` are a
    single matching call, and a short outcome tears the fused vector as a
    unit."""

    error: str = "EIO"
    ops: tuple[str, ...] | None = None   # op kinds to match; None = all
    path_glob: str | None = None         # fnmatch over the normalized path
    probability: float = 1.0             # chance a matching call fires
    after_count: int = 0                 # skip the first N matching calls
    max_failures: int | None = None      # stop firing after N failures
    outcome: str = "raise"               # "raise" | "short" | "delay" | "kill"
    short_fraction: float = 0.5          # of the payload, for "short"
    delay_s: float = 0.25                # stall length, for "delay"

    def __post_init__(self):
        if self.outcome not in OUTCOMES:
            raise ValueError(
                f"unknown outcome {self.outcome!r}; one of {OUTCOMES}")

    def matches(self, kind: str, path: str) -> bool:
        if self.outcome == "short" and kind != "write":
            return False  # only data writes can tear
        if self.ops is not None and kind not in self.ops:
            return False
        if self.path_glob is not None and not fnmatch.fnmatchcase(
                norm_path(path), self.path_glob):
            return False
        return True


class FaultPlan:
    """Seeded, thread-safe fault schedule.

    ``check(kind, path)`` returns the OSError to raise (or None).
    Probability draws are derived per (seed, rule, match-index) rather than
    from one shared sequential RNG, so the *number* of fires within any
    fixed count of matching calls is identical for a given seed no matter
    how worker threads interleave.  Exact ledger contents (which paths
    faulted) additionally require a deterministic execution order — a
    single worker, a drained step-by-step workload, or count/glob-based
    rules."""

    def __init__(self, rules: list[FaultRule] | None = None, *, seed: int = 0):
        self.rules = list(rules or [])
        self.seed = seed
        self._lock = threading.Lock()
        self._active = True
        self.match_counts = [0] * len(self.rules)
        self.fire_counts = [0] * len(self.rules)
        self.injected = 0                      # faults raised or torn
        self.injected_by_kind: dict[str, int] = {}
        self.delayed = 0                       # latency spikes fired
        self.delay_s_total = 0.0               # total injected stall time
        self.kills = 0                         # process deaths fired
        self.op_counts: dict[str, int] = {}    # trace: every op seen

    # -- schedule control -------------------------------------------------
    def expire(self) -> None:
        """Disable every rule (the 'transient outage ends' knob)."""
        with self._lock:
            self._active = False

    def reset(self, *, seed: int | None = None) -> None:
        """Re-arm all rules and counters (optionally reseeding)."""
        with self._lock:
            self._active = True
            if seed is not None:
                self.seed = seed
            self.match_counts = [0] * len(self.rules)
            self.fire_counts = [0] * len(self.rules)
            self.injected = 0
            self.injected_by_kind = {}
            self.delayed = 0
            self.delay_s_total = 0.0
            self.kills = 0
            self.op_counts = {}

    # -- the hot path -----------------------------------------------------
    def check(self, kind: str, path: str) -> OSError | None:
        with self._lock:
            self.op_counts[kind] = self.op_counts.get(kind, 0) + 1
            if not self._active:
                return None
            for i, rule in enumerate(self.rules):
                if not rule.matches(kind, path):
                    continue
                self.match_counts[i] += 1
                if self.match_counts[i] <= rule.after_count:
                    continue
                if (rule.max_failures is not None
                        and self.fire_counts[i] >= rule.max_failures):
                    continue
                if rule.probability < 1.0:
                    # per-(seed, rule, match-index) draw: fire counts are
                    # scheduling-independent (tuple-of-int hash is stable
                    # across processes, unlike str hashing)
                    draw = random.Random(
                        hash((self.seed, i, self.match_counts[i]))).random()
                    if draw >= rule.probability:
                        continue
                self.fire_counts[i] += 1
                if rule.outcome == "delay":
                    # a spike is a slow success, not a fault: counted apart
                    self.delayed += 1
                    self.delay_s_total += rule.delay_s
                elif rule.outcome == "kill":
                    self.kills += 1
                    self.injected += 1
                    self.injected_by_kind[kind] = \
                        self.injected_by_kind.get(kind, 0) + 1
                else:
                    self.injected += 1
                    self.injected_by_kind[kind] = \
                        self.injected_by_kind.get(kind, 0) + 1
                return make_fault(rule.error, path, outcome=rule.outcome,
                                  short_fraction=rule.short_fraction,
                                  delay_s=rule.delay_s)
        return None

    def stats(self) -> dict:
        with self._lock:
            return {
                "injected": self.injected,
                "injected_by_kind": dict(self.injected_by_kind),
                "delayed": self.delayed,
                "delay_s_total": self.delay_s_total,
                "kills": self.kills,
                "match_counts": list(self.match_counts),
                "fire_counts": list(self.fire_counts),
                "ops_seen": dict(self.op_counts),
            }


# ---------------------------------------------------------------------------


class FaultInjectingBackend(StorageBackend):
    """Decorator: consult a FaultPlan before delegating each primitive.

    Sits anywhere in the decorator stack; putting it outermost means the
    fault is charged *before* latency/quota are paid (a client-visible
    refusal), innermost means the op travelled to the 'server' first.

    ``clock`` serves the ``delay`` outcome (latency spikes): pass the same
    ``VirtualClock`` as the latency layer so spike schedules replay without
    real sleeps.  Defaults to real time."""

    def __init__(self, inner: StorageBackend, plan: FaultPlan,
                 clock: Clock | None = None,
                 kill_scope: str | None = None):
        self.inner = inner
        self.plan = plan
        self._fault_clock = clock or RealClock()
        self._dead = False
        # tenancy (PR 10): with ``kill_scope`` set (an fnmatch glob, e.g.
        # "tA/*"), a kill models the death of ONE tenant's worker process
        # sharing the mount — only calls on matching paths raise
        # ProcessKilled afterwards; neighbours' paths keep flowing.
        # Default None keeps the legacy whole-process semantics.
        self.kill_scope = kill_scope

    def __getattr__(self, name):  # delegate non-op attrs (snapshot, model…)
        return getattr(self.inner, name)

    def revive(self) -> None:
        """Clear the dead state: the 'fresh process re-attaches to the
        same storage' step of a preemption test.  The plan's counters are
        untouched — re-arm or expire it separately."""
        self._dead = False

    def _dead_for(self, path: str) -> bool:
        if not self._dead:
            return False
        if self.kill_scope is None:
            return True
        return fnmatch.fnmatchcase(norm_path(path), self.kill_scope)

    def cost_hint(self, op: str, nbytes: int = 0):
        # explicit inward delegation: the StorageBackend base defines
        # cost_hint (returning None), which would shadow __getattr__ —
        # faults add no cost of their own, the wrapped model answers
        return self.inner.cost_hint(op, nbytes)

    def _gate(self, kind: str, path: str) -> OSError | None:
        """Consult the plan.  Raise-outcome faults raise here; a delay
        outcome sleeps and clears; a short outcome is returned as a token
        for the write paths to interpret (torn op); a kill outcome flips
        the backend dead and raises ``ProcessKilled`` — as does every
        subsequent call, whatever the plan says (a dead process does not
        come back by retrying)."""
        if self._dead_for(path):
            exc = ProcessKilled(f"backend is dead (injected kill): "
                                f"{kind}({path})")
            exc.injected = True
            raise exc
        err = self.plan.check(kind, path)
        if err is None:
            return None
        outcome = getattr(err, "outcome", "raise")
        if outcome == "delay":
            self._fault_clock.sleep(err.delay_s)
            return None
        if outcome == "kill":
            # pre-apply death: the gated op was admitted but never lands
            self._dead = True
            exc = ProcessKilled(f"injected kill during {kind}({path})")
            exc.injected = True
            raise exc
        if outcome == "short":
            return err
        raise err

    @staticmethod
    def _tear(segments: list[tuple[int, bytes]],
              fraction: float) -> list[tuple[int, bytes]]:
        """Keep only the leading ``fraction`` of the vector's bytes —
        the torn prefix that 'reached the disk'."""
        budget = int(sum(len(d) for _, d in segments) * fraction)
        out: list[tuple[int, bytes]] = []
        for off, data in segments:
            take = min(len(data), budget)
            if take > 0:
                out.append((off, data[:take]))
            budget -= take
            if budget <= 0:
                break
        return out

    # namespace
    def mkdir(self, path): self._gate("mkdir", path); self.inner.mkdir(path)
    def rmdir(self, path): self._gate("rmdir", path); self.inner.rmdir(path)
    def create(self, path): self._gate("create", path); self.inner.create(path)
    def unlink(self, path): self._gate("unlink", path); self.inner.unlink(path)
    def rename(self, src, dst):
        # gate both endpoints so dst-targeting globs see renames *into*
        # their subtree (each counts as a matching call)
        self._gate("rename", src)
        self._gate("rename", dst)
        self.inner.rename(src, dst)
    def symlink(self, t, p): self._gate("symlink", p); self.inner.symlink(t, p)
    def link(self, s, d): self._gate("link", d); self.inner.link(s, d)
    def readlink(self, p): self._gate("readlink", p); return self.inner.readlink(p)
    # data — faults fire per backend call: one fused write_vec of N
    # coalesced writes is a single matching call for the plan
    def write_at(self, p, o, data):
        tok = self._gate("write", p)
        if tok is not None:   # torn op: land a prefix, return the short count
            torn = self._tear([(o, data)], tok.short_fraction)
            if torn:
                return self.inner.write_at(p, torn[0][0], torn[0][1])
            return 0
        return self.inner.write_at(p, o, data)

    def write_vec(self, p, segments):
        tok = self._gate("write", p)
        if tok is not None:
            torn = self._tear(segments, tok.short_fraction)
            return self.inner.write_vec(p, torn) if torn else 0
        return self.inner.write_vec(p, segments)
    def read_at(self, p, o, size):
        self._gate("read", p); return self.inner.read_at(p, o, size)
    def truncate(self, p, s): self._gate("truncate", p); self.inner.truncate(p, s)
    def fallocate(self, p, s): self._gate("fallocate", p); self.inner.fallocate(p, s)
    def fsync(self, p): self._gate("fsync", p); self.inner.fsync(p)
    # metadata
    def chmod(self, p, m): self._gate("chmod", p); self.inner.chmod(p, m)
    def chown(self, p, u, g): self._gate("chown", p); self.inner.chown(p, u, g)
    def utimens(self, p, a, m): self._gate("utimens", p); self.inner.utimens(p, a, m)
    def setxattr(self, p, k, v): self._gate("setxattr", p); self.inner.setxattr(p, k, v)
    def removexattr(self, p, k): self._gate("removexattr", p); self.inner.removexattr(p, k)
    def stat(self, p): self._gate("stat", p); return self.inner.stat(p)
    def readdir(self, p): self._gate("readdir", p); return self.inner.readdir(p)

    def readdir_plus(self, p):
        # one fused listing call = one matching "readdir" call for the
        # plan; per-entry stat rules do not fire (the warm-up is advisory
        # and must not condemn a region — cf. the prefetch-fault test)
        self._gate("readdir", p)
        return self.inner.readdir_plus(p)

    def readdir_plus_vec(self, paths):
        # per-fused-call semantics, mirroring write_vec/remove_tree: one
        # vectored batch of N listings is ONE matching "readdir" call,
        # gated on the batch's first path.  The caller (the speculative
        # prefetcher) treats a fired fault as advisory — the batch is
        # dropped and the walk falls back per-directory; nothing lands in
        # the ledger and no region is condemned.
        self._gate("readdir", paths[0] if paths else "")
        return self.inner.readdir_plus_vec(paths)

    def stat_vec(self, paths):
        # one fused batch of N existence probes is ONE matching "stat"
        # call, gated on the batch's first path (cf. readdir_plus_vec).
        # The existence batcher treats a fired fault as advisory: the
        # batch is dropped and each consumer falls back to its sync stat.
        self._gate("stat", paths[0] if paths else "")
        return self.inner.stat_vec(paths)

    def read_vec(self, p, spans):
        # one fused extent vector is ONE matching "read" call (cf.
        # write_vec): the read-ahead layer drops a faulted window and the
        # consumer's sync read re-gates it as its own matching call.
        self._gate("read", p)
        return self.inner.read_vec(p, spans)

    def remove_tree(self, p):
        # per-fused-op semantics, mirroring write_vec: N collapsed
        # unlinks/rmdirs are ONE matching "remove_tree" call
        self._gate("remove_tree", p)
        return self.inner.remove_tree(p)


# ---------------------------------------------------------------------------


class QuotaBackend(StorageBackend):
    """Byte- and inode-budget decorator: EDQUOT once cumulative file bytes
    exceed ``budget_bytes``; ENOSPC once ``max_inodes`` namespace entries
    (create/mkdir/symlink/link) are in flight.

    Accounting is by charged byte ranges per path (grow on write/truncate/
    fallocate past the previous high-water mark, release on unlink or
    shrinking truncate, move on rename) plus a charged-inode set (charge
    on create/mkdir/symlink/link, release on unlink/rmdir and on a bulk
    ``remove_tree``, move on rename).  Pre-existing entries written
    directly to the inner backend are not charged — the budget covers what
    flows *through* this decorator, which is the transaction's footprint.
    Charge and release are exception-safe and symmetric: a delegated op
    that raises uncharges, and rollback's removals release, which is why
    the paper's roll-back-and-resubmit loop converges."""

    def __init__(self, inner: StorageBackend, budget_bytes: int, *,
                 max_inodes: int | None = None):
        self.inner = inner
        self.budget_bytes = int(budget_bytes)
        self.max_inodes = None if max_inodes is None else int(max_inodes)
        self._qlock = threading.Lock()
        self._charged: dict[str, int] = {}   # path -> charged size
        self._inodes: set[str] = set()       # paths holding an inode charge
        self.used = 0
        self.inodes_used = 0
        self.edquot_count = 0
        self.enospc_count = 0

    def __getattr__(self, name):
        return getattr(self.inner, name)

    def cost_hint(self, op: str, nbytes: int = 0):
        # explicit inward delegation (see FaultInjectingBackend.cost_hint)
        return self.inner.cost_hint(op, nbytes)

    @property
    def remaining(self) -> int:
        with self._qlock:
            return self.budget_bytes - self.used

    @property
    def inodes_remaining(self) -> int | None:
        if self.max_inodes is None:
            return None
        with self._qlock:
            return self.max_inodes - self.inodes_used

    def usage(self) -> dict:
        """One consistent snapshot of the budget state — the per-tenant
        observability accessor (PR 10), mirrored by ``TenantQuota.usage``
        and surfaced in the ``multi_tenant`` paper table."""
        with self._qlock:
            return {
                "budget_bytes": self.budget_bytes,
                "bytes_used": self.used,
                "bytes_remaining": self.budget_bytes - self.used,
                "max_inodes": self.max_inodes,
                "inodes_used": self.inodes_used,
                "inodes_remaining": (None if self.max_inodes is None
                                     else self.max_inodes - self.inodes_used),
                "edquot_count": self.edquot_count,
                "enospc_count": self.enospc_count,
            }

    # -- inode accounting ----------------------------------------------

    def _charge_inode(self, path: str) -> bool:
        """Charge one inode for ``path``; raise ENOSPC when exhausted.
        Returns True iff a new charge was taken (so a failed delegate can
        uncharge exactly what it charged — recharging an owned path, e.g.
        create-with-O_TRUNC over a charged file, is free)."""
        if self.max_inodes is None:
            return False
        path = norm_path(path)
        with self._qlock:
            if path in self._inodes:
                return False
            if self.inodes_used + 1 > self.max_inodes:
                self.enospc_count += 1
                # organic budget exhaustion, not scripted chaos: no
                # .injected tag (mirrors the EDQUOT path)
                raise OSError(_errno.ENOSPC, "inode quota exceeded", path)
            self._inodes.add(path)
            self.inodes_used += 1
            return True

    def _uncharge_inode(self, path: str, charged: bool) -> None:
        if not charged:
            return
        path = norm_path(path)
        with self._qlock:
            if path in self._inodes:
                self._inodes.discard(path)
                self.inodes_used -= 1

    def _release_inode(self, path: str) -> None:
        path = norm_path(path)
        with self._qlock:
            if path in self._inodes:
                self._inodes.discard(path)
                self.inodes_used -= 1

    def _grow(self, path: str, new_size: int) -> int:
        """Charge growth up to new_size; raise EDQUOT if over budget.
        Returns the bytes charged so a failed delegate can uncharge."""
        path = norm_path(path)
        with self._qlock:
            prev = self._charged.get(path, 0)
            growth = new_size - prev
            if growth <= 0:
                return 0
            if self.used + growth > self.budget_bytes:
                self.edquot_count += 1
                # no .injected tag: this is organic budget exhaustion, not
                # scripted chaos — keep the two distinguishable in stats
                raise OSError(_errno.EDQUOT, "disk quota exceeded", path)
            self._charged[path] = new_size
            self.used += growth
            return growth

    def _uncharge(self, path: str, growth: int) -> None:
        """Back out a charge whose delegated op raised — no bytes landed."""
        if growth <= 0:
            return
        path = norm_path(path)
        with self._qlock:
            cur = self._charged.get(path, 0) - growth
            if cur <= 0:
                self._charged.pop(path, None)
            else:
                self._charged[path] = cur
            self.used -= growth

    def _release(self, path: str, new_size: int = 0) -> None:
        path = norm_path(path)
        with self._qlock:
            prev = self._charged.get(path, 0)
            if new_size >= prev:
                return
            if new_size <= 0:
                self._charged.pop(path, None)
            else:
                self._charged[path] = new_size
            self.used -= prev - new_size

    # namespace (dir bytes are free; every new entry costs an inode)
    def mkdir(self, path):
        inode = self._charge_inode(path)
        try:
            self.inner.mkdir(path)
        except BaseException:
            self._uncharge_inode(path, inode)
            raise

    def rmdir(self, path):
        self.inner.rmdir(path)
        self._release_inode(path)

    def create(self, path):
        inode = self._charge_inode(path)
        try:
            self.inner.create(path)
        except BaseException:
            self._uncharge_inode(path, inode)
            raise
        self._release(path)   # create truncates (O_TRUNC): old bytes are gone

    def unlink(self, path):
        self.inner.unlink(path)
        self._release(path)
        self._release_inode(path)

    def rename(self, src, dst):
        self.inner.rename(src, dst)
        src, dst = norm_path(src), norm_path(dst)
        if src == dst:
            return
        with self._qlock:
            # an overwriting rename destroys the old destination file —
            # release its charge or `used` inflates forever
            prev = self._charged.pop(dst, None)
            if prev:
                self.used -= prev
            for p in [p for p in self._charged if is_under(p, src)]:
                self._charged[dst + p[len(src):]] = self._charged.pop(p)
            if dst in self._inodes:
                self._inodes.discard(dst)
                self.inodes_used -= 1
            for p in [p for p in self._inodes if is_under(p, src)]:
                self._inodes.discard(p)
                self._inodes.add(dst + p[len(src):])

    def symlink(self, t, p):
        inode = self._charge_inode(p)
        try:
            self.inner.symlink(t, p)
        except BaseException:
            self._uncharge_inode(p, inode)
            raise

    def link(self, src, dst):
        # charge the new name as if it were a copy: per-path accounting
        # over-counts shared storage, but the alternative (free links whose
        # unlink releases the charge) lets linked data escape the budget
        with self._qlock:
            src_charge = self._charged.get(norm_path(src), 0)
        inode = self._charge_inode(dst)
        growth = self._grow(dst, src_charge)
        try:
            self.inner.link(src, dst)
        except BaseException:
            self._uncharge(dst, growth)
            self._uncharge_inode(dst, inode)
            raise

    def readlink(self, p): return self.inner.readlink(p)

    def readdir_plus(self, p):
        # must delegate whole: the base loop would re-enter this
        # decorator's per-entry ops instead of the inner fused call
        return self.inner.readdir_plus(p)

    def readdir_plus_vec(self, paths):
        return self.inner.readdir_plus_vec(paths)

    def stat_vec(self, paths):
        # must delegate whole: the base loop would re-enter this
        # decorator per path instead of the inner fused call
        return self.inner.stat_vec(paths)

    def read_vec(self, p, spans):
        return self.inner.read_vec(p, spans)

    def remove_tree(self, path):
        """Bulk removal releases every byte and inode charge under the
        root in one sweep — the uncharge mirror of the fused call.  On a
        partial failure (inner raised mid-walk) nothing is released: the
        surviving paths keep their charges (conservative over-count until
        the retried removal converges)."""
        n = self.inner.remove_tree(path)
        root = norm_path(path)
        with self._qlock:
            for p in [p for p in self._charged if is_under(p, root)]:
                self.used -= self._charged.pop(p)
            for p in [p for p in self._inodes if is_under(p, root)]:
                self._inodes.discard(p)
                self.inodes_used -= 1
        return n

    # data
    def write_at(self, path, offset, data):
        growth = self._grow(path, offset + len(data))
        try:
            n = self.inner.write_at(path, offset, data)
        except BaseException:
            self._uncharge(path, growth)
            raise
        if n < len(data):
            # torn op: bytes past the achieved high-water mark never landed
            self._uncharge(path, min(growth, offset + len(data) - (offset + n)))
        return n

    def write_vec(self, path, segments):
        """Vectored write: the whole fused batch is charged (to its highest
        end offset) before one delegated call — EDQUOT decides per fused
        op, matching the fault-injection semantics."""
        if not segments:
            return 0
        end = max(off + len(data) for off, data in segments)
        total = sum(len(data) for _, data in segments)
        growth = self._grow(path, end)
        try:
            n = self.inner.write_vec(path, segments)
        except BaseException:
            self._uncharge(path, growth)
            raise
        if n < total:
            # back out the charge beyond the high-water offset the torn
            # vector actually reached (segments land in order)
            achieved, rem = 0, n
            for off, data in segments:
                take = min(len(data), rem)
                if take > 0:
                    achieved = max(achieved, off + take)
                rem -= take
                if rem <= 0:
                    break
            self._uncharge(path, min(growth, end - achieved))
        return n

    def read_at(self, p, o, size): return self.inner.read_at(p, o, size)

    def truncate(self, path, size):
        growth = self._grow(path, size)
        try:
            self.inner.truncate(path, size)
        except BaseException:
            self._uncharge(path, growth)
            raise
        self._release(path, size)

    def fallocate(self, path, size):
        growth = self._grow(path, size)
        try:
            self.inner.fallocate(path, size)
        except BaseException:
            self._uncharge(path, growth)
            raise

    def fsync(self, p): self.inner.fsync(p)
    # metadata
    def chmod(self, p, m): self.inner.chmod(p, m)
    def chown(self, p, u, g): self.inner.chown(p, u, g)
    def utimens(self, p, a, m): self.inner.utimens(p, a, m)
    def setxattr(self, p, k, v): self.inner.setxattr(p, k, v)
    def removexattr(self, p, k): self.inner.removexattr(p, k)
    def stat(self, p): return self.inner.stat(p)
    def readdir(self, p): return self.inner.readdir(p)
