"""Vectored read-side data plane: BDP-sized read-ahead + stat batching.

The engine hides *write* latency by deferring and fusing mutations, and
the PR 5 prefetcher pipelines cold *metadata* walks — but the cold data
read path still costed one synchronous backend roundtrip per ``read``
and one per journaling existence probe, which is exactly the serialized
pattern that dominates checkpoint restore and data-shard ingestion in
the training loop.  This module closes it with two speculative
consumers of the new vectored backend primitives:

``ReadAheadManager`` — the buffered read-ahead file layer.  A
sequential consumer's first sync read of a known-size file registers a
per-file page buffer guarded by a ``SpeculationTicket`` and issues a
speculative ``read_vec`` *window* sized to ~``bdp_multiplier`` x the
backend's measured bandwidth-delay product (``bdp_bytes`` EWMAs, the
same clamping discipline as ``FusionPolicy.adaptive_max_bytes``).
Subsequent preads are served from the installed pages without a
roundtrip; every page hit extends the frontier by one more window
(clamped to the known file size), so a streaming reader pays
``1 + ceil((size - first_read) / window)`` roundtrips instead of one
per chunk.  A consumer that outruns the pipeline *latches* onto the
in-flight window op (one shared roundtrip) instead of duplicating the
fetch.

``StatVecBatcher`` — existence batching for the write path's
journaling probes.  Inside a transaction, ``create`` and an
implicit-create ``write`` must learn whether their target pre-existed
(journal a create vs. mark pre-existing).  The probes enqueue at
submission, flush as ONE speculative ``stat_vec`` per fused batch, and
the op's fn consumes the landed answer at execution time — falling
back to today's sync ``stat`` whenever the batch lost the race.

Both are strictly **advisory** and byte-identical to the unbuffered
engine, by the same ticket discipline as the metadata prefetcher:

* speculation registers only while the path (and, for read-ahead, its
  ancestors) has no pending ops — earlier-admitted work can never be
  overtaken;
* any racing *admitted* mutation that could change the answer —
  write/truncate/create/unlink on the file, rename/rmdir/remove_tree
  at or above it, an op failure, a transaction rollback — cancels the
  ticket, and installs are refused on arrival;
* probe consumption is single-shot: the first lookup (hit or miss)
  retires the entry and cancels its ticket, so a late install can
  never leak a stale answer into a later transaction;
* fetch failures — including injected faults, which fire once per
  *fused* batch — are swallowed: nothing lands in the ledger, no
  region is condemned, and the consumer falls back to its sync path.

``EngineStats`` reports ``readahead_{windows,hits,latched,bytes,
wasted,cancelled}`` and ``stat_{batches,probes,probe_hits,
probe_fallbacks}``.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass

from .backend import is_under, norm_path, parent_of
from .namespace import SpeculationTicket


@dataclass(frozen=True)
class ReadPolicy:
    """Knobs of the read-side data plane (``CannyFS(readahead=
    ReadPolicy(...))``; ``readahead=False`` disables it, the default
    enables it).

    ``min_bytes``/``max_bytes`` bound one speculative ``read_vec``
    window; with ``adaptive`` and a backend that measures its
    bandwidth-delay product (``LatencyBackend.bdp_bytes``), the window
    is ~``bdp_multiplier`` x BDP within those bounds — the same
    self-tuning the write coalescer and the metadata prefetcher use.
    ``max_files`` LRU-bounds the per-file page buffers so speculation
    can never hold unbounded memory.  ``stat_batching``/``stat_batch``
    gate and size the write-path existence batcher."""

    enabled: bool = True
    min_bytes: int = 64 << 10
    max_bytes: int = 8 << 20
    adaptive: bool = True
    bdp_multiplier: float = 2.0
    max_files: int = 64
    stat_batching: bool = True
    stat_batch: int = 16

    @classmethod
    def off(cls) -> "ReadPolicy":
        return cls(enabled=False)


# op kinds whose admission invalidates speculation exactly on the op's
# paths (content or existence of that file changes when they execute)
_EXACT_KINDS = frozenset({
    "write", "truncate", "fallocate", "create", "unlink", "mkdir",
    "symlink", "link",
})
# op kinds whose admission invalidates every speculation under their
# paths (a subtree moves or vanishes)
_TREE_KINDS = frozenset({"rename", "rmdir", "remove_tree"})
# the engine brackets these kinds' admissions with its in-flight guard:
# their on_admit cancellation hook runs before the scheduler publishes
# the op, so registration must decline while one is mid-admission
INVALIDATING_KINDS = _EXACT_KINDS | _TREE_KINDS
# ancestor tips that cannot change a *file's* existence or bytes: a
# pending mkdir of a parent only brings the directory into being (the
# DAG orders the probed op after it), and pure-metadata tips touch no
# namespace at all.  Anything else pending above the path refuses
# registration — earlier-admitted structural work must win.
_BENIGN_ANCESTOR_KINDS = frozenset({
    "mkdir", "chmod", "chown", "utimens", "setxattr", "removexattr",
    "fsync", "stat", "readdir",
})


class _WindowPayload:
    """Payload of one speculative window fetch; the engine calls
    ``on_cancelled`` when poison/close cancels the op before it ran, so
    the in-flight marker clears and the consumer's latch falls through
    to its sync path."""

    __slots__ = ("manager", "path", "ticket")

    def __init__(self, manager, path, ticket):
        self.manager = manager
        self.path = path
        self.ticket = ticket

    def on_cancelled(self) -> None:
        self.manager._window_aborted(self.path, self.ticket)


class _FileState:
    """One file's read-ahead run: a contiguous page buffer
    ``[start, start + len(buf))`` plus at most one in-flight window."""

    __slots__ = ("path", "ticket", "start", "buf", "expected", "size",
                 "inflight_op", "inflight_start", "inflight_end")

    def __init__(self, path: str, ticket: SpeculationTicket,
                 expected: int, size: int):
        self.path = path
        self.ticket = ticket
        self.start = expected       # buffer origin (empty buf)
        self.buf = b""
        self.expected = expected    # next sequential offset
        self.size = size            # known file size (fetch clamp ONLY)
        self.inflight_op = None
        self.inflight_start = 0
        self.inflight_end = 0


class ReadAheadManager:
    """The per-file page buffers + window pump.  One per engine; all
    entry points are thread-safe.  Holds its own lock (``_slock`` is
    the stats leaf lock, mirroring the prefetcher's discipline); the
    scheduler is only entered for non-blocking calls."""

    def __init__(self, engine, policy: ReadPolicy):
        self.engine = engine
        self.policy = policy
        bdp = getattr(engine.backend, "bdp_bytes", None)
        self._bdp = bdp if callable(bdp) else None
        # CostModel protocol: the "read" class hint outranks the scalar
        # probe, so the window is sized from read-request costs even when
        # the backend's metadata ops are billed differently
        cost = getattr(engine.backend, "cost_hint", None)
        self._cost = cost if callable(cost) else None
        self._lock = threading.Lock()
        self._slock = threading.Lock()
        self._files: OrderedDict[str, _FileState] = OrderedDict()

    # ------------------------------------------------------------------
    # sizing
    # ------------------------------------------------------------------

    def _bdp_bytes(self):
        if self._cost is not None:
            hint = self._cost("read", 0)
            if hint is not None:
                return hint.bdp_bytes()
        if self._bdp is not None:
            return self._bdp()
        return None

    def window(self) -> int:
        """Bytes per speculative fetch: ~2x the measured BDP when the
        backend exposes one, else the policy cap — the same clamp
        discipline as ``FusionPolicy.adaptive_max_bytes``."""
        pol = self.policy
        if not pol.adaptive:
            return pol.max_bytes
        bdp = self._bdp_bytes()
        if not bdp:
            return pol.max_bytes
        return max(pol.min_bytes,
                   min(int(pol.bdp_multiplier * bdp), pol.max_bytes))

    # ------------------------------------------------------------------
    # the read path (called by fs.pread on the consumer's thread)
    # ------------------------------------------------------------------

    def read(self, path: str, offset: int, size: int):
        """Serve ``[offset, offset + size)`` from installed pages, or
        latch onto the in-flight window covering the offset and re-try
        once, or return None — the caller then takes the sync path.  A
        hit is byte-identical to the sync read: pages register only on
        quiescent paths and cancel on any racing admitted mutation, so
        a valid page IS the backend's current content.  Reads are never
        served past the buffered run (EOF knowledge only clamps
        *fetches*, it never answers a consumer)."""
        if size < 0:
            return None
        path = norm_path(path)
        out, op = self._try_serve(path, offset, size)
        if out is not None or op is None:
            return out
        # consumer latch: the covering window is already on the wire —
        # wait for it on the caller's thread (never a pool worker) and
        # re-check, exactly one shared roundtrip instead of a duplicate
        sim = self.engine.sim
        if sim is not None:
            sim.wait_event(op.done)
        else:
            op.done.wait()
        with self._slock:
            self.engine.stats.readahead_latched += 1
        out, _ = self._try_serve(path, offset, size)
        return out

    def _try_serve(self, path: str, offset: int, size: int):
        """-> (bytes | None, latchable in-flight op | None)."""
        issue = None
        with self._lock:
            st = self._files.get(path)
            if st is None:
                return None, None
            if st.ticket.cancelled:
                self._drop_locked(path, st, count=False)
                return None, None
            self._files.move_to_end(path)
            end = offset + size
            buf_end = st.start + len(st.buf)
            if st.start <= offset and end <= buf_end:
                out = st.buf[offset - st.start:end - st.start]
                # trim the consumed prefix: sequential readers never
                # look back, and this bounds the buffer to one window
                st.buf = st.buf[end - st.start:]
                st.start = end
                st.expected = end
                # frontier extension: every hit keeps exactly one
                # window in flight until the known size is covered
                if st.inflight_op is None:
                    issue = self._next_window_locked(st)
            elif (st.inflight_op is not None
                    and st.inflight_start <= offset < st.inflight_end):
                return None, st.inflight_op
            else:
                return None, None
        with self._slock:
            self.engine.stats.readahead_hits += 1
        if issue is not None:
            self._issue(*issue)
        return out, None

    def observe_sync(self, path: str, offset: int, nbytes: int,
                     requested: int) -> None:
        """One sync read executed.  A fresh file read sequentially from
        offset 0 (or a sequential continuation after a cancelled
        window) triggers the first speculative window; a short read
        learned EOF and stops the pipeline; a non-sequential offset
        drops the state (random access)."""
        if requested < 0:
            return
        path = norm_path(path)
        issue = None
        with self._lock:
            st = self._files.get(path)
            if st is not None:
                if st.ticket.cancelled:
                    self._drop_locked(path, st, count=False)
                    st = None
                elif nbytes < requested:
                    # EOF: nothing left to speculate on
                    self._drop_locked(path, st, count=False)
                    return
                elif offset == st.expected:
                    # sequential miss (window cancelled/declined): resync
                    # the buffer origin and restart the pipeline
                    st.expected = offset + nbytes
                    st.start = st.expected
                    st.buf = b""
                    if st.inflight_op is None:
                        issue = self._next_window_locked(st)
                else:
                    self._drop_locked(path, st)
                    st = None
            if st is None and issue is None:
                if offset != 0 or nbytes < requested or nbytes == 0:
                    return
                size = self._known_size(path)
                if size is None or size <= nbytes:
                    return
                if not self._quiescent(path):
                    return
                st = _FileState(path, SpeculationTicket(path),
                                expected=nbytes, size=size)
                self._files[path] = st
                while len(self._files) > self.policy.max_files:
                    old, ost = next(iter(self._files.items()))
                    self._drop_locked(old, ost)
                issue = self._next_window_locked(st)
        if issue is not None:
            self._issue(*issue)

    # ------------------------------------------------------------------
    # window issue / install (the speculative fetch)
    # ------------------------------------------------------------------

    def _known_size(self, path: str):
        """The file's settled size, from the stat cache (registration
        requires a quiescent path, so a cached size is not mid-flight).
        Used ONLY to clamp fetch extents — never to answer a read."""
        st = self.engine.stat_cache.get(path)
        if st is None or not st.exists or st.is_dir or st.is_symlink:
            return None
        return st.size

    def _next_window_locked(self, st: _FileState):
        """Compute the next window for ``st`` (frontier = end of the
        buffered run) or None when the known size is covered.  Caller
        holds ``_lock`` and issues outside stats."""
        frontier = st.start + len(st.buf)
        if frontier >= st.size:
            return None
        length = min(self.window(), st.size - frontier)
        return st, frontier, length

    def _issue(self, st: _FileState, start: int, length: int) -> None:
        path, ticket = st.path, st.ticket
        backend = self.engine.backend

        def fn():
            try:
                data = backend.read_vec(path, [(start, length)])[0]
            except OSError:
                # advisory: an injected (or real) fault on the fused
                # window drops it whole — no ledger entry, no poison;
                # the consumer sync-reads and the pipeline restarts
                data = None
            self._install(path, ticket, start, data)

        op = self.engine._sched.submit_speculative(
            "read_ahead", (path,), fn,
            payload=_WindowPayload(self, path, ticket))
        with self._lock:
            cur = self._files.get(path)
            if cur is st and op is not None:
                st.inflight_op = op
                st.inflight_start = start
                st.inflight_end = start + length
        if op is None:
            return
        with self._slock:
            self.engine.stats.readahead_windows += 1

    def _install(self, path: str, ticket: SpeculationTicket,
                 start: int, data) -> None:
        """Land one fetched window (runs on an executor worker).  The
        ticket re-check happens under the manager lock, so a racing
        admitted mutation's cancellation always wins over the install —
        a cancelled window never plants bytes the unbuffered engine
        could not have read."""
        wasted = False
        with self._lock:
            st = self._files.get(path)
            if st is None or st.ticket is not ticket or ticket.cancelled:
                wasted = True
            else:
                if st.inflight_op is not None:
                    st.inflight_op = None
                if data is None:
                    wasted = True
                elif start == st.start + len(st.buf):
                    st.buf = st.buf + data
                    if len(data) < st.inflight_end - start:
                        # short fetch: the file is smaller than the stat
                        # suggested — learn the EOF and stop speculating
                        st.size = min(st.size, start + len(data))
                else:
                    wasted = True   # stale vs. a consumer resync
        with self._slock:
            stats = self.engine.stats
            if wasted:
                stats.readahead_wasted += 1
            else:
                stats.readahead_bytes += len(data)

    def _window_aborted(self, path: str, ticket: SpeculationTicket) -> None:
        with self._lock:
            st = self._files.get(path)
            if st is not None and st.ticket is ticket:
                st.inflight_op = None
        with self._slock:
            self.engine.stats.readahead_wasted += 1

    # ------------------------------------------------------------------
    # invalidation (racing admitted mutations / failures / rollback)
    # ------------------------------------------------------------------

    def _quiescent(self, path: str) -> bool:
        """True iff nothing already admitted can still change this
        file's bytes: no pending op on the path, no invalidating
        admission mid-flight (its cancellation hook has already fired
        but the op is not yet visible to ``pending_tip``), and no
        pending non-benign op on any ancestor.  Later admissions are
        the ``on_op`` hook's job."""
        eng = self.engine
        with eng._adm_lock:
            if eng._admitting:
                return False
        sched = eng._sched
        if sched.pending_tip(path) is not None:
            return False
        anc = parent_of(path)
        while True:
            tip = sched.pending_tip(anc)
            if tip is not None and tip.kind not in _BENIGN_ANCESTOR_KINDS:
                return False
            if not anc:
                return True
            anc = parent_of(anc)

    def _drop_locked(self, path: str, st: _FileState,
                     count: bool = True) -> None:
        st.ticket.cancelled = True
        self._files.pop(path, None)
        if count:
            with self._slock:
                self.engine.stats.readahead_cancelled += 1

    def on_op(self, kind: str, paths) -> None:
        """Admission hook (engine.submit's on_admit): cancel every
        speculation the op could invalidate once it executes."""
        if kind in _TREE_KINDS:
            with self._lock:
                for p, st in [(p, st) for p, st in self._files.items()
                              if any(is_under(p, q) for q in paths)]:
                    self._drop_locked(p, st)
        elif kind in _EXACT_KINDS:
            with self._lock:
                for q in paths:
                    st = self._files.get(q)
                    if st is not None:
                        self._drop_locked(q, st)

    def invalidate(self, path: str) -> None:
        """A background op on ``path`` failed after claiming its effect
        at ACK time — every speculation there is suspect."""
        with self._lock:
            st = self._files.get(path)
            if st is not None:
                self._drop_locked(path, st)

    def clear(self) -> None:
        """Transaction rollback mutates the backend directly (bypassing
        admission), so every page is suspect — drop them all."""
        with self._lock:
            for p, st in list(self._files.items()):
                self._drop_locked(p, st)


# ---------------------------------------------------------------------------


class _Probe:
    """One enqueued existence probe.  ``exempt_kind`` is the probed op's
    own kind: its (single) admission must not cancel the probe — it IS
    the consumer.  Per-path FIFO then orders every later same-path
    admission after the consumer's execution, so post-exemption
    admissions are harmless; any *other* admission before the exemption
    is consumed cancels (a foreign op slipped between enqueue and the
    consumer's admission)."""

    __slots__ = ("path", "ticket", "exempt_kind", "exempt_used", "value",
                 "flushed")

    def __init__(self, path: str, exempt_kind: str):
        self.path = path
        self.ticket = SpeculationTicket(path)
        self.exempt_kind = exempt_kind
        self.exempt_used = False
        self.value = None           # StatResult once a batch landed
        self.flushed = False        # left the pending buffer as a batch


class _ProbeBatchPayload:
    __slots__ = ("batcher", "batch")

    def __init__(self, batcher, batch):
        self.batcher = batcher
        self.batch = batch

    def on_cancelled(self) -> None:
        self.batcher._batch_aborted(self.batch)


class StatVecBatcher:
    """Fuses the write path's journaling existence probes into
    speculative ``stat_vec`` batches (one advisory rule match per fused
    batch on a fault-injecting stack).  Single-shot consumption keeps
    it exact: ``lookup`` retires the entry and cancels its ticket, so a
    batch that lost the race installs into nothing and the consumer's
    sync fallback is today's behaviour, RTT for RTT."""

    def __init__(self, engine, policy: ReadPolicy):
        self.engine = engine
        self.policy = policy
        # CostModel protocol: the "stat" class hint sizes the probe batch
        # (a high-RTT stat pipeline wants wider fusion); the policy's
        # ``stat_batch`` stays the hard ceiling either way
        cost = getattr(engine.backend, "cost_hint", None)
        self._cost = cost if callable(cost) else None
        self._lock = threading.Lock()
        self._slock = threading.Lock()
        self._entries: dict[str, _Probe] = {}
        self._pending: list[_Probe] = []   # enqueued, not yet flushed

    def effective_batch(self) -> int:
        """Probes per fused ``stat_vec``: ~2x the "stat" class BDP worth
        of ~256-byte attr records, floored at 4 and capped by the policy
        bound (which always wins, so cost-blind stacks are unchanged)."""
        pol = self.policy
        if self._cost is not None:
            hint = self._cost("stat", 0)
            if hint is not None:
                adaptive = max(4, int(2.0 * hint.bdp_bytes() / 256))
                return min(pol.stat_batch, adaptive)
        return pol.stat_batch

    # ------------------------------------------------------------------
    # producer side (fs.create / fs._write_at, at submission time)
    # ------------------------------------------------------------------

    def enqueue(self, path: str, exempt_kind: str) -> None:
        """Register one probe for ``path`` ahead of its op's admission.
        Declined (silently — the consumer just sync-stats) when the
        path or an ancestor has non-benign pending work: the answer
        would depend on ops the speculative lane can overtake."""
        path = norm_path(path)
        eng = self.engine
        with eng._adm_lock:
            if eng._admitting:
                return
        sched = eng._sched
        if sched.pending_tip(path) is not None:
            return
        anc = parent_of(path)
        while True:
            tip = sched.pending_tip(anc)
            if tip is not None and tip.kind not in _BENIGN_ANCESTOR_KINDS:
                return
            if not anc:
                break
            anc = parent_of(anc)
        flush = None
        with self._lock:
            if path in self._entries:
                return
            probe = _Probe(path, exempt_kind)
            self._entries[path] = probe
            self._pending.append(probe)
            if len(self._pending) >= self.effective_batch():
                flush = self._pending
                self._pending = []
        with self._slock:
            self.engine.stats.stat_probes += 1
        if flush is not None:
            self._flush(flush)

    def flush(self) -> None:
        """Flush a partial pending batch (consumers are catching up — the
        window for growing it further has passed)."""
        with self._lock:
            batch, self._pending = self._pending, []
        if batch:
            self._flush(batch)

    def _flush(self, batch) -> None:
        for p in batch:
            p.flushed = True
        live = [p for p in batch if not p.ticket.cancelled]
        if not live:
            return
        backend = self.engine.backend

        def fn(batch=live):
            try:
                res = backend.stat_vec([p.path for p in batch])
            except OSError:
                # advisory: a fault on the fused batch (ONE rule match)
                # drops it whole — consumers fall back per-path
                res = {}
            self._land(batch, res)

        op = self.engine._sched.submit_speculative(
            "stat", tuple(p.path for p in live), fn,
            payload=_ProbeBatchPayload(self, live))
        if op is not None:
            with self._slock:
                self.engine.stats.stat_batches += 1

    def _land(self, batch, res) -> None:
        with self._lock:
            for probe in batch:
                if probe.ticket.cancelled:
                    continue
                if self._entries.get(probe.path) is not probe:
                    continue            # already consumed: refuse
                st = res.get(probe.path)
                if st is not None:
                    probe.value = st

    def _batch_aborted(self, batch) -> None:
        # poison/close cancelled the batch op before it ran: consumers
        # fall back — nothing to release beyond the entries themselves,
        # which lookup() retires
        pass

    # ------------------------------------------------------------------
    # consumer side (the probed op's fn, at execution time)
    # ------------------------------------------------------------------

    def lookup(self, path: str):
        """Single-shot consume: the landed ``StatResult`` or None (sync
        fallback).  Retiring the entry cancels its ticket, so a batch
        still on the wire installs into nothing — a late answer can
        never leak into a later transaction's probe of the same path."""
        path = norm_path(path)
        flush = None
        with self._lock:
            probe = self._entries.pop(path, None)
            if probe is None:
                return None
            val = None if probe.ticket.cancelled else probe.value
            probe.ticket.cancelled = True
            if not probe.flushed and self._pending:
                # the consumer outran the batch window: flush what
                # accumulated so the rest still has a chance to land
                flush, self._pending = self._pending, []
        if flush:
            self._flush(flush)
        with self._slock:
            stats = self.engine.stats
            if val is None:
                stats.stat_probe_fallbacks += 1
            else:
                stats.stat_probe_hits += 1
        return val

    # ------------------------------------------------------------------
    # invalidation
    # ------------------------------------------------------------------

    def on_op(self, kind: str, paths) -> None:
        """Admission hook.  Structural subtree ops cancel everything
        underneath; an exact-path admission either consumes the probe's
        exemption (its own op arriving) or cancels it."""
        if kind in _TREE_KINDS:
            with self._lock:
                for p in [p for p in self._entries
                          if any(is_under(p, q) for q in paths)]:
                    self._entries.pop(p).ticket.cancelled = True
            return
        with self._lock:
            for q in paths:
                probe = self._entries.get(q)
                if probe is None or probe.exempt_used:
                    # post-exemption admissions are FIFO-ordered after
                    # the consumer's execution: harmless
                    continue
                if kind == probe.exempt_kind:
                    probe.exempt_used = True
                else:
                    self._entries.pop(q).ticket.cancelled = True

    def invalidate(self, path: str) -> None:
        with self._lock:
            probe = self._entries.pop(path, None)
            if probe is not None:
                probe.ticket.cancelled = True

    def clear(self) -> None:
        """Probes are transaction-scoped ('did the path exist before
        this region touched it') — commit and rollback both retire
        every outstanding entry."""
        with self._lock:
            for probe in self._entries.values():
                probe.ticket.cancelled = True
            self._entries.clear()
            self._pending = []


__all__ = ["INVALIDATING_KINDS", "ReadAheadManager", "ReadPolicy",
           "StatVecBatcher"]
