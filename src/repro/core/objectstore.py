"""S3-style object-store backend.

``ObjectStoreBackend`` presents the POSIX-shaped ``StorageBackend``
surface the engine speaks while *costing* every call the way a flat-
keyspace object store would bill it:

* **no native rename** — ``rename`` is a server-side COPY per key plus a
  DELETE per key (a directory move pays it for every key under the
  prefix), which is why cost-aware fusion defers/elides renames far more
  aggressively here than on POSIX media;
* **whole-object PUT** — there is no ranged write: ``write_at`` that
  does not rewrite the object from offset 0 becomes a read-modify-write
  (GET the old object + PUT the new one), so ``write_vec`` coalescing is
  mandatory, not an optimization — one fused vector is exactly one
  whole-object PUT;
* **paginated listings** — there is no readdir: ``list_by_prefix``
  returns at most ``list_page_size`` keys per request with an S3-style
  continuation token (the last key returned; the next page is every key
  strictly greater — robust to keys inserted or deleted between pages),
  and ``readdir``/``remove_tree`` pay one LIST request per page;
* **per-request + per-byte cost model** — each wire request costs
  ``rtt_ms`` (or only ``per_request_ms`` when pipelined behind a
  previous request of the same call: continuation pages, HEAD batches,
  ranged-GET vectors), plus payload over ``bandwidth_mb_s``.

State semantics are delegated to an internal ``InMemoryBackend`` so the
property suites can compare an object-store run against the POSIX
oracle byte-for-byte (same errors, same final ``snapshot()``) — the
class adds *accounting* (``request_count``, ``requests_by_class``,
``whole_object_puts``, ``rmw_gets``) and deterministic clock charging,
never behavioral divergence.  There is no randomness: same op stream in,
same request stream and virtual timeline out.
"""
from __future__ import annotations

import math
import threading
from dataclasses import dataclass
from typing import Optional

from .backend import (Clock, CostHint, InMemoryBackend, StorageBackend,
                      VirtualClock, norm_path)


@dataclass(frozen=True)
class ObjectStoreModel:
    """Request-billing parameters (deterministic — no jitter).

    * ``rtt_ms``            — full round-trip for a fresh request.
    * ``per_request_ms``    — marginal cost of a request pipelined behind
      another in the same call (continuation LIST pages, HEADs past the
      first in a ``stat_vec`` batch, per-key COPY/DELETEs of a dir move).
    * ``bandwidth_mb_s``    — payload streaming rate once in flight.
    * ``list_page_size``    — max keys per LIST response.
    """

    rtt_ms: float = 25.0
    per_request_ms: float = 2.0
    bandwidth_mb_s: float = 200.0
    list_page_size: int = 1000

    @property
    def rtt_s(self) -> float:
        return self.rtt_ms / 1e3

    @property
    def per_request_s(self) -> float:
        return self.per_request_ms / 1e3

    @property
    def bytes_per_s(self) -> float:
        return self.bandwidth_mb_s * 1e6


_REQUEST_CLASSES = ("put", "get", "list", "delete", "copy", "head")


class ObjectStoreBackend(StorageBackend):
    """Flat-keyspace object store over an in-memory oracle (see module
    docstring for the request model)."""

    def __init__(self, inner: Optional[InMemoryBackend] = None,
                 model: Optional[ObjectStoreModel] = None,
                 clock: Optional[Clock] = None):
        self.inner = inner if inner is not None else InMemoryBackend()
        self.model = model or ObjectStoreModel()
        self.clock = clock or VirtualClock()
        self.list_page_size = self.model.list_page_size
        self._acct = threading.Lock()
        self.op_count = 0            # public StorageBackend calls
        self.request_count = 0       # wire requests those calls issued
        self.requests_by_class = {c: 0 for c in _REQUEST_CLASSES}
        self.whole_object_puts = 0   # data PUTs that rewrote a whole object
        self.rmw_gets = 0            # GETs forced by a non-covering write
        self.busy_s = 0.0            # total charged service time

    # -- accounting ---------------------------------------------------

    def _request(self, cls: str, nbytes: int = 0, *,
                 pipelined: bool = False) -> None:
        lat = self.model.per_request_s if pipelined else self.model.rtt_s
        if nbytes > 0:
            lat += nbytes / self.model.bytes_per_s
        with self._acct:
            self.request_count += 1
            self.requests_by_class[cls] += 1
            self.busy_s += lat
        self.clock.sleep(lat)

    def _call(self) -> None:
        with self._acct:
            self.op_count += 1

    def _size_of(self, path: str) -> int:
        try:
            st = self.inner.stat(path)
        except OSError:
            return 0
        return st.size if st.exists and not st.is_dir else 0

    def _keys_under(self, prefix: str) -> list[str]:
        """Every object key at/under ``prefix`` in the flat keyspace:
        file and symlink objects plus the ``dir/`` marker objects."""
        prefix = norm_path(prefix)
        snap = self.inner.snapshot()
        keys = list(snap["files"]) + list(snap["symlinks"])
        keys += [d + "/" for d in snap["dirs"] if d]
        if prefix:
            keys = [k for k in keys
                    if k == prefix or k.startswith(prefix + "/")
                    or k == prefix + "/"]
        return sorted(keys)

    # -- the paginated listing primitive ------------------------------

    def list_by_prefix(self, prefix: str, token: Optional[str] = None,
                       page_size: Optional[int] = None,
                       ) -> tuple[list[str], Optional[str]]:
        """One LIST request: up to ``page_size`` keys under ``prefix``
        strictly greater than ``token`` (S3 continuation semantics: the
        token is the last key of the previous page, so a key inserted
        before it is missed and one deleted after it simply never
        appears — exactly the anomaly the overlay's speculation tickets
        must catch).  Returns ``(keys, next_token)`` with ``next_token
        is None`` iff nothing remains.  The first page of a call pays the
        full RTT; continuation pages are requested a page ahead and pay
        only the pipelined per-request overhead."""
        self._call()
        page = int(page_size or self.list_page_size)
        keys = self._keys_under(prefix)
        if token is not None:
            keys = [k for k in keys if k > token]
        out = keys[:page]
        self._request("list", pipelined=token is not None)
        next_token = out[-1] if len(keys) > page else None
        return out, next_token

    def _list_all(self, prefix: str) -> tuple[list[str], int]:
        """Drain the paginated listing; returns (keys, n_pages)."""
        keys: list[str] = []
        token: Optional[str] = None
        pages = 0
        while True:
            page, token = self.list_by_prefix(prefix, token)
            with self._acct:      # inner pages are one public call
                self.op_count -= 1
            keys.extend(page)
            pages += 1
            if token is None:
                return keys, pages

    # -- namespace -----------------------------------------------------

    def mkdir(self, path):
        self._call()
        self.inner.mkdir(path)          # oracle errors before billing
        self._request("put")            # PUT the dir/ marker object

    def rmdir(self, path):
        self._call()
        self.inner.rmdir(path)
        self._request("list")           # emptiness probe (one page)
        self._request("delete", pipelined=True)   # drop the marker

    def create(self, path):
        self._call()
        self.inner.create(path)
        self._request("put")            # PUT an empty object

    def unlink(self, path):
        self._call()
        self.inner.unlink(path)
        self._request("delete")

    def symlink(self, target, path):
        self._call()
        self.inner.symlink(target, path)
        self._request("put", len(target))

    def link(self, src, dst):
        self._call()
        nbytes = self._size_of(src)
        self.inner.link(src, dst)
        self._request("copy", nbytes)   # no hardlinks: server-side copy

    def readlink(self, path):
        self._call()
        out = self.inner.readlink(path)
        self._request("get", len(out))
        return out

    def rename(self, src, dst):
        """No native rename: COPY + DELETE per key.  A file move is two
        requests; a directory move pays the pair for every key under the
        prefix plus the marker — the cost the fuser's rename-retarget
        rule exists to avoid."""
        self._call()
        src_n, dst_n = norm_path(src), norm_path(dst)
        try:
            st = self.inner.stat(src_n)
        except OSError:
            st = None
        if st is not None and st.exists and st.is_dir and not st.is_symlink:
            keys = self._keys_under(src_n)
        else:
            keys = [src_n]
        self.inner.rename(src, dst)     # oracle errors before billing
        first = True
        for key in keys:
            nbytes = 0 if key.endswith("/") else self._size_of(
                norm_path(dst_n + key[len(src_n):]) if key != src_n
                else dst_n)
            self._request("copy", nbytes, pipelined=not first)
            self._request("delete", pipelined=True)
            first = False

    # -- data ----------------------------------------------------------

    def write_at(self, path, offset, data):
        """Whole-object PUT.  A write that rewrites the object from
        offset 0 is one PUT; anything else is read-modify-write: GET the
        current object, splice, PUT the result."""
        self._call()
        prior = self._size_of(path)
        covering = offset == 0 and len(data) >= prior
        n = self.inner.write_at(path, offset, data)
        new_size = self._size_of(path)
        if not covering:
            with self._acct:
                self.rmw_gets += 1
            self._request("get", prior)
        with self._acct:
            self.whole_object_puts += 1
        self._request("put", new_size, pipelined=not covering)
        return n

    def write_vec(self, path, segments):
        """ONE whole-object PUT for the fused vector (the coalescing
        win this backend makes mandatory).  Still read-modify-write when
        the vector does not itself rebuild the object from offset 0."""
        self._call()
        prior = self._size_of(path)
        covering = self._covers(segments, prior)
        n = self.inner.write_vec(path, segments)
        new_size = self._size_of(path)
        if not covering:
            with self._acct:
                self.rmw_gets += 1
            self._request("get", prior)
        with self._acct:
            self.whole_object_puts += 1
        self._request("put", new_size, pipelined=not covering)
        return n

    @staticmethod
    def _covers(segments, prior_size: int) -> bool:
        """Does the segment vector rewrite the object from offset 0
        through at least its prior size, with no gaps?"""
        spans = sorted((off, off + len(d)) for off, d in segments)
        if not spans or spans[0][0] != 0:
            return False
        end = 0
        for lo, hi in spans:
            if lo > end:
                return False
            end = max(end, hi)
        return end >= prior_size

    def read_at(self, path, offset, size):
        self._call()
        out = self.inner.read_at(path, offset, size)
        self._request("get", len(out))
        return out

    def read_vec(self, path, spans):
        # ranged GETs pipelined on one connection: first span pays the
        # RTT, the rest only the per-request overhead — the read-ahead
        # layer's fused extent vector stays one round-trip wide
        self._call()
        out = self.inner.read_vec(path, spans)
        for i, chunk in enumerate(out):
            self._request("get", len(chunk), pipelined=i > 0)
        return out

    def truncate(self, path, size):
        self._call()
        prior = self._size_of(path)
        self.inner.truncate(path, size)
        if size > 0:
            with self._acct:
                self.rmw_gets += 1
            self._request("get", prior)
        with self._acct:
            self.whole_object_puts += 1
        self._request("put", self._size_of(path), pipelined=size > 0)

    def fallocate(self, path, size):
        self._call()
        prior = self._size_of(path)
        self.inner.fallocate(path, size)
        new = self._size_of(path)
        if new != prior:
            with self._acct:
                self.rmw_gets += 1
                self.whole_object_puts += 1
            self._request("get", prior)
            self._request("put", new, pipelined=True)

    def fsync(self, path):
        # PUTs are atomic + durable on completion — fsync is free wire-
        # wise, which is itself a cost signal the fuser can exploit
        self._call()
        self.inner.fsync(path)

    # -- metadata: billed as a self-COPY (S3 metadata is immutable per
    # object version, so changing it rewrites the object server-side) --

    def _meta_copy(self, path):
        self._request("copy", self._size_of(path))

    def chmod(self, path, mode):
        self._call(); self.inner.chmod(path, mode); self._meta_copy(path)

    def chown(self, path, uid, gid):
        self._call(); self.inner.chown(path, uid, gid); self._meta_copy(path)

    def utimens(self, path, atime, mtime):
        self._call(); self.inner.utimens(path, atime, mtime)
        self._meta_copy(path)

    def setxattr(self, path, key, value):
        self._call(); self.inner.setxattr(path, key, value)
        self._meta_copy(path)

    def removexattr(self, path, key):
        self._call(); self.inner.removexattr(path, key)
        self._meta_copy(path)

    # -- attributes / listing ------------------------------------------

    def stat(self, path):
        self._call()
        self._request("head")
        return self.inner.stat(path)

    def stat_vec(self, paths):
        # HEADs pipelined on one connection (first pays the RTT)
        self._call()
        for i in range(len(paths)):
            self._request("head", pipelined=i > 0)
        return self.inner.stat_vec(paths)

    def readdir(self, path):
        self._call()
        names = self.inner.readdir(path)
        self._charge_listing(len(names))
        return names

    def readdir_plus(self, path):
        # LIST responses carry size+mtime per key, so the plus variant
        # costs the same pages as the plain listing
        self._call()
        out = self.inner.readdir_plus(path)
        self._charge_listing(len(out))
        return out

    def readdir_plus_vec(self, paths):
        self._call()
        out = self.inner.readdir_plus_vec(paths)
        first = True
        for listing in out.values():
            self._charge_listing(len(listing), pipelined=not first)
            first = False
        return out

    def _charge_listing(self, n_entries: int, *,
                        pipelined: bool = False) -> None:
        pages = max(1, math.ceil(n_entries / self.list_page_size))
        for i in range(pages):
            self._request("list", pipelined=pipelined or i > 0)

    def remove_tree(self, path):
        """LIST the prefix (one request per page) then ONE unbounded
        bulk DELETE — ceil(keys/page) + 1 requests total, never a DELETE
        per key.  This is the bound ``benchmarks.backend_guard`` holds
        the engine to for extract→rmtree."""
        self._call()
        keys, pages = self._list_all(path)
        removed = self.inner.remove_tree(path)
        if keys:
            self._request("delete", pipelined=True)   # bulk multi-delete
        return removed

    # -- cost model ----------------------------------------------------

    def cost_hint(self, op: str, nbytes: int = 0) -> Optional[CostHint]:
        m = self.model
        if op == "rename":
            # copy+delete: two fresh-request RTTs before any payload
            return CostHint(rtt_s=2 * m.rtt_s, bytes_per_s=m.bytes_per_s,
                            per_request_overhead_s=m.per_request_s)
        if op in ("readdir", "list", "stat", "remove_tree"):
            # paginated / pipelined classes: continuation requests only
            # pay the per-request overhead
            return CostHint(rtt_s=m.rtt_s, bytes_per_s=m.bytes_per_s,
                            per_request_overhead_s=m.per_request_s)
        return CostHint(rtt_s=m.rtt_s, bytes_per_s=m.bytes_per_s)

    # -- plumbing ------------------------------------------------------

    def snapshot(self) -> dict:
        return self.inner.snapshot()

    def __getattr__(self, name):  # delegate anything else to the oracle
        return getattr(self.inner, name)
