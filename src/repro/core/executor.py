"""Executor layer: worker models that drain the scheduler's ready queue.

Two models, unchanged semantics from the seed engine:

* ``pool``          — N recycled workers (the paper's stated future work);
* ``thread_per_op`` — one fresh thread per ready op (the paper's actual
  implementation: "high number of threads created and scrapped", kept for
  faithful overhead comparisons).

An executor knows nothing about paths, dependencies or fusion: it pulls
ready ops and hands them to the engine's ``run`` callback, which executes
the op and reports completion back to the scheduler.

Discrete-event mode (core/simclock.py): pool workers register with the
simulation for their whole lifetime — ``attach()`` before the first pop,
``detach()`` on the way out — so the event queue always knows exactly
which actors exist and the schedule is a pure function of the op stream.
``thread_per_op`` spawns an unbounded, timing-dependent set of threads
and is rejected under a SimClock (the engine enforces this).
"""
from __future__ import annotations

import threading
from typing import Callable

from .scheduler import OpScheduler, _Op

EXECUTOR_MODES = ("pool", "thread_per_op")


class PoolExecutor:
    """Workers are numbered: worker ``i`` of ``W`` pulls from its owned
    ready-queue shards first and steals from the rest when dry (see the
    scheduler's dispatch architecture)."""

    def __init__(self, sched: OpScheduler, run: Callable[[_Op], None],
                 workers: int = 32, sim=None):
        self._threads = []
        nworkers = max(1, int(workers))
        self.nworkers = nworkers
        for i in range(nworkers):
            t = threading.Thread(target=self._worker_loop,
                                 args=(sched, run, i, nworkers, sim),
                                 name=f"cannyfs-w{i}", daemon=True)
            t.start()
            self._threads.append(t)

    @staticmethod
    def _worker_loop(sched: OpScheduler, run: Callable[[_Op], None],
                     worker: int, workers: int, sim) -> None:
        if sim is not None:
            sim.attach()
        try:
            while True:
                op = sched.next_ready(worker, workers)
                if op is None:
                    return
                run(op)
        finally:
            if sim is not None:
                sim.detach()

    def join(self) -> None:
        for t in self._threads:
            t.join()


class ThreadPerOpExecutor:
    def __init__(self, sched: OpScheduler, run: Callable[[_Op], None],
                 workers: int = 0, sim=None):   # workers ignored
        self.nworkers = 0
        t = threading.Thread(target=self._dispatcher_loop, args=(sched, run),
                             name="cannyfs-dispatch", daemon=True)
        t.start()
        self._threads = [t]

    @staticmethod
    def _dispatcher_loop(sched: OpScheduler, run: Callable[[_Op], None]) -> None:
        while True:
            op = sched.next_ready()
            if op is None:
                return
            threading.Thread(target=run, args=(op,), daemon=True).start()

    def join(self) -> None:
        for t in self._threads:
            t.join()


def make_executor(mode: str, sched: OpScheduler,
                  run: Callable[[_Op], None], workers: int, sim=None):
    if mode == "pool":
        return PoolExecutor(sched, run, workers, sim=sim)
    if mode == "thread_per_op":
        return ThreadPerOpExecutor(sched, run)
    raise ValueError(f"unknown executor: {mode!r}")
