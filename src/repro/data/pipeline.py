"""Data pipeline: synthetic LM corpora + CannyFS-staged shards + eager
prefetch.

The prefetcher applies the paper's pattern on the read side: background
workers race ahead of the consumer; ``next()`` barriers only on the
specific batch it needs (never a global drain).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Any, Callable, Iterator, Optional

import numpy as np

from repro.core import CannyFS
from repro.models.config import ModelConfig


# ---------------------------------------------------------------------------
# synthetic corpora (self-contained: no external datasets in-container)
# ---------------------------------------------------------------------------

@dataclass
class SyntheticLM:
    """Markov-ish token stream: next-token structure so a trained model's
    loss actually falls (used by the e2e example)."""
    cfg: ModelConfig
    batch: int
    seq_len: int
    seed: int = 0

    def __iter__(self) -> Iterator[dict]:
        rng = np.random.default_rng(self.seed)
        V = self.cfg.vocab_size
        # random sparse bigram table: each token has 8 likely successors
        succ = rng.integers(0, V, size=(V, 8), dtype=np.int32)
        while True:
            toks = np.empty((self.batch, self.seq_len + 1), np.int32)
            toks[:, 0] = rng.integers(0, V, size=self.batch)
            for t in range(self.seq_len):
                pick = rng.integers(0, 8, size=self.batch)
                nxt = succ[toks[:, t], pick]
                noise = rng.random(self.batch) < 0.1
                nxt = np.where(noise, rng.integers(0, V, size=self.batch),
                               nxt)
                toks[:, t + 1] = nxt
            batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
            if self.cfg.modality == "audio_stub":
                batch["features"] = rng.standard_normal(
                    (self.batch, self.seq_len, 512)).astype(np.float32)
                batch["loss_mask"] = np.ones((self.batch, self.seq_len),
                                             bool)
            if self.cfg.modality == "vision_stub":
                n_img = min(self.cfg.frontend_tokens or 16, self.seq_len // 2)
                batch["vision_embeds"] = rng.standard_normal(
                    (self.batch, n_img, self.cfg.d_model)).astype(np.float32)
                vm = np.zeros((self.batch, self.seq_len), bool)
                vm[:, 1:1 + n_img] = True
                batch["vision_mask"] = vm
                pos = np.tile(np.arange(self.seq_len, dtype=np.int32),
                              (3, self.batch, 1))
                batch["positions3"] = pos
            yield batch


# ---------------------------------------------------------------------------
# CannyFS-staged shard reader (data staged from 'remote' storage)
# ---------------------------------------------------------------------------

def write_shards(fs: CannyFS, directory: str, it: Iterator[dict],
                 n_shards: int) -> list[str]:
    """Materialize n_shards batches as .npz-style shard files through the
    eager engine (a staging job — the paper's archive-extraction shape)."""
    fs.makedirs(directory)
    paths = []
    for i in range(n_shards):
        batch = next(it)
        import io
        buf = io.BytesIO()
        np.savez(buf, **batch)
        p = f"{directory}/shard_{i:05d}.npz"
        fs.write_file(p, buf.getvalue())
        paths.append(p)
    return paths


def read_shards(fs: CannyFS, directory: str,
                chunk: int = 256 << 10) -> Iterator[dict]:
    """readdir-prefetched shard sweep (the paper's traversal acceleration
    applies: one readdir prefetches every shard's stat).

    Each shard streams back in ``chunk``-byte sequential slices rather
    than one whole-file read: the stat (warmed by the listing) bounds the
    stream so the reader never runs past EOF, and the engine's read-ahead
    plane pipelines speculative ``read_vec`` windows ahead of the
    consumer — later chunks are served from pages already in flight."""
    import io
    for name in fs.readdir(directory):
        if not name.endswith(".npz"):
            continue
        p = f"{directory}/{name}"
        remaining = fs.stat(p).size
        pieces = []
        with fs.open(p, "rb") as f:
            while remaining > 0:
                piece = f.read(min(chunk, remaining))
                if not piece:
                    break
                pieces.append(piece)
                remaining -= len(piece)
        with np.load(io.BytesIO(b"".join(pieces))) as z:
            yield {k: z[k] for k in z.files}


# ---------------------------------------------------------------------------
# eager prefetcher
# ---------------------------------------------------------------------------

class Prefetcher:
    """Bounded background prefetch: depth batches in flight; the queue bound
    is the same backpressure idea as the engine's max_inflight."""

    def __init__(self, it: Iterator[dict], depth: int = 2,
                 transform: Optional[Callable[[dict], Any]] = None):
        self._it = it
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._transform = transform
        self._done = object()
        self._thread = threading.Thread(target=self._fill, daemon=True,
                                        name="data-prefetch")
        self._thread.start()

    def _fill(self):
        try:
            for item in self._it:
                if self._transform is not None:
                    item = self._transform(item)
                self._q.put(item)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item
