from .pipeline import Prefetcher, SyntheticLM, read_shards, write_shards

__all__ = ["Prefetcher", "SyntheticLM", "read_shards", "write_shards"]
