"""Transformer / SSM / RG-LRU blocks, as pure functions over param pytrees.

Block contract:

    apply_block(kind, params, x, ctx, cache) -> (x_out, new_cache)

where ``ctx`` carries positions, rotary tables, config and the activation-
sharding hook.  ``cache=None`` means training (full-sequence, no state);
otherwise cache is this block's decode state and is threaded functionally.
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.kernels.ssd.ref import ssd_decode_step
from repro.kernels.rglru.ref import rglru_gates
from .config import ATTN_KINDS, ModelConfig
from .layers import act_fn, dense, gated_mlp, rmsnorm
from .rope import apply_rotary


# ---------------------------------------------------------------------------
# initialization helpers
# ---------------------------------------------------------------------------

def _norm_init(cfg, key, fan_in, shape):
    std = fan_in ** -0.5
    return (jax.random.truncated_normal(key, -3, 3, shape, jnp.float32) * std)


def init_attn(key, cfg: ModelConfig) -> dict:
    D, dh = cfg.d_model, cfg.resolved_head_dim
    H, K = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": _norm_init(cfg, ks[0], D, (D, H * dh)),
        "wk": _norm_init(cfg, ks[1], D, (D, K * dh)),
        "wv": _norm_init(cfg, ks[2], D, (D, K * dh)),
        "wo": _norm_init(cfg, ks[3], H * dh, (H * dh, D)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * dh,), jnp.float32)
        p["bk"] = jnp.zeros((K * dh,), jnp.float32)
        p["bv"] = jnp.zeros((K * dh,), jnp.float32)
    return p


def init_ffn(key, cfg: ModelConfig, d_ff: int) -> dict:
    D = cfg.d_model
    ks = jax.random.split(key, 3)
    return {
        "gate": _norm_init(cfg, ks[0], D, (D, d_ff)),
        "up": _norm_init(cfg, ks[1], D, (D, d_ff)),
        "down": _norm_init(cfg, ks[2], d_ff, (d_ff, D)),
    }


def init_moe(key, cfg: ModelConfig) -> dict:
    D, E = cfg.d_model, cfg.num_experts
    F = cfg.moe_dff or cfg.d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": _norm_init(cfg, ks[0], D, (D, E)),
        "w_gate": _norm_init(cfg, ks[1], D, (E, D, F)),
        "w_up": _norm_init(cfg, ks[2], D, (E, D, F)),
        "w_down": _norm_init(cfg, ks[3], F, (E, F, D)),
    }
    if cfg.shared_expert_dff:
        p["shared"] = init_ffn(ks[4], cfg, cfg.shared_expert_dff)
    return p


def init_ssd(key, cfg: ModelConfig) -> dict:
    D, di = cfg.d_model, cfg.d_inner
    G, N, H = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    conv_ch = di + 2 * G * N
    ks = jax.random.split(key, 4)
    return {
        "in_proj": _norm_init(cfg, ks[0], D, (D, 2 * di + 2 * G * N + H)),
        "conv_w": _norm_init(cfg, ks[1], cfg.ssm_conv, (conv_ch, cfg.ssm_conv)),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.linspace(1e-3, 0.1, H))).astype(jnp.float32),
        "norm": jnp.ones((di,), jnp.float32),
        "out_proj": _norm_init(cfg, ks[2], di, (di, D)),
    }


def init_rglru(key, cfg: ModelConfig) -> dict:
    D, W = cfg.d_model, cfg.resolved_lru_width
    Hb = cfg.num_heads
    bw = W // Hb
    ks = jax.random.split(key, 6)
    # a_param init so the decay a lies in (0.9, 0.999) (Griffin appendix):
    # log a = -8 softplus(a_param) r, r~1  =>  a_param = softplus^-1(-log(u)/8)
    u = jax.random.uniform(ks[0], (W,), jnp.float32, 0.9, 0.999)
    a_param = jnp.log(jnp.expm1(-jnp.log(u) / 8.0))
    return {
        "in_x": _norm_init(cfg, ks[1], D, (D, W)),
        "in_gate": _norm_init(cfg, ks[2], D, (D, W)),
        "a_gate_w": _norm_init(cfg, ks[3], bw, (Hb, bw, bw)),
        "a_gate_b": jnp.zeros((Hb, bw), jnp.float32),
        "x_gate_w": _norm_init(cfg, ks[4], bw, (Hb, bw, bw)),
        "x_gate_b": jnp.zeros((Hb, bw), jnp.float32),
        "a_param": a_param,
        "conv_w": _norm_init(cfg, ks[5], cfg.ssm_conv, (W, cfg.ssm_conv)),
        "conv_b": jnp.zeros((W,), jnp.float32),
        "out": _norm_init(cfg, ks[0], W, (W, D)),
    }


def init_mixer(key, cfg: ModelConfig, kind: str) -> dict:
    if kind in ATTN_KINDS:
        return init_attn(key, cfg)
    if kind == "ssd":
        return init_ssd(key, cfg)
    if kind == "rglru":
        return init_rglru(key, cfg)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# causal depthwise conv (shared by ssd / rglru)
# ---------------------------------------------------------------------------

def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array,
                  state: jax.Array | None = None):
    """x (B,S,C), w (C,K) depthwise, causal.  With ``state`` (B,K-1,C) the
    conv consumes carried history and returns the updated state."""
    B, S, C = x.shape
    K = w.shape[1]
    if state is not None:
        x_ext = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    else:
        x_ext = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        x_ext, w.astype(x.dtype)[:, None, :].transpose(2, 1, 0),  # (K,1,C)->OIW?
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=C)
    out = out + b.astype(x.dtype)
    new_state = x_ext[:, -(K - 1):, :] if K > 1 else None
    return out, new_state


# ---------------------------------------------------------------------------
# attention block
# ---------------------------------------------------------------------------

def _attn_geometry(cfg: ModelConfig, kind: str):
    causal = cfg.causal and kind != "attn_bidir"
    window = cfg.window if kind in ("attn_sliding", "attn_local") else 0
    chunk = cfg.chunk_size if kind == "attn_chunked" else 0
    use_rope = cfg.pos_type != "none" and kind != "attn_global"  # iRoPE/NoPE
    return causal, window, chunk, use_rope


def attn_forward(p, x, kind, ctx, cache=None):
    cfg: ModelConfig = ctx["cfg"]
    B, S, D = x.shape
    H, K, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    causal, window, chunk, use_rope = _attn_geometry(cfg, kind)

    q = dense(x, p["wq"], p.get("bq")).reshape(B, S, H, dh)
    k = dense(x, p["wk"], p.get("bk")).reshape(B, S, K, dh)
    v = dense(x, p["wv"], p.get("bv")).reshape(B, S, K, dh)
    if use_rope:
        q = apply_rotary(q, ctx["cos"], ctx["sin"])
        k = apply_rotary(k, ctx["cos"], ctx["sin"])

    if cache is None:  # training: pure self-attention
        out = kops.flash_attention(q, k, v, causal=causal, window=window,
                                   chunk=chunk)
        new_cache = None
    else:
        Sc = cache["k"].shape[1]
        t = ctx["t"]  # int32 scalar: #tokens already in cache
        if S > 1:      # prefill (t == 0)
            if S <= Sc:
                ck = jax.lax.dynamic_update_slice(
                    cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
                cv = jax.lax.dynamic_update_slice(
                    cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
                cpos = jax.lax.dynamic_update_slice(
                    cache["pos"], jnp.arange(S, dtype=jnp.int32), (0,))
            else:
                # ring cache shorter than the prompt (sliding/chunked):
                # keep the last Sc tokens at their ring slots p % Sc
                shift = S % Sc
                ck = jnp.roll(k[:, S - Sc:].astype(cache["k"].dtype),
                              shift, axis=1)
                cv = jnp.roll(v[:, S - Sc:].astype(cache["v"].dtype),
                              shift, axis=1)
                cpos = jnp.roll(jnp.arange(S - Sc, S, dtype=jnp.int32), shift)
            out = kops.flash_attention(q, k, v, causal=causal, window=window,
                                       chunk=chunk)
        else:          # decode one token at position t
            slot = jnp.mod(t, Sc)
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
            cpos = jax.lax.dynamic_update_slice(
                cache["pos"], t[None].astype(jnp.int32), (slot,))
            seq_axes = ctx.get("kv_seq_axes")
            if seq_axes and kind != "attn_bidir":
                # sequence-sharded cache -> distributed flash-decode
                from repro.parallel.flash_decode import (
                    seq_sharded_decode_attention)
                out = seq_sharded_decode_attention(
                    ctx["mesh"], seq_axes, q, ck.astype(q.dtype),
                    cv.astype(q.dtype), cpos, t.astype(jnp.int32),
                    batch_axes=ctx.get("kv_batch_axes", ()),
                    causal=causal, window=window, chunk=chunk)
            else:
                q_pos = jnp.broadcast_to(t[None, None], (B, 1)).astype(jnp.int32)
                k_pos = jnp.broadcast_to(cpos[None], (B, Sc))
                out = kops.flash_attention(
                    q, ck.astype(q.dtype), cv.astype(q.dtype), causal=causal,
                    window=window, chunk=chunk, q_positions=q_pos,
                    k_positions=k_pos)
        new_cache = {"k": ck, "v": cv, "pos": cpos}

    out = out.reshape(B, S, H * dh)
    return dense(out, p["wo"]), new_cache


# ---------------------------------------------------------------------------
# MoE block (scatter-based dropless-with-capacity dispatch)
# ---------------------------------------------------------------------------

def moe_forward(p, x, ctx):
    """x (B,S,D).  Each batch row is a dispatch group (maps onto the dp
    shard); capacity bounds the per-expert buffer.  Returns (y, aux_loss)."""
    cfg: ModelConfig = ctx["cfg"]
    B, S, D = x.shape
    E, kk = cfg.num_experts, cfg.experts_per_token
    C = int(math.ceil(S * kk * cfg.capacity_factor / E))
    C = max(min(C, S * kk), 1)

    router_logits = dense(x, p["router"]).astype(jnp.float32)  # (B,S,E)
    probs = jax.nn.softmax(router_logits, axis=-1)
    w, sel = jax.lax.top_k(probs, kk)                          # (B,S,k)
    w = w / jnp.maximum(jnp.sum(w, -1, keepdims=True), 1e-9)

    # ---- slot bookkeeping: position of each (token,k) within its expert
    e_flat = sel.reshape(B, S * kk)                            # (B, T)
    order = jnp.argsort(e_flat, axis=1, stable=True)
    e_sorted = jnp.take_along_axis(e_flat, order, axis=1)
    seg_start = jax.vmap(lambda es: jnp.searchsorted(es, jnp.arange(E)))(e_sorted)
    ranks_sorted = jnp.arange(S * kk)[None] - jnp.take_along_axis(
        seg_start, e_sorted, axis=1)
    inv = jnp.argsort(order, axis=1)
    ranks = jnp.take_along_axis(ranks_sorted, inv, axis=1)     # (B,T)
    keep = ranks < C
    pos = jnp.where(keep, ranks, C)                            # overflow -> slot C

    # ---- dispatch: buf (B,E,C+1,D); slot C is the overflow trash slot
    tok = jnp.repeat(jnp.arange(S), kk)[None].repeat(B, 0)     # (B,T) token ids
    xs = jnp.take_along_axis(x, tok[..., None], axis=1)        # (B,T,D)
    buf = jnp.zeros((B, E, C + 1, D), x.dtype)
    bidx = jnp.arange(B)[:, None].repeat(S * kk, 1)
    buf = buf.at[bidx, e_flat, pos].set(xs)

    # ---- expert FFN (stacked einsum; E dim shards as EP)
    h = buf[:, :, :C]                                          # (B,E,C,D)
    g = act_fn(cfg.act)(jnp.einsum("becd,edf->becf", h,
                                   p["w_gate"].astype(x.dtype)))
    u = jnp.einsum("becd,edf->becf", h, p["w_up"].astype(x.dtype))
    y_e = jnp.einsum("becf,efd->becd", g * u, p["w_down"].astype(x.dtype))
    y_e = jnp.pad(y_e, ((0, 0), (0, 0), (0, 1), (0, 0)))       # restore slot C

    # ---- combine
    gathered = y_e[bidx, e_flat, pos]                          # (B,T,D)
    wk = (w.reshape(B, S * kk) * keep).astype(x.dtype)
    y = jnp.sum((gathered * wk[..., None]).reshape(B, S, kk, D), axis=2)

    if "shared" in p:
        y = y + gated_mlp(x, p["shared"], cfg.act)

    # ---- Switch-style load-balance aux loss
    frac_tokens = jnp.mean(
        (jax.nn.one_hot(sel, E).sum(2) > 0).astype(jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * frac_probs)
    return y, aux


# ---------------------------------------------------------------------------
# Mamba-2 (SSD) block
# ---------------------------------------------------------------------------

def ssd_forward(p, x, ctx, cache=None):
    cfg: ModelConfig = ctx["cfg"]
    B, S, D = x.shape
    di, G, N = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state
    H, P = cfg.ssm_heads, cfg.ssm_head_dim

    zxbcdt = dense(x, p["in_proj"])
    z, xBC, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * G * N], axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    xBC, new_conv = causal_conv1d(xBC, p["conv_w"], p["conv_b"], conv_state)
    xBC = jax.nn.silu(xBC)
    xs, Bm, Cm = jnp.split(xBC, [di, di + G * N], axis=-1)
    xs = xs.reshape(B, S, H, P)
    Bm = Bm.reshape(B, S, G, N)
    Cm = Cm.reshape(B, S, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    if cache is None:
        y = kops.ssd_scan(xs, dt, A, Bm, Cm, p["D"], chunk=cfg.ssm_chunk)
        new_cache = None
    elif S > 1:  # prefill: run the scan, then recompute the final state
        y = kops.ssd_scan(xs, dt, A, Bm, Cm, p["D"], chunk=cfg.ssm_chunk)
        # final state via sequential fold of the last chunk is cheap but
        # simplest correct option: fold everything (prefill is one-time)
        state = cache["state"]
        def fold(state, t):
            s, _ = ssd_decode_step(state, xs[:, t], dt[:, t], A,
                                   Bm[:, t], Cm[:, t], p["D"])
            return s, None
        state, _ = jax.lax.scan(fold, state.astype(jnp.float32),
                                jnp.arange(S))
        new_cache = {"conv": new_conv, "state": state}
    else:        # decode
        state, y = ssd_decode_step(cache["state"], xs[:, 0], dt[:, 0], A,
                                   Bm[:, 0], Cm[:, 0], p["D"])
        y = y[:, None]
        new_cache = {"conv": new_conv, "state": state}

    y = y.reshape(B, S, di)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], eps=cfg.norm_eps)
    return dense(y, p["out_proj"]), new_cache


# ---------------------------------------------------------------------------
# RG-LRU block
# ---------------------------------------------------------------------------

def rglru_forward(p, x, ctx, cache=None):
    cfg: ModelConfig = ctx["cfg"]
    B, S, D = x.shape
    W = cfg.resolved_lru_width
    xb = dense(x, p["in_x"])
    gate = act_fn("gelu")(dense(x, p["in_gate"]))
    conv_state = cache["conv"] if cache is not None else None
    xb, new_conv = causal_conv1d(xb, p["conv_w"], p["conv_b"], conv_state)
    log_a, gx = rglru_gates(xb, p)
    h0 = cache["h"] if cache is not None else None
    y, h_last = kops.rglru_scan(log_a, gx, h0=h0)
    y = y.astype(x.dtype) * gate
    out = dense(y, p["out"])
    new_cache = None if cache is None else {"conv": new_conv, "h": h_last}
    return out, new_cache


# ---------------------------------------------------------------------------
# unified block application (pre-norm residual layer)
# ---------------------------------------------------------------------------

def apply_block(kind: str, p: dict, x: jax.Array, ctx: dict,
                cache: Optional[dict] = None):
    """One full layer: mixer + FFN/MoE, pre-norm residuals.

    Returns (x, new_cache, aux_loss)."""
    cfg: ModelConfig = ctx["cfg"]
    constrain = ctx.get("constrain", lambda a: a)
    aux = jnp.zeros((), jnp.float32)

    h = rmsnorm(x, p["norm1"], eps=cfg.norm_eps)
    if kind in ATTN_KINDS:
        mixed, new_cache = attn_forward(p["mixer"], h, kind, ctx, cache)
    elif kind == "ssd":
        mixed, new_cache = ssd_forward(p["mixer"], h, ctx, cache)
    elif kind == "rglru":
        mixed, new_cache = rglru_forward(p["mixer"], h, ctx, cache)
    else:
        raise ValueError(kind)
    x = constrain(x + mixed)

    if "moe" in p or "ffn" in p:   # mamba2 backbone is mixer-only (d_ff=0)
        h = rmsnorm(x, p["norm2"], eps=cfg.norm_eps)
        if "moe" in p:
            y, aux = moe_forward(p["moe"], h, ctx)
        else:
            y = gated_mlp(h, p["ffn"], cfg.act)
        x = constrain(x + y)
    return x, new_cache, aux
