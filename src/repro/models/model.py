"""Model assembly: embeddings → scanned superblocks → head.

The layer stack is organized as ``pattern × n_superblocks (+ remainder)``;
per-pattern-position parameter trees are stacked along a leading superblock
axis and the forward pass ``lax.scan``s over them (compact HLO — one
superblock traced once regardless of depth — which is what keeps the
512-device dry-run compile times sane and is standard production practice).

Public entry points:

    init_params(rng, cfg)                   -> fp32 param pytree
    forward_train(params, batch, cfg, ...)  -> (logits, aux)
    init_cache(cfg, B, max_len, dtype)      -> decode cache pytree
    prefill(params, batch, cache, cfg, ...) -> (last_logits, cache)
    decode_step(params, tokens, cache, cfg) -> (logits, cache)
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from . import blocks
from .config import ATTN_KINDS, ModelConfig
from .layers import cast_tree, embed, rmsnorm, unembed
from .rope import mrope_cos_sin, rope_cos_sin, text_positions3


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ModelConfig, kind: str, layer_idx: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "norm1": jnp.ones((cfg.d_model,), jnp.float32),
        "mixer": blocks.init_mixer(k1, cfg, kind),
    }
    if cfg.num_experts and layer_idx >= cfg.first_k_dense:
        p["norm2"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["moe"] = blocks.init_moe(k2, cfg)
    elif cfg.d_ff:
        p["norm2"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["ffn"] = blocks.init_ffn(k3, cfg, cfg.d_ff)
    return p


def init_params(rng, cfg: ModelConfig) -> dict:
    n_pat = len(cfg.block_pattern)
    n_sb = cfg.n_superblocks
    keys = jax.random.split(rng, cfg.num_layers + 4)
    params: dict[str, Any] = {}
    params["embed"] = (jax.random.truncated_normal(
        keys[-1], -3, 3, (cfg.vocab_size, cfg.d_model), jnp.float32)
        * cfg.d_model ** -0.5)
    if cfg.modality == "audio_stub":
        params["frontend_proj"] = (jax.random.truncated_normal(
            keys[-2], -3, 3, (512, cfg.d_model), jnp.float32) * 512 ** -0.5)

    # stacked superblocks: per pattern position, stack n_sb layer trees
    stacked = []
    for pos in range(n_pat):
        per_layer = [
            _init_block(keys[sb * n_pat + pos], cfg, cfg.block_pattern[pos],
                        sb * n_pat + pos)
            for sb in range(n_sb)
        ]
        stacked.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer))
    params["blocks"] = stacked

    # remainder layers (unstacked)
    base = n_sb * n_pat
    params["rem"] = [
        _init_block(keys[base + i], cfg, kind, base + i)
        for i, kind in enumerate(cfg.remainder_pattern)
    ]

    params["final_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.truncated_normal(
            keys[-3], -3, 3, (cfg.d_model, cfg.vocab_size), jnp.float32)
            * cfg.d_model ** -0.5)
    return params


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------

def _cache_len(cfg: ModelConfig, kind: str, max_len: int) -> int:
    if kind in ("attn_sliding", "attn_local"):
        return min(cfg.window, max_len)
    if kind == "attn_chunked":
        return min(cfg.chunk_size, max_len)
    return max_len


def _init_block_cache(cfg: ModelConfig, kind: str, B: int, max_len: int,
                      dtype) -> Optional[dict]:
    if kind in ATTN_KINDS:
        Sc = _cache_len(cfg, kind, max_len)
        K, dh = cfg.num_kv_heads, cfg.resolved_head_dim
        return {
            "k": jnp.zeros((B, Sc, K, dh), dtype),
            "v": jnp.zeros((B, Sc, K, dh), dtype),
            "pos": jnp.full((Sc,), -1, jnp.int32),
        }
    if kind == "ssd":
        conv_ch = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
        return {
            "conv": jnp.zeros((B, cfg.ssm_conv - 1, conv_ch), dtype),
            "state": jnp.zeros((B, cfg.ssm_heads, cfg.ssm_head_dim,
                                cfg.ssm_state), jnp.float32),
        }
    if kind == "rglru":
        W = cfg.resolved_lru_width
        return {
            "conv": jnp.zeros((B, cfg.ssm_conv - 1, W), dtype),
            "h": jnp.zeros((B, W), jnp.float32),
        }
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> dict:
    n_sb = cfg.n_superblocks
    stacked = []
    for kind in cfg.block_pattern:
        one = _init_block_cache(cfg, kind, batch, max_len, dtype)
        stacked.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_sb,) + x.shape), one))
    rem = [_init_block_cache(cfg, kind, batch, max_len, dtype)
           for kind in cfg.remainder_pattern]
    return {"blocks": stacked, "rem": rem, "t": jnp.zeros((), jnp.int32)}


# ---------------------------------------------------------------------------
# forward machinery
# ---------------------------------------------------------------------------

def _make_ctx(cfg: ModelConfig, positions, positions3, dtype, t,
              constrain, extra_ctx=None) -> dict:
    dh = cfg.resolved_head_dim
    if cfg.pos_type == "mrope":
        p3 = positions3 if positions3 is not None else text_positions3(positions)
        cos, sin = mrope_cos_sin(p3, dh, cfg.rope_theta, cfg.mrope_sections)
    elif cfg.pos_type == "rope":
        cos, sin = rope_cos_sin(positions, dh, cfg.rope_theta)
    else:
        cos = sin = None
    ctx = {"cfg": cfg, "cos": cos, "sin": sin, "t": t,
           "constrain": constrain or (lambda x: x)}
    if extra_ctx:
        ctx.update(extra_ctx)
    return ctx


def _embed_inputs(params, batch, cfg: ModelConfig, dtype):
    if cfg.modality == "audio_stub":
        # stub frontend: precomputed conv features (B,S,512) -> d_model
        x = batch["features"].astype(dtype) @ params["frontend_proj"].astype(dtype)
        return x
    x = embed(batch["tokens"], params["embed"], dtype)
    if cfg.modality == "vision_stub" and "vision_embeds" in batch:
        # early fusion: scatter precomputed patch embeddings over the
        # placeholder token positions (vision_mask True)
        ve = batch["vision_embeds"].astype(dtype)       # (B, n_img, D)
        mask = batch["vision_mask"]                     # (B, S) bool
        B, S, D = x.shape
        n_img = ve.shape[1]
        # positions of the j-th True in each row -> scatter target
        idx = jnp.argsort(~mask, axis=1, stable=True)[:, :n_img]  # (B,n_img)
        rows = jnp.arange(B)[:, None]
        x = x.at[rows, idx].set(
            jnp.where(jnp.take_along_axis(mask, idx, 1)[..., None], ve,
                      x[rows, idx]))
    return x


def _run_stack(params, x, cfg: ModelConfig, ctx, cache, *,
               remat_policy: Optional[str] = None, dtype=jnp.bfloat16,
               scan_layers: bool = True):
    """Scan superblocks (+ remainder layers); returns (x, new_cache, aux).

    scan_layers=False unrolls the superblock loop in Python — identical
    math, one HLO instance per layer.  The dry-run uses this so
    cost_analysis / collective parsing attribute per-layer work exactly
    (XLA's cost analysis counts a while body once, not × trip count);
    production training keeps the scan for compact HLO."""
    pattern = cfg.block_pattern
    aux_total = jnp.zeros((), jnp.float32)

    def superblock(x, layer_params, layer_cache):
        layer_params = cast_tree(layer_params, dtype)
        new_caches = []
        aux_sb = jnp.zeros((), jnp.float32)
        for i, kind in enumerate(pattern):
            c = None if layer_cache is None else layer_cache[i]
            x, nc, aux = blocks.apply_block(kind, layer_params[i], x, ctx, c)
            new_caches.append(nc)
            aux_sb = aux_sb + aux
        return x, tuple(new_caches), aux_sb

    if remat_policy:
        from repro.parallel.remat import wrap_remat
        superblock = wrap_remat(superblock, remat_policy)

    n_sb = cfg.n_superblocks

    def sb_slice(tree, i):
        return jax.tree.map(lambda a: a[i], tree)

    if cache is None:
        if scan_layers:
            def body(carry, layer_params):
                x, aux = carry
                x, _, aux_sb = superblock(x, layer_params, None)
                return (x, aux + aux_sb), None
            (x, aux_total), _ = jax.lax.scan(
                body, (x, aux_total), tuple(params["blocks"]))
        else:
            for i in range(n_sb):
                x, _, aux_sb = superblock(
                    x, sb_slice(tuple(params["blocks"]), i), None)
                aux_total = aux_total + aux_sb
        new_block_caches = None
    else:
        if scan_layers:
            def body(carry, xs):
                x, aux = carry
                layer_params, layer_cache = xs
                x, ncs, aux_sb = superblock(x, layer_params, layer_cache)
                return (x, aux + aux_sb), ncs
            (x, aux_total), new_block_caches = jax.lax.scan(
                body, (x, aux_total), (tuple(params["blocks"]),
                                       tuple(cache["blocks"])))
        else:
            ncs_all = []
            for i in range(n_sb):
                x, ncs, aux_sb = superblock(
                    x, sb_slice(tuple(params["blocks"]), i),
                    sb_slice(tuple(cache["blocks"]), i))
                aux_total = aux_total + aux_sb
                ncs_all.append(ncs)
            # restack to match the scanned layout (n_sb leading axis)
            new_block_caches = jax.tree.map(
                lambda *xs: jnp.stack(xs), *ncs_all)

    # remainder layers
    new_rem = []
    base = cfg.n_superblocks * len(pattern)
    for i, kind in enumerate(cfg.remainder_pattern):
        p = cast_tree(params["rem"][i], dtype)
        c = None if cache is None else cache["rem"][i]
        x, nc, aux = blocks.apply_block(kind, p, x, ctx, c)
        new_rem.append(nc)
        aux_total = aux_total + aux

    if cache is None:
        return x, None, aux_total
    new_cache = {"blocks": list(new_block_caches), "rem": new_rem,
                 "t": cache["t"]}
    return x, new_cache, aux_total


def _head(params, x, cfg: ModelConfig):
    x = rmsnorm(x, params["final_norm"].astype(x.dtype), eps=cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return unembed(x, table, tied=cfg.tie_embeddings,
                   softcap=cfg.logit_softcap)


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def forward_train(params, batch, cfg: ModelConfig, *, dtype=jnp.bfloat16,
                  remat_policy: Optional[str] = None,
                  constrain: Optional[Callable] = None,
                  scan_layers: bool = True, extra_ctx=None):
    """Full-sequence forward; returns (logits (B,S,V), aux_loss)."""
    x = _embed_inputs(params, batch, cfg, dtype)
    B, S = x.shape[:2]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    ctx = _make_ctx(cfg, positions, batch.get("positions3"), dtype,
                    jnp.zeros((), jnp.int32), constrain, extra_ctx)
    x, _, aux = _run_stack(params, x, cfg, ctx, None,
                           remat_policy=remat_policy, dtype=dtype,
                           scan_layers=scan_layers)
    return _head(params, x, cfg), aux


def prefill(params, batch, cache, cfg: ModelConfig, *, dtype=jnp.bfloat16,
            constrain: Optional[Callable] = None, extra_ctx=None,
            scan_layers: bool = True):
    """Process the prompt, fill the cache, return last-position logits only
    (never materializes (B,S,V))."""
    x = _embed_inputs(params, batch, cfg, dtype)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    ctx = _make_ctx(cfg, positions, batch.get("positions3"), dtype,
                    jnp.zeros((), jnp.int32), constrain, extra_ctx)
    x, new_cache, _ = _run_stack(params, x, cfg, ctx, cache, dtype=dtype,
                                 scan_layers=scan_layers)
    new_cache["t"] = jnp.asarray(S, jnp.int32)
    logits = _head(params, x[:, -1:], cfg)
    return logits[:, 0], new_cache


def decode_step(params, tokens, cache, cfg: ModelConfig, *,
                dtype=jnp.bfloat16, constrain: Optional[Callable] = None,
                extra_ctx=None, scan_layers: bool = True):
    """One decode step: tokens (B,1) int32 -> (logits (B,V), new cache)."""
    x = embed(tokens, params["embed"], dtype)
    B = x.shape[0]
    t = cache["t"]
    positions = jnp.broadcast_to(t[None, None], (B, 1)).astype(jnp.int32)
    ctx = _make_ctx(cfg, positions, None, dtype, t, constrain, extra_ctx)
    x, new_cache, _ = _run_stack(params, x, cfg, ctx, cache, dtype=dtype,
                                 scan_layers=scan_layers)
    new_cache["t"] = t + 1
    logits = _head(params, x, cfg)
    return logits[:, 0], new_cache


def param_specs(cfg: ModelConfig) -> Any:
    """ShapeDtypeStruct tree of the parameters (no allocation) — used by the
    dry-run."""
    return jax.eval_shape(lambda k: init_params(k, cfg),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))
