"""Shared layer primitives: norms, gated MLPs, embeddings, losses.

Everything is a pure function over explicit parameter pytrees; compute dtype
is the dtype of the activations passed in (params are cast at the call
site by ``model.apply``-level code).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm computed in fp32 (mixed-precision-sensitive reduction)."""
    from repro.kernels import ops as kops  # late import; dispatch layer
    return kops.rmsnorm(x, scale, eps=eps)


def dense(x: jax.Array, w: jax.Array, b: jax.Array | None = None) -> jax.Array:
    y = x @ w.astype(x.dtype)
    if b is not None:
        y = y + b.astype(x.dtype)
    return y


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": partial(jax.nn.gelu, approximate=True)}[name]


def gated_mlp(x: jax.Array, p: dict, act: str = "silu") -> jax.Array:
    """SwiGLU/GeGLU: down( act(x @ gate) * (x @ up) )."""
    g = act_fn(act)(dense(x, p["gate"]))
    u = dense(x, p["up"])
    return dense(g * u, p["down"])


def embed(tokens: jax.Array, table: jax.Array, dtype) -> jax.Array:
    return jnp.take(table, tokens, axis=0).astype(dtype)


def unembed(x: jax.Array, table_or_head: jax.Array, *,
            tied: bool, softcap: float = 0.0) -> jax.Array:
    w = table_or_head.astype(x.dtype)
    logits = x @ (w.T if tied else w)
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    return logits


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: jax.Array | None = None, z_loss: float = 0.0,
                  compute_dtype=jnp.float32):
    """Token-mean CE with fp32 reductions (default) and optional z-loss.

    logits (..., V) any float dtype; labels (...) int32; mask (...) bool.
    The gold logit is extracted with a masked reduction rather than a
    gather: it partitions trivially when V is model-sharded (gathers on a
    sharded dim trip XLA's SPMD partitioner inside partial-manual regions).

    compute_dtype=bfloat16 skips the fp32 materialization of the
    (B,S,V) tensor — a memory-roofline lever; the per-token max subtraction
    keeps it stable and the final reductions still accumulate in fp32."""
    logits = logits.astype(compute_dtype)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    sumexp = jnp.sum(jnp.exp(shifted), axis=-1, dtype=jnp.float32)
    lse = jnp.log(sumexp) + m[..., 0].astype(jnp.float32)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    gold = jnp.sum(jnp.where(vocab_iota == labels[..., None], logits, 0.0),
                   axis=-1, dtype=jnp.float32)
    nll = lse - gold
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    if mask is None:
        return jnp.mean(nll), jnp.size(nll)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(nll * mask) / denom, denom


def cast_tree(tree, dtype):
    """Cast floating leaves of a param tree to the compute dtype."""
    def c(x):
        if isinstance(x, jax.Array) or hasattr(x, "dtype"):
            if jnp.issubdtype(x.dtype, jnp.floating):
                return x.astype(dtype)
        return x
    return jax.tree.map(c, tree)
