"""repro.models — pure-functional JAX model zoo for the 10 assigned archs."""
from .config import ModelConfig, ATTN_KINDS, MIXER_KINDS
from .model import (decode_step, forward_train, init_cache, init_params,
                    param_specs, prefill)
from .layers import cross_entropy

__all__ = [
    "ATTN_KINDS", "MIXER_KINDS", "ModelConfig", "cross_entropy",
    "decode_step", "forward_train", "init_cache", "init_params",
    "param_specs", "prefill",
]
