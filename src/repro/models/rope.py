"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE.

M-RoPE (multimodal RoPE, arXiv:2409.12191): the head-dim frequency bands are
split into (temporal, height, width) sections; each band rotates by the
corresponding coordinate of the 3-D position id.  Text tokens carry equal
(t,h,w) ids, so M-RoPE degenerates to RoPE on pure text.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _inv_freq(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def rope_cos_sin(positions: jax.Array, head_dim: int, theta: float):
    """positions (..., S) int -> cos/sin (..., S, head_dim)."""
    inv = _inv_freq(head_dim, theta)
    freqs = positions[..., None].astype(jnp.float32) * inv  # (..., S, half)
    emb = jnp.concatenate([freqs, freqs], axis=-1)
    return jnp.cos(emb), jnp.sin(emb)


def mrope_cos_sin(positions3: jax.Array, head_dim: int, theta: float,
                  sections: tuple):
    """positions3 (3, ..., S) -> cos/sin (..., S, head_dim).

    sections partition the half-dim frequency bands among (t, h, w)."""
    assert positions3.shape[0] == 3
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    inv = _inv_freq(head_dim, theta)
    # (3, ..., S, half)
    freqs = positions3[..., None].astype(jnp.float32) * inv
    # pick which of t/h/w drives each band
    band_src = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections), total_repeat_length=half)
    freqs = jnp.take_along_axis(
        freqs, band_src[(None,) * (freqs.ndim - 1)].astype(jnp.int32),
        axis=0)[0]  # (..., S, half)
    emb = jnp.concatenate([freqs, freqs], axis=-1)
    return jnp.cos(emb), jnp.sin(emb)


def _rotate_half(x: jax.Array) -> jax.Array:
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([-x2, x1], axis=-1)


def apply_rotary(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (B, S, H, dh); cos/sin (B, S, dh) or (S, dh)."""
    while cos.ndim < x.ndim:
        cos = cos[..., None, :] if cos.ndim == x.ndim - 1 else cos[None]
        sin = sin[..., None, :] if sin.ndim == x.ndim - 1 else sin[None]
    orig = x.dtype
    x32 = x.astype(jnp.float32)
    out = x32 * cos + _rotate_half(x32) * sin
    return out.astype(orig)


def text_positions3(positions: jax.Array) -> jax.Array:
    """Lift 1-D positions to degenerate (t,h,w) ids for text tokens."""
    return jnp.broadcast_to(positions[None], (3,) + positions.shape)
