"""Model configuration.

One frozen dataclass covers all five assigned families (dense / moe / ssm /
hybrid / encoder / vlm-backbone).  Layers are organized as repeating
*superblocks* (the layer pattern) so that the forward pass can
``lax.scan`` over superblocks — compact HLO, fast multi-device compiles,
and the standard production trick (MaxText-style scanned layers).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field


# block kinds usable inside a superblock pattern
ATTN_KINDS = {"attn", "attn_bidir", "attn_sliding", "attn_chunked",
              "attn_global", "attn_local"}
MIXER_KINDS = ATTN_KINDS | {"ssd", "rglru"}


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encoder | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    # layer pattern: one superblock = this tuple of mixer kinds; the stack is
    # pattern * n_superblocks + pattern[:remainder]
    block_pattern: tuple = ("attn",)

    head_dim: int = 0                # 0 -> d_model // num_heads
    qkv_bias: bool = False
    causal: bool = True
    window: int = 0                  # sliding/local attention window
    chunk_size: int = 0              # llama4-style chunked attention
    rope_theta: float = 10000.0
    pos_type: str = "rope"           # rope | mrope | none
    mrope_sections: tuple = ()       # e.g. (16, 24, 24) for qwen2-vl
    norm_eps: float = 1e-6
    act: str = "silu"                # silu | gelu
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_dff: int = 0                 # per-expert hidden dim (0 -> d_ff)
    shared_expert_dff: int = 0       # always-on shared expert hidden dim
    first_k_dense: int = 0           # leading dense layers (DeepSeek-style)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # --- SSM (Mamba-2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 128

    # --- RG-LRU (Griffin / RecurrentGemma) ---
    lru_width: int = 0               # 0 -> d_model

    # --- modality stub frontends ---
    modality: str = "text"           # text | vision_stub | audio_stub
    frontend_tokens: int = 0         # patches/frames injected per sample

    # ------------------------------------------------------------------

    def __post_init__(self):
        assert self.num_layers >= 1
        for k in self.block_pattern:
            assert k in MIXER_KINDS, k

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // max(self.num_heads, 1)

    @property
    def n_superblocks(self) -> int:
        return self.num_layers // len(self.block_pattern)

    @property
    def remainder_pattern(self) -> tuple:
        r = self.num_layers % len(self.block_pattern)
        return self.block_pattern[:r]

    @property
    def d_inner(self) -> int:       # ssm inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_encoder(self) -> bool:
        return not self.causal

    @property
    def resolved_lru_width(self) -> int:
        return self.lru_width or self.d_model

    # ------------------------------------------------------------------
    # analytic parameter / FLOP accounting (used by the roofline)
    # ------------------------------------------------------------------

    def mixer_params(self, kind: str) -> int:
        D, dh = self.d_model, self.resolved_head_dim
        H, K = self.num_heads, self.num_kv_heads
        if kind in ATTN_KINDS:
            qkv = D * (H * dh) + 2 * D * (K * dh)
            if self.qkv_bias:
                qkv += (H + 2 * K) * dh
            out = (H * dh) * D
            return qkv + out
        if kind == "ssd":
            di, N, G = self.d_inner, self.ssm_state, self.ssm_groups
            nh = self.ssm_heads
            in_proj = D * (2 * di + 2 * G * N + nh)
            conv = (di + 2 * G * N) * self.ssm_conv
            extra = 3 * nh          # A_log, D, dt_bias
            out = di * D + di       # out_proj + gated norm
            return in_proj + conv + extra + out
        if kind == "rglru":
            W = self.resolved_lru_width
            return 2 * D * W + W * self.ssm_conv + 3 * W + W * D
        raise ValueError(kind)

    def ffn_params(self, layer_idx: int) -> int:
        D = self.d_model
        if self.num_experts and layer_idx >= self.first_k_dense:
            dff = self.moe_dff or self.d_ff
            p = self.num_experts * 3 * D * dff + D * self.num_experts
            if self.shared_expert_dff:
                p += 3 * D * self.shared_expert_dff
            return p
        gate_mult = 3  # gated MLPs everywhere (SwiGLU/GeGLU)
        return gate_mult * D * self.d_ff

    def ffn_active_params(self, layer_idx: int) -> int:
        D = self.d_model
        if self.num_experts and layer_idx >= self.first_k_dense:
            dff = self.moe_dff or self.d_ff
            p = self.experts_per_token * 3 * D * dff + D * self.num_experts
            if self.shared_expert_dff:
                p += 3 * D * self.shared_expert_dff
            return p
        return 3 * D * self.d_ff

    def _layer_kinds(self):
        kinds = list(self.block_pattern) * self.n_superblocks
        kinds += list(self.remainder_pattern)
        return kinds

    def param_count(self) -> int:
        n = self.vocab_size * self.d_model          # embed
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model     # lm head
        for i, kind in enumerate(self._layer_kinds()):
            n += self.mixer_params(kind) + self.ffn_params(i)
            n += 2 * self.d_model                   # the two norms
        n += self.d_model                           # final norm
        return n

    def active_param_count(self) -> int:
        n = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model
        for i, kind in enumerate(self._layer_kinds()):
            n += self.mixer_params(kind) + self.ffn_active_params(i)
            n += 2 * self.d_model
        n += self.d_model
        return n

    def flops_parts(self, tokens: int, *, training: bool = True,
                    seq_len: int = 1, kv_len: int = 0) -> dict:
        """MODEL_FLOPS split into the 6·N·D projection term and the
        attention quadratic term (which 6ND famously omits).

        The embedding *gather* does no matmul work, so one V·D table is
        excluded from the FLOP-bearing parameter count (the unembed matmul
        keeps its V·D whether tied or not)."""
        mult = 6.0 if training else 2.0
        flop_params = self.active_param_count() - self.vocab_size * self.d_model
        base = mult * flop_params * tokens
        # attention score/PV FLOPs: fwd = 4·eff·H·dh per token (two matmuls);
        # training adds bwd (8) = 12 (remat recompute excluded: reported via
        # the useful-flops ratio instead)
        dh, H = self.resolved_head_dim, self.num_heads
        attn_unit = 12.0 if training else 4.0
        attn = 0.0
        for kind in self._layer_kinds():
            if kind not in ATTN_KINDS:
                continue
            if kv_len:      # decode: each token sees kv_len history
                eff = min(kv_len, self._attn_span(kind, kv_len))
                attn += attn_unit * tokens * eff * H * dh
            else:           # self-attention over seq_len, causal ≈ /2
                eff = min(seq_len, self._attn_span(kind, seq_len))
                frac = 0.5 if self.causal else 1.0
                attn += attn_unit * tokens * eff * frac * H * dh
        return {"base": base, "attn": attn}

    def model_flops(self, tokens: int, *, training: bool = True,
                    seq_len: int = 1, kv_len: int = 0) -> float:
        parts = self.flops_parts(tokens, training=training, seq_len=seq_len,
                                 kv_len=kv_len)
        return parts["base"] + parts["attn"]

    def _attn_span(self, kind: str, default: int) -> int:
        if kind in ("attn_sliding", "attn_local"):
            return self.window or default
        if kind == "attn_chunked":
            return self.chunk_size or default
        return default

    def supports_decode(self) -> bool:
        return self.causal

    def subquadratic(self) -> bool:
        """True if no layer attends to unbounded history (long_500k-able)."""
        return all(k not in ("attn", "attn_global", "attn_bidir")
                   for k in self._layer_kinds())

    def long_context_ok(self) -> bool:
        """long_500k policy: SSM/hybrid/windowed archs qualify; archs with a
        *few* global layers qualify via sequence-sharded decode attention."""
        kinds = self._layer_kinds()
        n_global = sum(k in ("attn", "attn_global") for k in kinds)
        return self.causal and (n_global == 0 or
                                (n_global <= len(kinds) // 4 and
                                 self.family in ("moe", "hybrid")))
