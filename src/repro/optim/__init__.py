from .adamw import AdamWConfig, adamw_update, global_norm, init_opt_state
from .schedule import constant, cosine_with_warmup

__all__ = ["AdamWConfig", "adamw_update", "constant", "cosine_with_warmup",
           "global_norm", "init_opt_state"]
