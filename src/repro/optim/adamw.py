"""AdamW with fp32 master weights, global-norm clipping, and optional
ZeRO-1 state sharding (the moments take their sharding from
``parallel.sharding.zero1_specs`` via lazy init under jit).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def init_opt_state(params) -> dict:
    z = lambda: jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return {"m": z(), "v": z(), "count": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(grads, opt, params, lr, cfg: AdamWConfig = AdamWConfig()):
    """Returns (new_params, new_opt, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    count = opt["count"] + 1
    c = count.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** c
    bc2 = 1.0 - cfg.b2 ** c

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        step = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (norms/bias exempt)
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt["m"])
    flat_v = tdef.flatten_up_to(opt["v"])
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}, {
        "grad_norm": gnorm, "clip_scale": scale}
