from .manager import (COMMIT_FILE, MANIFEST_FILE, SaveResult,
                      TransactionalCheckpointManager)

__all__ = ["COMMIT_FILE", "MANIFEST_FILE", "SaveResult",
           "TransactionalCheckpointManager"]
