"""Transactional checkpointing on the CannyFS engine — the paper's
technique as a first-class training feature.

Timeline of one save (the CannyFS mapping):

    train loop:  save(step, state)         <- returns after device→host copy
       engine:   [manifest + leaf writes eagerly ACKed, running in
                  background per-path queues while the next train steps run]
    finalizer:   drain() -> ledger clean? -> write COMMIT marker
                 (the transaction commit; a checkpoint without COMMIT is
                  invisible to restore and rolled back on startup)

Failure model = the paper's: any deferred I/O error means the whole
checkpoint transaction is discarded (rolled back) and retried at the next
save interval; the job itself restarts from the last *committed*
checkpoint.  Restore accepts a different mesh/device count
(reshard-on-restore → elastic scaling).
"""
from __future__ import annotations

import io
import json
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import numpy as np

from repro.core import CannyFS, is_under, norm_path
from repro.core.durability import commit_marker_ok
from repro.core.errors import CannyError

# ledger kinds that cannot be a checkpoint write failure — a failed or
# cancelled readdir-prefetch stat on the step dir must not condemn a save
_READ_KINDS = frozenset({"stat", "readdir", "read", "readlink"})

from .serialization import (flatten_for_save, manifest_bytes, parse_manifest,
                            unflatten_from)

COMMIT_FILE = "COMMIT"
MANIFEST_FILE = "manifest.json"

# leaf payloads stream through CannyFile in bounded chunks: consecutive
# chunks coalesce in the engine's optimizer into one vectored write_vec
# backend call, so large shards pay one remote roundtrip without the
# manager ever materializing more than the source array
_WRITE_CHUNK = 4 << 20


@dataclass
class SaveResult:
    step: int
    directory: str
    ok: bool = False
    error: Optional[str] = None
    gc_error: Optional[str] = None   # GC hiccup after a durable commit
    ack_s: float = 0.0        # time the train loop was blocked
    commit_s: float = 0.0     # background time to durable commit
    bytes: int = 0


class TransactionalCheckpointManager:
    def __init__(self, fs: CannyFS, directory: str = "ckpt", *,
                 keep: int = 3):
        self.fs = fs
        self.dir = norm_path(directory)
        self.keep = keep
        self._lock = threading.Lock()
        self._finalizer: Optional[threading.Thread] = None
        self._results: list[SaveResult] = []
        # steps whose COMMIT this manager validated or wrote itself —
        # lets _gc use the validated list without re-reading markers
        self._committed_cache: set[int] = set()
        with fs.detached():   # the ckpt root is not any transaction's output
            if not fs.exists(self.dir):
                fs.makedirs(self.dir)
        self.rollback_uncommitted()

    # ------------------------------------------------------------------

    def _step_dir(self, step: int) -> str:
        return f"{self.dir}/step_{step:010d}"

    def _under_dir(self, d: str):
        """Predicate over ledger entries: this manager's own (detached,
        untagged) write failures under ``d`` — a user transaction's entries
        under the step dir belong to its commit, and a failed or cancelled
        readdir-prefetch stat must not condemn a save."""
        def pred(e):
            return (e.region is None and e.kind not in _READ_KINDS
                    and any(is_under(p, d) for p in e.paths))
        return pred

    def _discard_step_dir(self, d: str, *, strict: bool = False) -> list:
        """The single step-dir rollback path (consolidates what used to be
        three copies: the ack-phase abort, the finalizer's error branch and
        startup recovery): un-poison the mount so cleanup I/O can run,
        remove the partial dir, then drop the manager's own deferred
        errors under it so a re-save of the same step starts from a clean
        ledger.  Returns the dropped cleanup entries (already echoed at
        record time) for error reporting.

        Save-path callers run best-effort (``strict=False``: a removal
        failure is absorbed — startup recovery is their backstop).
        Startup recovery itself runs ``strict=True``: it IS the backstop,
        so a dir it cannot remove must propagate, not be reported as
        rolled back with its errors cleared."""
        try:
            self.fs.engine.reset_poison()
            with self.fs.detached():
                if self.fs.exists(d):
                    self.fs.rmtree(d)
                    self.fs.drain()
        except (OSError, CannyError):
            if strict:
                raise
        return self.fs.ledger.clear_where(self._under_dir(d))

    def _is_committed(self, step: int) -> bool:
        """A COMMIT marker is only trusted if its content names the step —
        an empty/partial marker (write faulted after create) is not a
        commit.  Only a *missing* marker means uncommitted; any other read
        error propagates — treating a transient EIO as 'not committed'
        would let startup recovery delete a durable checkpoint."""
        if step in self._committed_cache:
            return True
        try:
            data = self.fs.read_file(f"{self._step_dir(step)}/{COMMIT_FILE}")
        except FileNotFoundError:
            return False
        # shared marker discipline with the durability spill's CUT file:
        # one validator, one notion of "content names the epoch/step"
        ok = commit_marker_ok(data, step)
        if ok:
            self._committed_cache.add(step)
        return ok

    def list_steps(self, *, committed_only: bool = True) -> list[int]:
        steps = []
        for name in self.fs.readdir(self.dir):
            if not name.startswith("step_"):
                continue
            try:
                step = int(name.split("_", 1)[1])
            except ValueError:
                continue
            if committed_only and not self._is_committed(step):
                continue
            steps.append(step)
        return sorted(steps)

    def rollback_uncommitted(self) -> list[int]:
        """Startup recovery: delete any checkpoint without a COMMIT marker
        (the paper's 'roll back the failed transaction')."""
        rolled = []
        committed = set(self.list_steps(committed_only=True))
        for step in self.list_steps(committed_only=False):
            if step not in committed:
                self._discard_step_dir(self._step_dir(step), strict=True)
                rolled.append(step)
        return rolled

    # ------------------------------------------------------------------

    def save(self, step: int, state: Any, *, block: bool = False) -> SaveResult:
        """Eagerly-ACKed checkpoint save.  Returns as soon as all writes are
        queued (device→host copy included); a background finalizer commits.
        """
        self.wait_for_save()          # one in-flight checkpoint at a time
        t0 = time.monotonic()
        d = self._step_dir(step)
        res = SaveResult(step=step, directory=d)
        manifest, leaves = flatten_for_save(state)
        under_d = self._under_dir(d)

        def abort_save(e: BaseException) -> SaveResult:
            """Ack-phase failure (e.g. poisoned engine rejecting a queued
            write): report via SaveResult — never raise into the train
            loop — and best-effort roll the partial step dir back."""
            res.ok = False
            res.error = repr(e)
            res.ack_s = time.monotonic() - t0   # loop was blocked this long
            self._discard_step_dir(d)
            res.commit_s = time.monotonic() - t0
            with self._lock:
                self._results.append(res)
            return res

        # detached: checkpoint files belong to the manager's own commit
        # protocol — they must not be journaled into (or their failures
        # blamed on) whatever user Transaction is open on this mount
        try:
            with self.fs.detached():
                self.fs.makedirs(d)
                total = 0
                self.fs.write_file(f"{d}/{MANIFEST_FILE}",
                                   manifest_bytes(manifest))
                for key, arr in leaves:
                    fname = key.replace("/", "__") + ".bin"
                    # chunked stream: the optimizer coalesces these into
                    # one vectored write_vec per shard file
                    blob = arr.tobytes()
                    with self.fs.open(f"{d}/{fname}", "wb") as f:
                        for lo in range(0, len(blob), _WRITE_CHUNK):
                            f.write(blob[lo:lo + _WRITE_CHUNK])
                    total += arr.nbytes
        except (OSError, CannyError) as e:
            return abort_save(e)
        res.bytes = total
        res.ack_s = time.monotonic() - t0

        def finalize():
            try:
                with self.fs.detached():
                    finalize_detached()
            except (OSError, CannyError) as e:
                # e.g. poisoned engine rejecting the COMMIT write, or a
                # sync-mode mount surfacing the fault directly — the
                # checkpoint is not durable, and the caller must hear it;
                # roll the partial dir back (a partial COMMIT marker would
                # otherwise make the step look durable)
                res.ok = False
                res.error = res.error or repr(e)
                self._discard_step_dir(d)
            finally:
                res.commit_s = time.monotonic() - t0
                with self._lock:
                    self._results.append(res)

        def finalize_detached():
            self.fs.drain()
            # path-scoped, not positional: a concurrent transaction
            # rollback can clear unrelated ledger entries, which would
            # shift a positional slice and hide this checkpoint's failures
            errs = [e for e in self.fs.ledger.entries() if under_d(e)]
            if not errs:
                self.fs.write_file(f"{d}/{COMMIT_FILE}", str(step).encode())
                self.fs.engine.barrier(f"{d}/{COMMIT_FILE}")
                # the COMMIT write itself can fail (eager => deferred);
                # re-scan or a lost marker gets reported as durable
                errs = [e for e in self.fs.ledger.entries() if under_d(e)]
            if errs:
                # handled (reported below + rolled back): clear exactly
                # the scanned entries by identity so a re-save of this
                # step works and other regions' entries are untouched
                handled = set(map(id, errs))
                self.fs.ledger.clear_where(lambda e: id(e) in handled)
                res.ok = False
                res.error = "; ".join(str(e) for e in errs[:4])
                # _discard_step_dir un-poisons *before* the rmtree (its
                # sync readdir would fail fast on a poisoned engine and
                # leak the partial step dir); the rollback's own deferred
                # errors under the step dir are cleared (stale entries
                # would fail every future save of this step) and reported
                # alongside the originals
                cleanup = self._discard_step_dir(d)
                if cleanup:
                    res.error += "; " + "; ".join(
                        str(e) for e in cleanup[:2])
            else:
                res.ok = True
                self._committed_cache.add(step)
                try:
                    self._gc()
                except (OSError, CannyError) as e:
                    # the checkpoint IS durable (COMMIT landed) — a GC
                    # hiccup must not flip ok; report it separately
                    res.gc_error = repr(e)

        if block:
            finalize()
        else:
            self._finalizer = threading.Thread(target=finalize, daemon=True,
                                               name=f"ckpt-commit-{step}")
            self._finalizer.start()
        return res

    def wait_for_save(self) -> None:
        t = self._finalizer
        if t is not None:
            t.join()
            self._finalizer = None

    def _gc(self) -> None:
        # validated list via the committed-step cache: zero marker reads
        # for steps committed (or once validated) by this process
        steps = self.list_steps()
        for step in steps[:-self.keep] if self.keep else []:
            self.fs.rmtree(self._step_dir(step))
            self._committed_cache.discard(step)

    @property
    def results(self) -> list[SaveResult]:
        with self._lock:
            return list(self._results)

    # ------------------------------------------------------------------

    def restore(self, like: Any, *, step: Optional[int] = None,
                shardings: Any = None) -> tuple[int, Any]:
        """Restore the latest (or given) committed checkpoint into the
        structure of ``like``.  ``shardings`` (a matching pytree of
        NamedSharding) reshards on restore — the saved artifact is
        mesh-agnostic, so restoring onto a different mesh/host count is the
        elastic-scaling path."""
        self.wait_for_save()
        steps = self.list_steps()
        if not steps:
            raise FileNotFoundError("no committed checkpoint found")
        step = steps[-1] if step is None else step
        d = self._step_dir(step)
        manifest = parse_manifest(self.fs.read_file(f"{d}/{MANIFEST_FILE}"))
        blobs: dict[str, bytes] = {}
        for key in manifest["leaves"]:
            fname = key.replace("/", "__") + ".bin"
            blobs[key] = self.fs.read_file(f"{d}/{fname}")
        tree = unflatten_from(manifest, blobs, like)
        if shardings is not None:
            tree = jax.tree.map(
                lambda arr, sh: jax.device_put(arr, sh), tree, shardings)
        return step, tree
