"""Transactional checkpointing on the CannyFS engine — the paper's
technique as a first-class training feature.

Timeline of one save (the CannyFS mapping):

    train loop:  save(step, state)         <- returns after device→host copy
       engine:   [manifest + leaf writes eagerly ACKed, running in
                  background per-path queues while the next train steps run]
    finalizer:   drain() -> ledger clean? -> write COMMIT marker
                 (the transaction commit; a checkpoint without COMMIT is
                  invisible to restore and rolled back on startup)

Failure model = the paper's: any deferred I/O error means the whole
checkpoint transaction is discarded (rolled back) and retried at the next
save interval; the job itself restarts from the last *committed*
checkpoint.  Restore accepts a different mesh/device count
(reshard-on-restore → elastic scaling).
"""
from __future__ import annotations

import io
import json
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import numpy as np

from repro.core import CannyFS, norm_path
from repro.core.errors import TransactionFailedError

from .serialization import (flatten_for_save, manifest_bytes, parse_manifest,
                            unflatten_from)

COMMIT_FILE = "COMMIT"
MANIFEST_FILE = "manifest.json"


@dataclass
class SaveResult:
    step: int
    directory: str
    ok: bool = False
    error: Optional[str] = None
    ack_s: float = 0.0        # time the train loop was blocked
    commit_s: float = 0.0     # background time to durable commit
    bytes: int = 0


class TransactionalCheckpointManager:
    def __init__(self, fs: CannyFS, directory: str = "ckpt", *,
                 keep: int = 3):
        self.fs = fs
        self.dir = norm_path(directory)
        self.keep = keep
        self._lock = threading.Lock()
        self._finalizer: Optional[threading.Thread] = None
        self._results: list[SaveResult] = []
        if not fs.exists(self.dir):
            fs.makedirs(self.dir)
        self.rollback_uncommitted()

    # ------------------------------------------------------------------

    def _step_dir(self, step: int) -> str:
        return f"{self.dir}/step_{step:010d}"

    def list_steps(self, *, committed_only: bool = True) -> list[int]:
        steps = []
        for name in self.fs.readdir(self.dir):
            if not name.startswith("step_"):
                continue
            try:
                step = int(name.split("_", 1)[1])
            except ValueError:
                continue
            if committed_only and not self.fs.exists(
                    f"{self.dir}/{name}/{COMMIT_FILE}"):
                continue
            steps.append(step)
        return sorted(steps)

    def rollback_uncommitted(self) -> list[int]:
        """Startup recovery: delete any checkpoint without a COMMIT marker
        (the paper's 'roll back the failed transaction')."""
        rolled = []
        committed = set(self.list_steps(committed_only=True))
        for step in self.list_steps(committed_only=False):
            if step not in committed:
                self.fs.rmtree(self._step_dir(step))
                rolled.append(step)
        if rolled:
            self.fs.drain()
        return rolled

    # ------------------------------------------------------------------

    def save(self, step: int, state: Any, *, block: bool = False) -> SaveResult:
        """Eagerly-ACKed checkpoint save.  Returns as soon as all writes are
        queued (device→host copy included); a background finalizer commits.
        """
        self.wait_for_save()          # one in-flight checkpoint at a time
        t0 = time.monotonic()
        d = self._step_dir(step)
        res = SaveResult(step=step, directory=d)
        manifest, leaves = flatten_for_save(state)

        self.fs.makedirs(d)
        total = 0
        self.fs.write_file(f"{d}/{MANIFEST_FILE}", manifest_bytes(manifest))
        ledger_start = len(self.fs.ledger)
        for key, arr in leaves:
            fname = key.replace("/", "__") + ".bin"
            self.fs.write_file(f"{d}/{fname}", arr.tobytes())
            total += arr.nbytes
        res.bytes = total
        res.ack_s = time.monotonic() - t0

        def finalize():
            self.fs.drain()
            errs = self.fs.ledger.entries()[ledger_start:]
            if errs:
                # transaction failed -> roll back this checkpoint
                try:
                    self.fs.rmtree(d)
                    self.fs.drain()
                except OSError:
                    pass
                res.ok = False
                res.error = "; ".join(str(e) for e in errs[:4])
            else:
                self.fs.write_file(f"{d}/{COMMIT_FILE}",
                                   str(step).encode())
                self.fs.engine.barrier(f"{d}/{COMMIT_FILE}")
                res.ok = True
                self._gc()
            res.commit_s = time.monotonic() - t0
            with self._lock:
                self._results.append(res)

        if block:
            finalize()
        else:
            self._finalizer = threading.Thread(target=finalize, daemon=True,
                                               name=f"ckpt-commit-{step}")
            self._finalizer.start()
        return res

    def wait_for_save(self) -> None:
        t = self._finalizer
        if t is not None:
            t.join()
            self._finalizer = None

    def _gc(self) -> None:
        steps = self.list_steps()
        for step in steps[:-self.keep] if self.keep else []:
            self.fs.rmtree(self._step_dir(step))

    @property
    def results(self) -> list[SaveResult]:
        with self._lock:
            return list(self._results)

    # ------------------------------------------------------------------

    def restore(self, like: Any, *, step: Optional[int] = None,
                shardings: Any = None) -> tuple[int, Any]:
        """Restore the latest (or given) committed checkpoint into the
        structure of ``like``.  ``shardings`` (a matching pytree of
        NamedSharding) reshards on restore — the saved artifact is
        mesh-agnostic, so restoring onto a different mesh/host count is the
        elastic-scaling path."""
        self.wait_for_save()
        steps = self.list_steps()
        if not steps:
            raise FileNotFoundError("no committed checkpoint found")
        step = steps[-1] if step is None else step
        d = self._step_dir(step)
        manifest = parse_manifest(self.fs.read_file(f"{d}/{MANIFEST_FILE}"))
        blobs: dict[str, bytes] = {}
        for key in manifest["leaves"]:
            fname = key.replace("/", "__") + ".bin"
            blobs[key] = self.fs.read_file(f"{d}/{fname}")
        tree = unflatten_from(manifest, blobs, like)
        if shardings is not None:
            tree = jax.tree.map(
                lambda arr, sh: jax.device_put(arr, sh), tree, shardings)
        return step, tree
