"""Pytree <-> bytes codec for checkpoints.

Leaves are stored raw (``tobytes``) with dtype/shape in a JSON manifest —
no pickle, bf16-safe via ml_dtypes, mmap-friendly.  Keys are '/'-joined
pytree paths so a manifest diff is human-readable.
"""
from __future__ import annotations

import json
from typing import Any

import jax
import numpy as np

try:  # bf16 and friends
    import ml_dtypes
    _EXTRA = {"bfloat16": ml_dtypes.bfloat16,
              "float8_e4m3fn": ml_dtypes.float8_e4m3fn,
              "float8_e5m2": ml_dtypes.float8_e5m2}
except ImportError:  # pragma: no cover
    _EXTRA = {}


def dtype_name(dt) -> str:
    return np.dtype(dt).name


def name_to_dtype(name: str):
    if name in _EXTRA:
        return np.dtype(_EXTRA[name])
    return np.dtype(name)


def leaf_path_str(kp) -> str:
    parts = []
    for e in kp:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "idx"):
            parts.append(str(e.idx))
        else:
            parts.append(str(e))
    return "/".join(parts)


def flatten_for_save(tree: Any) -> tuple[dict, list[tuple[str, np.ndarray]]]:
    """-> (manifest dict, [(key, host ndarray)]).  Device arrays are fetched
    to host here (the only blocking device interaction of a save)."""
    leaves_kp = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest = {"leaves": {}, "version": 1}
    out = []
    for kp, leaf in leaves_kp:
        key = leaf_path_str(kp)
        arr = np.asarray(leaf)
        manifest["leaves"][key] = {
            "dtype": dtype_name(arr.dtype),
            "shape": list(arr.shape),
            "nbytes": int(arr.nbytes),
        }
        out.append((key, arr))
    return manifest, out


def tree_def_of(tree: Any):
    return jax.tree_util.tree_structure(tree)


def unflatten_from(manifest: dict, blobs: dict[str, bytes], like: Any):
    """Rebuild a pytree with the structure of ``like`` from manifest +
    raw blobs."""
    leaves_kp, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for kp, ref_leaf in leaves_kp:
        key = leaf_path_str(kp)
        meta = manifest["leaves"][key]
        arr = np.frombuffer(blobs[key], dtype=name_to_dtype(meta["dtype"]))
        arr = arr.reshape(meta["shape"])
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def manifest_bytes(manifest: dict) -> bytes:
    return json.dumps(manifest, indent=1).encode()


def parse_manifest(raw: bytes) -> dict:
    return json.loads(raw.decode())
