"""Roofline-term extraction from compiled dry-run artifacts.

    compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory term     = HLO_bytes / (chips × HBM_bw)
    collective term = collective_bytes / (chips × link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (per-device on
a partitioned module → × chips for the global figure).  collective_bytes is
parsed from the compiled HLO text: the operand/result bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

Hardware constants (per the brief; v5e-class chip):
    197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Sum bytes over all tensor types in an HLO type string (handles
    tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-collective-kind result bytes, per device (the module is the
    per-device program).  async start/done pairs are counted once (start)."""
    out: dict[str, float] = {k: 0.0 for k in COLLECTIVE_OPS}
    counts: dict[str, int] = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        s = line.strip()
        if " = " not in s:
            continue
        lhs, rhs = s.split(" = ", 1)
        for op in COLLECTIVE_OPS:
            # match `<type> op-name(` and async starts; skip `-done`
            if re.match(rf"^[^\s]+\s+{op}(-start)?\(", rhs):
                out[op] += _shape_bytes(rhs.split("(", 1)[0])
                counts[op] += 1
                break
    out_counts = {f"n_{k}": v for k, v in counts.items() if v}
    return {**{k: v for k, v in out.items() if v}, **out_counts}


@dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_breakdown: dict = field(default_factory=dict)
    model_flops: float = 0.0
    peak_memory_per_device: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_per_device / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        hlo_global = self.flops_per_device * self.chips
        return self.model_flops / hlo_global if hlo_global else 0.0

    @property
    def step_time_s(self) -> float:
        """Roofline step-time estimate: max of the three terms (perfect
        overlap assumption; the no-overlap sum is also reported)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the pure-compute roofline achieved if the step ran at
        the max-term estimate AND all compiled FLOPs were useful model
        FLOPs: (MODEL_FLOPS / chips / peak) / step_time."""
        ideal = self.model_flops / self.chips / PEAK_FLOPS
        return ideal / self.step_time_s if self.step_time_s else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "coll_breakdown": self.coll_breakdown,
            "model_flops": self.model_flops,
            "peak_memory_per_device": self.peak_memory_per_device,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "step_time_s": self.step_time_s,
            "roofline_fraction": self.roofline_fraction,
        }
