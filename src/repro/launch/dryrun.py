import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# ^ MUST precede any jax import: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (architecture × input-shape)
cell on the production meshes, prove the distribution config is coherent,
and extract roofline terms from the compiled artifacts.

Usage:
    python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
    python -m repro.launch.dryrun --all --out benchmarks/results/dryrun

Per cell this runs
    jax.jit(step, in_shardings=..., out_shardings=...)
       .lower(**input_specs).compile()
prints memory_analysis() (fits-on-device proof) and cost_analysis()
(FLOPs/bytes for the roofline), parses collective bytes from the compiled
HLO, and writes a JSON record consumed by EXPERIMENTS.md §Roofline.
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import RooflineTerms, collective_bytes
from repro.launch.specs import (SHAPES, batch_specs, cache_specs,
                                cell_supported, decode_token_specs)
from repro.models import param_specs
from repro.optim import init_opt_state
from repro.parallel.sharding import batch_pspecs, make_shardings
from repro.train.steps import (TrainConfig, make_decode_step,
                               make_encode_step, make_prefill_step,
                               make_train_step, serve_shardings,
                               train_shardings)


def _mem_stats(compiled) -> dict:
    out = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("temp_size_in_bytes", "argument_size_in_bytes",
                  "output_size_in_bytes", "generated_code_size_in_bytes",
                  "peak_memory_in_bytes", "alias_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                out[k] = int(v)
    except Exception as e:  # pragma: no cover - backend-specific
        out["error"] = repr(e)
    return out


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             train_cfg: TrainConfig | None = None,
             scan_layers: bool = False,
             cfg_overrides: dict | None = None,
             verbose: bool = True) -> dict:
    """One (arch × shape × mesh) cell.

    scan_layers=False (default) lowers with the layer loop unrolled so
    cost_analysis counts every layer (XLA counts a while body once);
    the scanned variant is the production path and compiles too.
    cfg_overrides: dataclasses.replace overrides on the ModelConfig
    (hillclimb knobs such as ssm_chunk)."""
    import dataclasses
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    cell = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    ok, reason = cell_supported(cfg, shape_name)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "status": "skipped", "reason": reason}
    if not ok:
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    tc = train_cfg or TrainConfig()
    tc = TrainConfig(**{**tc.__dict__, "scan_layers": scan_layers})
    t0 = time.monotonic()

    pshape = param_specs(cfg)
    n_params = sum(int(jnp.prod(jnp.array(l.shape)))
                   for l in jax.tree.leaves(pshape))

    if cell.step == "train":
        bspec = batch_specs(cfg, cell.seq_len, cell.global_batch,
                            training=True)
        sh = train_shardings(cfg, mesh, pshape, bspec, zero1=tc.zero1)
        opt_shape = jax.eval_shape(init_opt_state, pshape)
        step = make_train_step(cfg, mesh, tc)
        jitted = jax.jit(
            step,
            in_shardings=(sh["params"], sh["opt"], sh["batch"], None),
            out_shardings=(sh["params"], sh["opt"], None),
            donate_argnums=(0, 1))
        with mesh:
            lowered = jitted.lower(pshape, opt_shape, bspec,
                                   jax.ShapeDtypeStruct((), jnp.float32))
            t_lower = time.monotonic() - t0
            compiled = lowered.compile()
        tokens = cell.global_batch * cell.seq_len
        model_flops = cfg.model_flops(tokens, training=True,
                                      seq_len=cell.seq_len)
    elif cell.step == "prefill" and not cfg.supports_decode():
        # encoder-only: prefill_32k is a pure encode forward (no cache)
        bspec = batch_specs(cfg, cell.seq_len, cell.global_batch,
                            training=False)
        sh = train_shardings(cfg, mesh, pshape, bspec, zero1=False)
        step = make_encode_step(cfg, mesh, scan_layers=scan_layers)
        jitted = jax.jit(step, in_shardings=(sh["params"], sh["batch"]),
                         out_shardings=None)
        with mesh:
            lowered = jitted.lower(pshape, bspec)
            t_lower = time.monotonic() - t0
            compiled = lowered.compile()
        tokens = cell.global_batch * cell.seq_len
        model_flops = cfg.model_flops(tokens, training=False,
                                      seq_len=cell.seq_len)
    else:
        bspec = batch_specs(cfg, cell.seq_len, cell.global_batch,
                            training=False)
        cshape = cache_specs(cfg, cell.global_batch, cell.seq_len)
        sh = serve_shardings(cfg, mesh, pshape, cshape, cell.global_batch,
                             cell.seq_len)
        bsh = make_shardings(mesh, batch_pspecs(cfg, bspec, mesh))
        if cell.step == "prefill":
            step = make_prefill_step(cfg, mesh, batch=cell.global_batch,
                                     max_len=cell.seq_len,
                                     scan_layers=scan_layers)
            jitted = jax.jit(step,
                             in_shardings=(sh["params"], bsh, sh["cache"]),
                             out_shardings=(None, sh["cache"]),
                             donate_argnums=(2,))
            with mesh:
                lowered = jitted.lower(pshape, bspec, cshape)
                t_lower = time.monotonic() - t0
                compiled = lowered.compile()
            tokens = cell.global_batch * cell.seq_len
            model_flops = cfg.model_flops(tokens, training=False,
                                          seq_len=cell.seq_len)
        else:
            step = make_decode_step(cfg, mesh, batch=cell.global_batch,
                                    max_len=cell.seq_len,
                                    scan_layers=scan_layers)
            tok = decode_token_specs(cell.global_batch)
            jitted = jax.jit(step,
                             in_shardings=(sh["params"], None, sh["cache"]),
                             out_shardings=(None, None, sh["cache"]),
                             donate_argnums=(2,))
            with mesh:
                lowered = jitted.lower(pshape, tok, cshape)
                t_lower = time.monotonic() - t0
                compiled = lowered.compile()
            tokens = cell.global_batch
            model_flops = cfg.model_flops(tokens, training=False,
                                          kv_len=cell.seq_len)

    t_compile = time.monotonic() - t0 - t_lower
    ca = compiled.cost_analysis() or {}
    flops = float(ca.get("flops", 0.0))
    bytes_acc = float(ca.get("bytes accessed", 0.0))
    flops_raw = flops
    # The query-blocked attention path (self-attn, S >= 2048) runs nq chunks
    # inside one lax.map whose body XLA counts once — add the analytic
    # remainder (methodology: EXPERIMENTS.md §Roofline).  This mirrors the
    # TPU target, where the Pallas flash kernel's FLOPs are likewise
    # invisible to cost_analysis and accounted analytically.
    attn_corr = 0.0
    if cell.step != "decode" and cell.seq_len >= 2048:
        nq = cell.seq_len // 1024
        attn_flops = cfg.flops_parts(
            cell.global_batch * cell.seq_len,
            training=(cell.step == "train"), seq_len=cell.seq_len)["attn"]
        attn_corr = attn_flops * (nq - 1) / nq / chips
        flops += attn_corr
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    coll_total = sum(v for k, v in coll.items() if not k.startswith("n_"))
    mem = _mem_stats(compiled)

    terms = RooflineTerms(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        flops_per_device=flops, bytes_per_device=bytes_acc,
        coll_bytes_per_device=coll_total, coll_breakdown=coll,
        model_flops=model_flops,
        peak_memory_per_device=float(mem.get("temp_size_in_bytes", 0)
                                     + mem.get("argument_size_in_bytes", 0)))
    rec.update(terms.to_dict())
    rec.update({
        "status": "ok", "n_params": int(n_params),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": mem,
        "hlo_size": len(hlo),
        "scan_layers": scan_layers,
        "flops_per_device_raw": flops_raw,
        "attn_flops_correction_per_device": attn_corr,
    })
    if verbose:
        print(f"[dryrun] {arch} × {shape_name} × {mesh_name}: "
              f"compile ok in {t_lower + t_compile:.1f}s; "
              f"bottleneck={terms.bottleneck} "
              f"compute={terms.compute_s * 1e3:.2f}ms "
              f"memory={terms.memory_s * 1e3:.2f}ms "
              f"collective={terms.collective_s * 1e3:.2f}ms "
              f"useful_flops={terms.useful_flops_ratio:.2f}")
        print(f"[dryrun]   memory_analysis: {mem}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--remat", default="dots_no_batch")
    ap.add_argument("--act-mode", default="dp")
    ap.add_argument("--scan-layers", action="store_true",
                    help="lower the production scan-over-layers variant "
                         "(compact HLO) instead of the unrolled analysis "
                         "variant")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = []
    if not args.multi_pod_only:
        meshes.append(False)
    if not args.single_pod_only:
        meshes.append(True)
    if not (args.all or args.arch):
        ap.error("pass --all or --arch")

    tc = TrainConfig(remat_policy=args.remat, activation_mode=args.act_mode)
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                path = outdir / f"{arch}__{shape}__{mesh_name}.json"
                if path.exists() and not args.force:
                    print(f"[dryrun] cached: {path}")
                    continue
                try:
                    rec = run_cell(arch, shape, multi_pod=mp, train_cfg=tc,
                                   scan_layers=args.scan_layers)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "failed", "error": repr(e),
                           "traceback": traceback.format_exc()}
                    failures.append((arch, shape, mesh_name, repr(e)))
                    print(f"[dryrun] FAILED {arch} × {shape} × {mesh_name}: "
                          f"{e!r}")
                path.write_text(json.dumps(rec, indent=2))
    if failures:
        print(f"\n[dryrun] {len(failures)} FAILURES:")
        for f in failures:
            print("  ", *f)
        raise SystemExit(1)
    print("\n[dryrun] all requested cells compiled OK")


if __name__ == "__main__":
    main()
