"""Production meshes.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required for the smoke tests, which must
see the real single CPU device, while the dry-run forces 512 host devices
before first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2 pods x 256 = 512 chips (pod, data, model); 'pod' carries
    only gradient reduction (or pipeline stages) over the slow links."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int | None = None, *, multi_pod: bool = False):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = n_devices or len(jax.devices())
    if multi_pod and n >= 8:
        return jax.make_mesh((2, 2, n // 4), ("pod", "data", "model"))
    if n == 1:
        return jax.make_mesh((1, 1), ("data", "model"))
    d = 2 if n % 2 == 0 else 1
    return jax.make_mesh((d, n // d), ("data", "model"))
