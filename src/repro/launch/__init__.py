"""repro.launch — meshes, input specs, dry-run, and the training launcher.

NOTE: importing this package must NOT touch jax device state; dryrun.py
sets XLA_FLAGS before any jax import and is run as __main__ only.
"""
