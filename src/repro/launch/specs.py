"""Input ShapeDtypeStruct stand-ins for every (arch × input-shape) cell.

No device allocation — the dry-run lowers against these.  Shapes per the
assignment:

    train_4k     seq 4,096   global_batch 256   (train_step)
    prefill_32k  seq 32,768  global_batch 32    (prefill / encode)
    decode_32k   kv 32,768   global_batch 128   (serve_step, 1 new token)
    long_500k    kv 524,288  global_batch 1     (serve_step, 1 new token)

Skips (DESIGN.md §4): encoder-only archs have no decode; pure
full-attention archs skip long_500k.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models import ModelConfig, init_cache

S = jax.ShapeDtypeStruct


@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    step: str          # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def cell_supported(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    cell = SHAPES[shape_name]
    if cell.step == "decode":
        if not cfg.supports_decode():
            return False, "encoder-only: no decode step"
        if cell.name == "long_500k" and not cfg.long_context_ok():
            return False, "full attention: long_500k needs sub-quadratic attn"
    return True, ""


def batch_specs(cfg: ModelConfig, seq_len: int, batch: int,
                *, training: bool) -> dict:
    """ShapeDtypeStructs for one train/prefill batch."""
    out = {"tokens": S((batch, seq_len), jnp.int32)}
    if training:
        out["labels"] = S((batch, seq_len), jnp.int32)
    if cfg.modality == "audio_stub":
        out["features"] = S((batch, seq_len, 512), jnp.bfloat16)
        if training:
            out["loss_mask"] = S((batch, seq_len), jnp.bool_)
    if cfg.modality == "vision_stub":
        n_img = min(cfg.frontend_tokens or 1024, seq_len // 2)
        out["vision_embeds"] = S((batch, n_img, cfg.d_model), jnp.bfloat16)
        out["vision_mask"] = S((batch, seq_len), jnp.bool_)
        out["positions3"] = S((3, batch, seq_len), jnp.int32)
    return out


def cache_specs(cfg: ModelConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: init_cache(cfg, batch, max_len, dtype))


def decode_token_specs(batch: int):
    return S((batch, 1), jnp.int32)


def cell_tokens(shape_name: str) -> int:
    cell = SHAPES[shape_name]
    if cell.step == "decode":
        return cell.global_batch          # one new token per sequence
    return cell.global_batch * cell.seq_len
