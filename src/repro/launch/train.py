"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-3b \
        --smoke --steps 50 --workdir /tmp/run1

On a real TPU fleet this binary runs once per host (jax.distributed
handles process groups); in-container it drives the debug mesh.  All host
I/O (checkpoints, metrics) flows through the CannyFS transactional engine;
``--restarts`` wraps the job in the rollback-and-resubmit loop.
"""
import argparse
import tempfile

import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.core import CannyFS, LatencyBackend, LatencyModel, LocalBackend
from repro.data import Prefetcher, SyntheticLM
from repro.launch.mesh import make_debug_mesh, make_production_mesh
from repro.train.loop import LoopConfig, Trainer, run_with_restarts
from repro.train.steps import TrainConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--workdir", default=None)
    ap.add_argument("--mesh", choices=("debug", "pod", "multipod"),
                    default="debug")
    ap.add_argument("--remat", default="none")
    ap.add_argument("--act-mode", default="dp", choices=("dp", "dp_sp"))
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--restarts", type=int, default=1)
    ap.add_argument("--io-latency-ms", type=float, default=0.0)
    ap.add_argument("--dtype", default="float32",
                    choices=("float32", "bfloat16"))
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = {"debug": lambda: make_debug_mesh(),
            "pod": lambda: make_production_mesh(multi_pod=False),
            "multipod": lambda: make_production_mesh(multi_pod=True),
            }[args.mesh]()

    workdir = args.workdir or tempfile.mkdtemp(prefix="repro_run_")
    backend = LocalBackend(workdir)
    if args.io_latency_ms:
        backend = LatencyBackend(backend, LatencyModel(
            meta_ms=args.io_latency_ms, data_ms=args.io_latency_ms))
    fs = CannyFS(backend, max_inflight=4000, workers=32)
    print(f"[launch] arch={cfg.name} params≈{cfg.param_count()/1e6:.1f}M "
          f"mesh={dict(mesh.shape)} workdir={workdir}")

    tc = TrainConfig(dtype=getattr(jnp, args.dtype),
                     remat_policy=args.remat,
                     activation_mode=args.act_mode, peak_lr=args.lr)
    lc = LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                    log_every=10, warmup=min(20, args.steps // 5 + 1))

    def factory():
        data = Prefetcher(iter(SyntheticLM(cfg, batch=args.batch,
                                           seq_len=args.seq, seed=0)),
                          depth=2)
        return Trainer(cfg, mesh, fs, data, tc=tc, lc=lc)

    metrics = run_with_restarts(factory, max_restarts=args.restarts)
    print("[launch] done:", {k: round(float(v), 4)
                             for k, v in metrics.items()})
    fs.close()


if __name__ == "__main__":
    main()
