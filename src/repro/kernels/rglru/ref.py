"""Pure-jnp oracles for the RG-LRU (Real-Gated Linear Recurrent Unit) scan.

Griffin / RecurrentGemma (arXiv:2402.19427):

    r_t = sigmoid(gate_a(x_t))               recurrence gate
    i_t = sigmoid(gate_x(x_t))               input gate
    log a_t = -c * softplus(Λ) * r_t         (c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

The gates are block-diagonal linear maps (num_heads blocks) computed by the
caller; this module implements the recurrence itself given per-step
log-decay ``log_a`` and gated input ``gx``:

    h_t = exp(log_a_t) ⊙ h_{t-1} + sqrt(1 - exp(2 log_a_t)) ⊙ gx_t

Two references: exact sequential scan (oracle) and a block-parallel
formulation (what the Pallas kernel implements): within a block of T steps,
    h_{t} = exp(cum_t - cum_j) terms — computed via an associative scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_sequential(log_a: jax.Array, gx: jax.Array,
                     h0: jax.Array | None = None):
    """log_a, gx: (B, S, W) -> (y (B,S,W), h_S (B,W)). fp32 internals."""
    B, S, W = gx.shape
    f32 = jnp.float32
    la = log_a.astype(f32)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * la), 1e-12))
    u = beta * gx.astype(f32)
    h = jnp.zeros((B, W), f32) if h0 is None else h0.astype(f32)

    def step(h, inp):
        la_t, u_t = inp
        h = jnp.exp(la_t) * h + u_t
        return h, h

    h_last, ys = jax.lax.scan(step, h, (jnp.moveaxis(la, 1, 0),
                                        jnp.moveaxis(u, 1, 0)))
    return jnp.moveaxis(ys, 0, 1).astype(gx.dtype), h_last


def rglru_assoc(log_a: jax.Array, gx: jax.Array,
                h0: jax.Array | None = None):
    """Same math via jax.lax.associative_scan (log-depth; used on the CPU
    path for long sequences)."""
    f32 = jnp.float32
    la = log_a.astype(f32)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * la), 1e-12))
    u = beta * gx.astype(f32)
    if h0 is not None:
        # fold h0 in as a virtual step 0 with a=0 contribution
        la = jnp.concatenate([jnp.zeros_like(la[:, :1]), la], axis=1)
        u = jnp.concatenate([h0.astype(f32)[:, None], u], axis=1)

    def combine(c1, c2):
        a1, u1 = c1
        a2, u2 = c2
        return a1 * a2, u1 * a2 + u2

    a_acc, y = jax.lax.associative_scan(
        combine, (jnp.exp(la), u), axis=1)
    if h0 is not None:
        y = y[:, 1:]
    return y.astype(gx.dtype), y[:, -1].astype(f32)


def rglru_gates(x: jax.Array, p: dict, *, c: float = 8.0):
    """Compute (log_a, gx) from inputs and block-diagonal gate params.

    x (B,S,W); p = {a_gate_w (Hb, bw, bw), a_gate_b (Hb, bw),
                    x_gate_w, x_gate_b, a_param (W,)} with W = Hb*bw."""
    B, S, W = x.shape
    Hb, bw, _ = p["a_gate_w"].shape
    xb = x.reshape(B, S, Hb, bw)
    f32 = jnp.float32
    ra = jax.nn.sigmoid(jnp.einsum("bshi,hij->bshj", xb.astype(f32),
                                   p["a_gate_w"].astype(f32))
                        + p["a_gate_b"].astype(f32))
    ix = jax.nn.sigmoid(jnp.einsum("bshi,hij->bshj", xb.astype(f32),
                                   p["x_gate_w"].astype(f32))
                        + p["x_gate_b"].astype(f32))
    log_a_base = -c * jax.nn.softplus(p["a_param"].astype(f32))  # (W,)
    log_a = ra.reshape(B, S, W) * log_a_base
    gx = ix.reshape(B, S, W) * x.astype(f32)
    return log_a, gx
