"""Pallas TPU kernel for the RG-LRU linear-recurrence scan.

TPU adaptation (vs Griffin's custom GPU linear-scan kernel): the recurrence
is strictly sequential in time, so the win is purely memory-locality — keep
the (lane-block of the) hidden state resident in VMEM across the whole
sequence instead of round-tripping HBM per step.  Grid is
(batch, width-blocks, time-blocks) with time last (sequential); each step
consumes a (T_blk × 128) tile and runs a fori loop over its rows, state in
fp32 scratch.  Width is vectorized across the 128-lane dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128


def _rglru_kernel(la_ref, gx_ref, h0_ref, y_ref, h_scr, *, t_blk):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)[None, :]

    la = la_ref[0].astype(jnp.float32)        # (T, 128) log decay
    gx = gx_ref[0].astype(jnp.float32)        # (T, 128) gated input
    a = jnp.exp(la)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * la), 1e-12))
    u = beta * gx

    def step(t, carry):
        h, ys = carry
        h = a[t] * h + u[t]
        ys = jax.lax.dynamic_update_slice_in_dim(ys, h[None], t, axis=0)
        return h, ys

    h0 = h_scr[0]
    h, ys = jax.lax.fori_loop(
        0, t_blk, step, (h0, jnp.zeros((t_blk, LANES), jnp.float32)))
    h_scr[...] = h[None]
    y_ref[0] = ys.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("t_blk", "interpret"))
def rglru_pallas(log_a, gx, h0=None, *, t_blk: int = 128, interpret=False):
    """log_a, gx (B,S,W) -> (y (B,S,W), h_last (B,W)).  W, S 128-aligned."""
    B, S, W = gx.shape
    assert S % t_blk == 0 and W % LANES == 0, (S, W)
    if h0 is None:
        h0 = jnp.zeros((B, W), jnp.float32)
    n_w = W // LANES
    n_t = S // t_blk

    kernel = functools.partial(_rglru_kernel, t_blk=t_blk)
    y = pl.pallas_call(
        kernel,
        grid=(B, n_w, n_t),
        in_specs=[
            pl.BlockSpec((1, t_blk, LANES), lambda b, w, t: (b, t, w)),
            pl.BlockSpec((1, t_blk, LANES), lambda b, w, t: (b, t, w)),
            pl.BlockSpec((1, LANES), lambda b, w, t: (b, w)),
        ],
        out_specs=pl.BlockSpec((1, t_blk, LANES), lambda b, w, t: (b, t, w)),
        out_shape=jax.ShapeDtypeStruct((B, S, W), gx.dtype),
        scratch_shapes=[pltpu.VMEM((1, LANES), jnp.float32)],
        interpret=interpret,
    )(log_a, gx, h0)
    return y, y[:, -1].astype(jnp.float32)
