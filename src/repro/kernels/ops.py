"""Dispatch wrappers: the models call these; we pick Pallas-on-TPU,
Pallas-interpret (kernel tests), or the jnp reference (CPU / dry-run).

Env override: REPRO_KERNELS = auto | jnp | pallas | interpret
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp


def kernel_mode() -> str:
    mode = os.environ.get("REPRO_KERNELS", "auto")
    if mode == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    return mode


def _interpret() -> bool:
    return kernel_mode() == "interpret"


def _use_pallas() -> bool:
    return kernel_mode() in ("pallas", "interpret")


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, *, eps: float = 1e-6, residual=None):
    from .rmsnorm.ref import rmsnorm_ref
    if _use_pallas() and x.ndim >= 2 and x.shape[-1] % 128 == 0:
        from .rmsnorm.kernel import rmsnorm_pallas
        return rmsnorm_pallas(x, scale, eps=eps, residual=residual,
                              interpret=_interpret())
    return rmsnorm_ref(x, scale, eps=eps, residual=residual)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def flash_attention(q, k, v, *, causal=True, window=0, chunk=0,
                    q_positions=None, k_positions=None, softcap=0.0,
                    scale=None):
    """q (B,Sq,H,dh), k/v (B,Sk,K,dh) -> (B,Sq,H,dh).

    The Pallas path requires static self-attention layout (Sq == Sk,
    positions defaulted, 128-aligned seq) — exactly the training/prefill
    shapes; everything else (decode, ragged cache) falls back to the ref.
    """
    from .flash_attention.ref import mha_blocked, mha_ref
    B, Sq, H, dh = q.shape
    Sk = k.shape[1]
    pallas_ok = (_use_pallas() and q_positions is None and k_positions is None
                 and Sq == Sk and Sq % 256 == 0 and dh % 128 == 0
                 and softcap == 0.0)
    if pallas_ok:
        from .flash_attention.kernel import flash_attention_pallas
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      chunk=chunk, scale=scale,
                                      interpret=_interpret())
    # self-attention on the jnp path: query-blocked exact attention so the
    # lowered HLO never holds an O(S²) buffer (the flash-like production
    # schedule — the dry-run's memory analysis reflects this)
    if (q_positions is None and k_positions is None and Sq == Sk
            and Sq >= 2048 and Sq % 1024 == 0):
        # hillclimbed variant: slice K/V to the mask's reach per q-block
        if ((window or chunk) and
                os.environ.get("REPRO_WINDOWED_ATTN") == "1"):
            from .flash_attention.ref import mha_blocked_windowed
            return mha_blocked_windowed(q, k, v, causal=causal,
                                        window=window, chunk=chunk,
                                        softcap=softcap, scale=scale)
        return mha_blocked(q, k, v, causal=causal, window=window, chunk=chunk,
                           softcap=softcap, scale=scale)
    return mha_ref(q, k, v, causal=causal, window=window, chunk=chunk,
                   q_positions=q_positions, k_positions=k_positions,
                   softcap=softcap, scale=scale)


# ---------------------------------------------------------------------------
# Mamba-2 SSD scan
# ---------------------------------------------------------------------------

def ssd_scan(x, dt, A, Bm, Cm, D, *, chunk: int = 128):
    from .ssd.ref import ssd_chunked
    S = x.shape[1]
    if _use_pallas() and S % chunk == 0 and x.shape[-1] % 8 == 0:
        from .ssd.kernel import ssd_pallas
        return ssd_pallas(x, dt, A, Bm, Cm, D, chunk=chunk,
                          interpret=_interpret())
    if S % chunk == 0:
        return ssd_chunked(x, dt, A, Bm, Cm, D, chunk=chunk)
    from .ssd.ref import ssd_sequential
    return ssd_sequential(x, dt, A, Bm, Cm, D)


# ---------------------------------------------------------------------------
# RG-LRU scan
# ---------------------------------------------------------------------------

def rglru_scan(log_a, gx, h0=None):
    """log_a, gx (B,S,W) -> (y, h_last)."""
    from .rglru.ref import rglru_assoc, rglru_sequential
    B, S, W = gx.shape
    if _use_pallas() and S % 128 == 0 and W % 128 == 0:
        from .rglru.kernel import rglru_pallas
        return rglru_pallas(log_a, gx, h0=h0, interpret=_interpret())
    if S >= 64:
        return rglru_assoc(log_a, gx, h0=h0)
    return rglru_sequential(log_a, gx, h0=h0)
