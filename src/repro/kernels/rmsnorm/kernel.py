"""Pallas TPU fused RMSNorm (+ optional residual add).

Bandwidth-bound epilogue: one HBM read of x (+residual), one write of y,
fp32 statistics in-register.  Rows are tiled (block_rows × D) so the full
feature dimension sits in VMEM per tile (D ≤ 8192 fp32 = 32 KiB/row).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * s_ref[...].astype(jnp.float32)[None, :]
    o_ref[...] = y.astype(o_ref.dtype)


def _rmsnorm_res_kernel(x_ref, s_ref, r_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * s_ref[...].astype(jnp.float32)[None, :]
    y = y + r_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm_pallas(x, scale, *, eps: float = 1e-6, residual=None,
                   block_rows: int = 256, interpret=False):
    shape = x.shape
    D = shape[-1]
    xr = x.reshape(-1, D)
    R = xr.shape[0]
    rb = min(block_rows, R)
    pad = (-R) % rb
    if pad:
        xr = jnp.pad(xr, ((0, pad), (0, 0)))
    rows = xr.shape[0]

    if residual is None:
        out = pl.pallas_call(
            functools.partial(_rmsnorm_kernel, eps=eps),
            grid=(rows // rb,),
            in_specs=[pl.BlockSpec((rb, D), lambda i: (i, 0)),
                      pl.BlockSpec((D,), lambda i: (0,))],
            out_specs=pl.BlockSpec((rb, D), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((rows, D), x.dtype),
            interpret=interpret,
        )(xr, scale)
    else:
        rr = residual.reshape(-1, D)
        if pad:
            rr = jnp.pad(rr, ((0, pad), (0, 0)))
        out = pl.pallas_call(
            functools.partial(_rmsnorm_res_kernel, eps=eps),
            grid=(rows // rb,),
            in_specs=[pl.BlockSpec((rb, D), lambda i: (i, 0)),
                      pl.BlockSpec((D,), lambda i: (0,)),
                      pl.BlockSpec((rb, D), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((rb, D), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((rows, D), x.dtype),
            interpret=interpret,
        )(xr, scale, rr)
    if pad:
        out = out[:R]
    return out.reshape(shape)
