"""Pure-jnp oracle for fused RMSNorm."""
from __future__ import annotations

import jax.numpy as jnp


def rmsnorm_ref(x, scale, *, eps: float = 1e-6, residual=None):
    """y = x / rms(x) * scale (+1 Gemma-style offset is NOT used here);
    optional fused residual add (y += residual) for the epilogue case."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * (var + eps) ** -0.5
    y = y * scale.astype(jnp.float32)
    if residual is not None:
        y = y + residual.astype(jnp.float32)
    return y.astype(x.dtype)
