"""Pallas TPU flash attention (forward) with GQA + causal/sliding/chunked
masking.

TPU adaptation notes (vs the CUDA FlashAttention algorithm):

* blocking is over (q-block, kv-block) with the kv dimension as the *last,
  sequential* grid axis — running max/denominator/accumulator live in VMEM
  scratch and persist across kv steps (the Pallas-TPU "revisiting output"
  pattern), instead of CUDA's per-SM shared-memory tiles;
* block shapes are 128-aligned so the MXU sees full tiles; softmax
  statistics are fp32 in scratch regardless of io dtype;
* fully-masked kv blocks are skipped via ``pl.when`` on block-index
  arithmetic (causal upper triangle, out-of-window, out-of-chunk) — this is
  the structural analogue of FlashAttention's early-exit;
* GQA shares each kv-head block across its q-head group through the k/v
  index maps (no KV replication in VMEM).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
LANES = 128


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale, causal, window, chunk, bq, bk, n_kv):
    iq = pl.program_id(2)
    jk = pl.program_id(3)

    @pl.when(jk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q0 = iq * bq            # first q position of this block
    k0 = jk * bk            # first kv position of this block

    # --- block-level skip: is any (i, j) pair in this tile visible?
    live = jnp.bool_(True)
    if causal:
        live &= (q0 + bq - 1) >= k0                  # not above diagonal
    if window:
        live &= q0 < (k0 + bk + window)              # not fully aged out
    if chunk:
        live &= (q0 // chunk) <= ((k0 + bk - 1) // chunk)
        live &= ((q0 + bq - 1) // chunk) >= (k0 // chunk)

    @pl.when(live)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, dh)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, dh)
        v = v_ref[0, 0].astype(jnp.float32)          # (bk, dh)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)

        qi = q0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kj = k0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= qi >= kj
        if window:
            mask &= (qi - kj) < window
        if chunk:
            mask &= (qi // chunk) == (kj // chunk)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, :1]                        # (bq, 1) replicated
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)              # rescale old stats
        p = jnp.exp(s - m_new)                       # (bq, bk)
        l_new = alpha * l_scr[:, :1] + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(jk == n_kv - 1)
    def _finalize():
        l = l_scr[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)              # fully-masked rows -> 0
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "chunk", "scale", "block_q",
                     "block_k", "interpret"))
def flash_attention_pallas(q, k, v, *, causal=True, window=0, chunk=0,
                           scale=None, block_q=128, block_k=128,
                           interpret=False):
    """q (B,S,H,dh); k,v (B,S,K,dh) -> (B,S,H,dh).  Self-attention layout
    (training / prefill); decode uses the jnp path."""
    B, S, H, dh = q.shape
    K = k.shape[2]
    G = H // K
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    scale = scale if scale is not None else dh ** -0.5

    qt = q.transpose(0, 2, 1, 3)                     # (B,H,S,dh)
    kt = k.transpose(0, 2, 1, 3)                     # (B,K,S,dh)
    vt = v.transpose(0, 2, 1, 3)
    n_q = S // block_q
    n_kv = S // block_k

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window, chunk=chunk,
        bq=block_q, bk=block_k, n_kv=n_kv)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh),
                         lambda b, h, iq, jk: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda b, h, iq, jk: (b, h // G, jk, 0)),
            pl.BlockSpec((1, 1, block_k, dh),
                         lambda b, h, iq, jk: (b, h // G, jk, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, dh),
                               lambda b, h, iq, jk: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, LANES), jnp.float32),   # running max
            pltpu.VMEM((block_q, LANES), jnp.float32),   # running denom
            pltpu.VMEM((block_q, dh), jnp.float32),      # output accum
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
