"""Pure-jnp oracle for the flash-attention kernel.

Shared mask logic for all attention variants in the framework:

* ``causal``      — q_pos >= k_pos
* ``window > 0``  — sliding window: q_pos - k_pos < window
* ``chunk > 0``   — llama4-style chunked locality: q_pos//chunk == k_pos//chunk
* k positions < 0 mark invalid (unwritten cache slots)

GQA is native: q has H heads, k/v have K heads, H = K * G.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_mask(q_pos: jax.Array, k_pos: jax.Array, *, causal: bool,
                   window: int = 0, chunk: int = 0) -> jax.Array:
    """(..., Sq), (..., Sk) int32 -> (..., Sq, Sk) bool (True = attend)."""
    q = q_pos[..., :, None]
    k = k_pos[..., None, :]
    m = k >= 0
    if causal:
        m &= q >= k
    if window:
        m &= (q - k) < window
    if chunk:
        m &= (q // chunk) == (k // chunk)
    return m


def mha_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
            causal: bool = True, window: int = 0, chunk: int = 0,
            q_positions: jax.Array | None = None,
            k_positions: jax.Array | None = None,
            softcap: float = 0.0, scale: float | None = None) -> jax.Array:
    """q (B,Sq,H,dh); k,v (B,Sk,K,dh) -> (B,Sq,H,dh).

    Softmax statistics in fp32; output in q.dtype."""
    B, Sq, H, dh = q.shape
    _, Sk, K, _ = k.shape
    assert H % K == 0, (H, K)
    G = H // K
    scale = scale if scale is not None else dh ** -0.5

    if q_positions is None:
        q_positions = jnp.broadcast_to(jnp.arange(Sq), (B, Sq))
        if Sq != Sk:  # decode: new tokens sit at the end of the kv history
            q_positions = q_positions + (Sk - Sq)
    if k_positions is None:
        k_positions = jnp.broadcast_to(jnp.arange(Sk), (B, Sk))

    qg = q.reshape(B, Sq, K, G, dh)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    mask = attention_mask(q_positions, k_positions, causal=causal,
                          window=window, chunk=chunk)  # (B,Sq,Sk)
    logits = jnp.where(mask[:, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)
    return out.reshape(B, Sq, H, dh)


def mha_blocked(q, k, v, *, causal=True, window=0, chunk=0,
                softcap: float = 0.0, scale: float | None = None,
                block_q: int = 1024):
    """Query-blocked exact attention (jnp): identical math to mha_ref but
    never materializes the full (Sq, Sk) score matrix — the CPU/XLA
    lowering analogue of the flash kernel, used for long self-attention so
    the dry-run's memory analysis reflects a production schedule rather
    than an O(S²) buffer."""
    B, Sq, H, dh = q.shape
    _, Sk, K, _ = k.shape
    G = H // K
    scale = scale if scale is not None else dh ** -0.5
    assert Sq % block_q == 0, (Sq, block_q)
    nq = Sq // block_q
    qb = q.reshape(B, nq, block_q, K, G, dh)
    k_pos = jnp.arange(Sk)

    def one_block(i):
        qi = qb[:, i]                                 # (B,bq,K,G,dh)
        q_pos = i * block_q + jnp.arange(block_q)
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qi, k,
                            preferred_element_type=jnp.float32) * scale
        if softcap:
            logits = softcap * jnp.tanh(logits / softcap)
        m = attention_mask(q_pos[None], k_pos[None], causal=causal,
                           window=window, chunk=chunk)  # (1,bq,Sk)
        logits = jnp.where(m[:, None, None], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)

    out = jax.lax.map(one_block, jnp.arange(nq))       # (nq,B,bq,K,G,dh)
    out = jnp.moveaxis(out, 0, 1).reshape(B, Sq, H, dh)
    return out


def mha_blocked_windowed(q, k, v, *, causal=True, window=0, chunk=0,
                         softcap: float = 0.0, scale: float | None = None,
                         block_q: int = 1024):
    """Locality-aware blocked attention: each q-block only reads the K/V
    slice its mask can reach (sliding window / chunk locality), instead of
    scoring against the full sequence.  Python loop with static slice
    bounds — every block appears in the HLO, so both the work saving and
    the cost accounting are exact.  This is the jnp-path analogue of the
    Pallas kernel's block skipping."""
    B, Sq, H, dh = q.shape
    Sk = k.shape[1]
    assert Sq == Sk and Sq % block_q == 0
    assert window or chunk, "use mha_blocked for global attention"
    nq = Sq // block_q
    outs = []
    for i in range(nq):
        hi = (i + 1) * block_q if causal else min(
            Sk, (i + 1) * block_q + (window or chunk))
        lo = 0
        if window:
            lo = max(0, i * block_q - window + 1)
        if chunk:
            lo = max(lo, (i * block_q // chunk) * chunk)
        qi = q[:, i * block_q:(i + 1) * block_q]
        ki = k[:, lo:hi]
        vi = v[:, lo:hi]
        q_pos = jnp.broadcast_to(
            i * block_q + jnp.arange(block_q), (B, block_q))
        k_pos = jnp.broadcast_to(jnp.arange(lo, hi), (B, hi - lo))
        outs.append(mha_ref(qi, ki, vi, causal=causal, window=window,
                            chunk=chunk, q_positions=q_pos,
                            k_positions=k_pos, softcap=softcap, scale=scale))
    return jnp.concatenate(outs, axis=1)
