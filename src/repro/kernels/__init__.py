"""repro.kernels — Pallas TPU kernels for the framework's compute hot spots.

Layout (per the repo convention):

    kernels/<name>/kernel.py   pl.pallas_call + BlockSpec VMEM tiling (TPU target)
    kernels/<name>/ref.py      pure-jnp oracle (also the CPU lowering path)
    kernels/ops.py             jit'd dispatch wrappers used by the models

Dispatch policy: on a TPU backend the Pallas kernel is lowered; elsewhere
(this CPU container, and the multi-device dry-run) the mathematically
identical jnp reference is lowered so XLA cost analysis stays well-defined.
`REPRO_KERNELS=interpret` forces Pallas-in-interpret-mode (used by the
kernel test suite to execute the actual kernel bodies on CPU).

The paper (CannyFS) has no compute-kernel contribution — these kernels are
the perf-critical layers of the surrounding training/serving framework
(attention, SSD scan, RG-LRU scan, fused RMSNorm), per the brief.
"""
from . import ops

__all__ = ["ops"]
