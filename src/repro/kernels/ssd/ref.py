"""Pure-jnp oracles for the Mamba-2 SSD (state-space duality) scan.

Two references:

* ``ssd_sequential`` — the exact recurrence, scanned one timestep at a time.
  This is the ground-truth oracle.
* ``ssd_chunked``    — the SSD chunked decomposition (intra-chunk quadratic
  term + inter-chunk state passing), mathematically identical, and the
  algorithm the Pallas kernel implements.  Also the CPU lowering path.

Shapes follow Mamba-2 (arXiv:2405.21060):
    x  (B, S, H, P)   values (P = head dim)
    dt (B, S, H)      positive step sizes (already softplus'ed)
    A  (H,)           negative real decay per head
    Bm (B, S, G, N)   input matrix  (G groups, N = state dim)
    Cm (B, S, G, N)   output matrix
    D  (H,)           skip connection
Recurrence per head h (group g = h * G // H):
    state_t = exp(dt_t A_h) * state_{t-1} + dt_t * x_t ⊗ B_t
    y_t     = C_t · state_t + D_h * x_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _expand_groups(Bm: jax.Array, H: int) -> jax.Array:
    """(B,S,G,N) -> (B,S,H,N) by repeating each group over its heads."""
    G = Bm.shape[2]
    assert H % G == 0
    return jnp.repeat(Bm, H // G, axis=2)


def ssd_sequential(x, dt, A, Bm, Cm, D):
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    Bh = _expand_groups(Bm.astype(jnp.float32), H)
    Ch = _expand_groups(Cm.astype(jnp.float32), H)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    decay = jnp.exp(dtf * A.astype(jnp.float32))  # (B,S,H)

    def step(state, inp):
        xt, bt, ct, at, dtt = inp       # (B,H,P),(B,H,N),(B,H,N),(B,H),(B,H)
        state = state * at[..., None, None] + (
            (dtt[..., None] * xt)[..., :, None] * bt[..., None, :])
        y = jnp.einsum("bhpn,bhn->bhp", state, ct)
        return state, y

    s0 = jnp.zeros((B, H, P, N), jnp.float32)
    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(Bh, 1, 0),
          jnp.moveaxis(Ch, 1, 0), jnp.moveaxis(decay, 1, 0),
          jnp.moveaxis(dtf, 1, 0))
    _, ys = jax.lax.scan(step, s0, xs)
    y = jnp.moveaxis(ys, 0, 1) + xf * D.astype(jnp.float32)[None, None, :, None]
    return y.astype(x.dtype)


def ssd_chunked(x, dt, A, Bm, Cm, D, *, chunk: int = 128):
    """The SSD algorithm: O(S·chunk) intra-chunk + O(S/chunk) state pass."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    f32 = jnp.float32

    xf = x.astype(f32).reshape(B, nc, chunk, H, P)
    dtf = dt.astype(f32).reshape(B, nc, chunk, H)
    Bh = _expand_groups(Bm.astype(f32), H).reshape(B, nc, chunk, H, N)
    Ch = _expand_groups(Cm.astype(f32), H).reshape(B, nc, chunk, H, N)

    dA = dtf * A.astype(f32)                      # (B,nc,Q,H) log-decay
    cum = jnp.cumsum(dA, axis=2)                  # inclusive cumulative
    total = cum[:, :, -1:]                        # (B,nc,1,H)

    # intra-chunk quadratic term: att[i,j] = exp(cum_i - cum_j) for i >= j.
    # The argument is masked BEFORE the exp — masking the exp's output
    # leaves exp(+big) = inf on the dead branch, whose gradient is
    # inf * 0 = NaN (the standard where-grad trap).
    li = cum[:, :, :, None, :]                    # (B,nc,Q,1,H)
    lj = cum[:, :, None, :, :]                    # (B,nc,1,Q,H)
    idx = jnp.arange(chunk)
    causal = idx[:, None] >= idx[None, :]
    arg = jnp.where(causal[None, None, :, :, None], li - lj, -1e30)
    att = jnp.exp(arg)                            # (B,nc,Q,Q,H)
    cb = jnp.einsum("bcqhn,bcshn->bcqsh", Ch, Bh)
    y_intra = jnp.einsum("bcqsh,bcqsh,bcsh,bcshp->bcqhp",
                         cb, att, dtf, xf)

    # per-chunk end state: sum_j exp(total - cum_j) * dt_j * B_j x_j
    decay_to_end = jnp.exp(total - cum)           # (B,nc,Q,H)
    chunk_state = jnp.einsum("bcqh,bcqh,bcqhn,bcqhp->bchpn",
                             decay_to_end, dtf, Bh, xf)

    # inter-chunk recurrence over nc chunks
    def step(state, inp):
        st_c, tot_c = inp                          # (B,H,P,N), (B,H)
        out_state = state                          # state entering the chunk
        state = state * jnp.exp(tot_c)[..., None, None] + st_c
        return state, out_state

    s0 = jnp.zeros((B, H, P, N), f32)
    _, states_in = jax.lax.scan(
        step, s0, (jnp.moveaxis(chunk_state, 1, 0),
                   jnp.moveaxis(total[:, :, 0], 1, 0)))
    states_in = jnp.moveaxis(states_in, 0, 1)      # (B,nc,H,P,N)

    # inter-chunk contribution: C_i exp(cum_i) state_in
    y_inter = jnp.einsum("bcqh,bcqhn,bchpn->bcqhp",
                         jnp.exp(cum), Ch, states_in)

    y = (y_intra + y_inter).reshape(B, S, H, P)
    y = y + x.astype(f32) * D.astype(f32)[None, None, :, None]
    return y.astype(x.dtype)


def ssd_decode_step(state, x, dt, A, Bm, Cm, D):
    """Single-token decode: state (B,H,P,N), x (B,H,P), dt (B,H),
    Bm/Cm (B,G,N) -> (new_state, y)."""
    H = x.shape[1]
    f32 = jnp.float32
    Bh = _expand_groups(Bm.astype(f32)[:, None], H)[:, 0]
    Ch = _expand_groups(Cm.astype(f32)[:, None], H)[:, 0]
    dtf = dt.astype(f32)
    a = jnp.exp(dtf * A.astype(f32))
    state = state * a[..., None, None] + (
        (dtf[..., None] * x.astype(f32))[..., :, None] * Bh[..., None, :])
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch)
    y = y + x.astype(f32) * D.astype(f32)[None, :, None]
    return state, y.astype(x.dtype)
