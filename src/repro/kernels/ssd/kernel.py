"""Pallas TPU kernel for the Mamba-2 SSD chunked scan.

TPU adaptation (vs the Triton SSD kernels in the Mamba-2 release):

* the chunk axis is the last (sequential) grid dimension; the carried
  (N × P) recurrent state lives in fp32 VMEM scratch and persists across
  chunk steps — replacing the GPU's separate state-passing kernel launch
  with a single fused pass;
* everything cheap and awkward for the MXU (softplus, cumsums of the
  log-decay within fixed chunk boundaries, dt scaling) is precomputed
  outside with jnp elementwise ops — the kernel keeps only the three
  matmuls (C·Bᵀ, scores·X, Bᵀ·X) that dominate FLOPs, sized so chunk Q is
  lane-aligned (128);
* numerically the intra-chunk factor uses exp(cum_i − cum_j) with i ≥ j
  only (argument ≤ 0 — stable), matching the reference.

Inputs are pre-arranged per (batch·head): see ``ssd_pallas``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import _expand_groups


def _ssd_kernel(cum_ref, xdt_ref, xe_ref, b_ref, c_ref, y_ref, state_scr, *,
                q):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    cum = cum_ref[0]                                  # (Q,) log-decay cumsum
    xdt = xdt_ref[0].astype(jnp.float32)              # (Q, P)  dt*x
    xe = xe_ref[0].astype(jnp.float32)                # (Q, P)  exp(tot-cum)*dt*x
    Bc = b_ref[0].astype(jnp.float32)                 # (Q, N)
    Cc = c_ref[0].astype(jnp.float32)                 # (Q, N)

    # intra-chunk: (C Bᵀ ⊙ decay ⊙ causal) @ (dt x)
    cb = jax.lax.dot_general(Cc, Bc, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q,Q)
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    # mask the argument, not the output (matches ref.py; avoids inf)
    att = jnp.exp(jnp.where(ii >= jj, cum[:, None] - cum[None, :], -1e30))
    y = jax.lax.dot_general(cb * att, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (Q,P)

    # inter-chunk: exp(cum) * (C @ state_in)
    state = state_scr[...]                            # (N, P)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cc, state, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    # state update: exp(total) * state + Bᵀ @ xe
    total = cum[q - 1]
    state_scr[...] = jnp.exp(total) * state + jax.lax.dot_general(
        Bc, xe, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    y_ref[0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_pallas(x, dt, A, Bm, Cm, D, *, chunk: int = 128, interpret=False):
    """Same contract as ssd_chunked: x (B,S,H,P), dt (B,S,H), A (H,),
    Bm/Cm (B,S,G,N), D (H,)."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0
    nc = S // chunk
    f32 = jnp.float32

    # ---- jnp-side precompute (elementwise; negligible FLOPs) ----
    dtf = dt.astype(f32)
    dA = (dtf * A.astype(f32)).reshape(B, nc, chunk, H)
    cum = jnp.cumsum(dA, axis=2)                       # within-chunk cumsum
    total = cum[:, :, -1:, :]
    xdt = (x.astype(f32) * dtf[..., None])
    xe = xdt * jnp.exp((total - cum)).reshape(B, S, H)[..., None]
    Bh = _expand_groups(Bm.astype(f32), H)             # (B,S,H,N)
    Ch = _expand_groups(Cm.astype(f32), H)

    # ---- per (batch·head) layout ----
    def bh(a):   # (B,S,H,...) -> (B*H, S, ...)
        return jnp.moveaxis(a, 2, 1).reshape((B * H, S) + a.shape[3:])

    cum_bh = bh(cum.reshape(B, S, H))                  # (BH, S)
    args = (cum_bh, bh(xdt), bh(xe), bh(Bh), bh(Ch))

    kernel = functools.partial(_ssd_kernel, q=chunk)
    y = pl.pallas_call(
        kernel,
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk), lambda g, c: (g, c)),
            pl.BlockSpec((1, chunk, P), lambda g, c: (g, c, 0)),
            pl.BlockSpec((1, chunk, P), lambda g, c: (g, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda g, c: (g, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda g, c: (g, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, P), lambda g, c: (g, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(*args)

    y = jnp.moveaxis(y.reshape(B, H, S, P), 1, 2)      # (B,S,H,P)
    y = y + x * D.astype(x.dtype)[None, None, :, None]
    return y
