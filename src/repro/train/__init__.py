from .steps import (TrainConfig, make_decode_step, make_encode_step,
                    make_eval_step, make_prefill_step, make_train_step,
                    serve_shardings, train_shardings)

__all__ = ["TrainConfig", "make_decode_step", "make_encode_step",
           "make_eval_step", "make_prefill_step", "make_train_step",
           "serve_shardings", "train_shardings"]
