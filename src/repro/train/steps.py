"""Step builders: training (fwd+bwd+AdamW) and serving (prefill / decode).

These are the functions the launcher jits with explicit in/out shardings;
the dry-run lowers exactly these.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import (ModelConfig, cross_entropy, decode_step,
                          forward_train, prefill)
from repro.optim import AdamWConfig, adamw_update, init_opt_state
from repro.parallel.sharding import (activation_constrainer, batch_pspecs,
                                     cache_pspecs, dp_axes, make_shardings,
                                     param_pspecs, zero1_specs)


@dataclass(frozen=True)
class TrainConfig:
    dtype: Any = jnp.bfloat16
    remat_policy: str = "dots_no_batch"
    activation_mode: str = "dp"         # "dp" | "dp_sp"
    z_loss: float = 1e-4
    peak_lr: float = 3e-4
    adamw: AdamWConfig = field(default_factory=AdamWConfig)
    zero1: bool = True
    pod_grad_compress: bool = False      # int8 cross-pod gradient psum
    scan_layers: bool = True             # False: unrolled (dry-run analysis)
    loss_dtype: Any = jnp.float32        # bfloat16: skip fp32 logits pass


def make_train_step(cfg: ModelConfig, mesh: Mesh,
                    tc: TrainConfig = TrainConfig()) -> Callable:
    pod_manual = tc.pod_grad_compress and "pod" in mesh.axis_names
    constrain = activation_constrainer(
        mesh, tc.activation_mode, exclude=("pod",) if pod_manual else ())

    def step_body(params, opt, batch, lr, grad_sync=None):
        def loss_fn(p):
            logits, aux = forward_train(
                p, batch, cfg, dtype=tc.dtype, remat_policy=tc.remat_policy,
                constrain=constrain, scan_layers=tc.scan_layers)
            labels = batch["labels"]
            loss, denom = cross_entropy(logits, labels,
                                        batch.get("loss_mask"),
                                        z_loss=tc.z_loss,
                                        compute_dtype=tc.loss_dtype)
            total = loss + cfg.router_aux_coef * aux
            return total, (loss, aux)

        (total, (loss, aux)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        if grad_sync is not None:
            grads, loss, aux, total = grad_sync(grads, loss, aux, total)
        new_params, new_opt, om = adamw_update(grads, opt, params, lr,
                                               tc.adamw)
        metrics = {"loss": loss, "aux_loss": aux, "total_loss": total,
                   "grad_norm": om["grad_norm"], "lr": lr}
        return new_params, new_opt, metrics

    if not pod_manual:
        def train_step(params, opt, batch, lr):
            return step_body(params, opt, batch, lr)
        return train_step

    # --- compressed cross-pod DP: shard_map over 'pod' only; data/model
    # stay under GSPMD auto-partitioning inside the region -----------------
    from repro.parallel.compress import compressed_grad_psum
    n_pods = mesh.shape["pod"]

    def pod_body(params, opt, batch, lr):
        def sync(grads, loss, aux, total):
            grads = compressed_grad_psum(grads, "pod", n_pods)
            loss = jax.lax.pmean(loss, "pod")
            aux = jax.lax.pmean(aux, "pod")
            total = jax.lax.pmean(total, "pod")
            return grads, loss, aux, total
        return step_body(params, opt, batch, lr, grad_sync=sync)

    def train_step(params, opt, batch, lr):
        batch_specs = {k: P("pod", *([None] * (v.ndim - 1)))
                       for k, v in batch.items()}
        return jax.shard_map(
            pod_body, mesh=mesh,
            in_specs=(P(), P(), batch_specs, P()),
            out_specs=(P(), P(), P()),
            axis_names={"pod"}, check_vma=False,
        )(params, opt, batch, lr)

    return train_step


def make_eval_step(cfg: ModelConfig, mesh: Mesh,
                   tc: TrainConfig = TrainConfig()) -> Callable:
    constrain = activation_constrainer(mesh, tc.activation_mode)

    def eval_step(params, batch):
        logits, _ = forward_train(params, batch, cfg, dtype=tc.dtype,
                                  constrain=constrain)
        loss, _ = cross_entropy(logits, batch["labels"],
                                batch.get("loss_mask"))
        return {"loss": loss}

    return eval_step


def make_encode_step(cfg: ModelConfig, mesh: Mesh, dtype=jnp.bfloat16,
                     scan_layers: bool = True) -> Callable:
    """Encoder-only forward (hubert prefill_32k): embeddings -> logits."""
    constrain = activation_constrainer(mesh, "dp")

    def encode_step(params, batch):
        logits, _ = forward_train(params, batch, cfg, dtype=dtype,
                                  constrain=constrain,
                                  scan_layers=scan_layers)
        return logits

    return encode_step


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def serve_extra_ctx(cfg: ModelConfig, mesh: Mesh, batch: int,
                    max_len: int) -> dict:
    """Decide KV-cache sequence sharding (-> distributed flash-decode).

    Heads shard on 'model' when divisible; otherwise the cache sequence dim
    is sharded — over 'model' only (batch still on dp) or over
    (data, model) when the batch itself is unshardable (long-context B=1)."""
    msize = mesh.shape.get("model", 1)
    dp = dp_axes(mesh)
    dp_total = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    extra: dict = {"mesh": mesh}
    if cfg.num_kv_heads % msize == 0:
        return extra  # head-sharded KV; no seq sharding needed
    if batch % dp_total == 0 and batch > 1:
        if max_len % msize == 0:
            extra["kv_seq_axes"] = ("model",)
            extra["kv_batch_axes"] = dp
    else:
        axes = tuple(dp) + ("model",)
        tot = dp_total * msize
        if max_len % tot == 0:
            extra["kv_seq_axes"] = axes
    return extra


def make_prefill_step(cfg: ModelConfig, mesh: Mesh, *, batch: int,
                      max_len: int, dtype=jnp.bfloat16,
                      scan_layers: bool = True) -> Callable:
    constrain = activation_constrainer(mesh, "dp")
    extra = serve_extra_ctx(cfg, mesh, batch, max_len)

    def prefill_step(params, batch_in, cache):
        return prefill(params, batch_in, cache, cfg, dtype=dtype,
                       constrain=constrain, extra_ctx=extra,
                       scan_layers=scan_layers)

    return prefill_step


def make_decode_step(cfg: ModelConfig, mesh: Mesh, *, batch: int,
                     max_len: int, dtype=jnp.bfloat16,
                     sample: bool = False, scan_layers: bool = True) -> Callable:
    constrain = activation_constrainer(mesh, "dp")
    extra = serve_extra_ctx(cfg, mesh, batch, max_len)

    def serve_step(params, tokens, cache):
        logits, cache = decode_step(params, tokens, cache, cfg, dtype=dtype,
                                    constrain=constrain, extra_ctx=extra,
                                    scan_layers=scan_layers)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_tok, logits, cache

    return serve_step


# ---------------------------------------------------------------------------
# sharding bundles (used by launcher, dry-run and checkpoint reshard)
# ---------------------------------------------------------------------------

def train_shardings(cfg: ModelConfig, mesh: Mesh, params_shape, batch_shape,
                    *, zero1: bool = True, replicate_embed: bool = False):
    pspecs = param_pspecs(cfg, params_shape, mesh,
                          replicate_embed=replicate_embed)
    opt_shape = jax.eval_shape(init_opt_state, params_shape)
    mv_specs = zero1_specs(pspecs, params_shape, mesh) if zero1 else pspecs
    opt_specs = {"m": mv_specs, "v": mv_specs, "count": P()}
    bspecs = batch_pspecs(cfg, batch_shape, mesh)
    return {
        "params": make_shardings(mesh, pspecs),
        "opt": make_shardings(mesh, opt_specs),
        "batch": make_shardings(mesh, bspecs),
        "pspecs": pspecs,
        "opt_specs": opt_specs,
        "batch_specs": bspecs,
    }


def serve_shardings(cfg: ModelConfig, mesh: Mesh, params_shape, cache_shape,
                    batch: int, max_len: int):
    pspecs = param_pspecs(cfg, params_shape, mesh)
    extra = serve_extra_ctx(cfg, mesh, batch, max_len)
    cspecs = cache_pspecs(cfg, cache_shape, mesh,
                          seq_axes=extra.get("kv_seq_axes", ()))
    return {
        "params": make_shardings(mesh, pspecs),
        "cache": make_shardings(mesh, cspecs),
        "pspecs": pspecs,
        "cache_specs": cspecs,
    }
