"""The training loop: steps + transactional checkpointing + metrics +
fault-tolerant restart.

Fault model (the paper's, applied to training):

* all host I/O (checkpoints, metrics, staged data) goes through CannyFS —
  eagerly ACKed, so the accelerator never stalls on storage latency;
* a checkpoint is a transaction: COMMIT marker last, rollback of partial
  output, restart from the last committed step;
* ``run_with_restarts`` is the job harness: on any step-time failure it
  rolls the engine back, restores the last committed checkpoint (possibly
  onto a different mesh — elasticity) and continues.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import TransactionalCheckpointManager
from repro.core import CannyFS
from repro.models import ModelConfig, init_params
from repro.optim import init_opt_state
from repro.train.metrics import MetricsWriter
from repro.train.steps import TrainConfig, make_train_step, train_shardings
from repro.optim.schedule import cosine_with_warmup


@dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    log_every: int = 10
    warmup: int = 10
    seed: int = 0
    keep_ckpts: int = 3


class Trainer:
    def __init__(self, cfg: ModelConfig, mesh, fs: CannyFS,
                 data: Iterator[dict], tc: TrainConfig = TrainConfig(),
                 lc: LoopConfig = LoopConfig(), ckpt_dir: str = "ckpt"):
        self.cfg = cfg
        self.mesh = mesh
        self.fs = fs
        self.data = data
        self.tc = tc
        self.lc = lc
        self.ckpt = TransactionalCheckpointManager(fs, ckpt_dir,
                                                   keep=lc.keep_ckpts)
        self.metrics = MetricsWriter(fs)
        self.step_fn: Optional[Callable] = None
        self.shardings = None
        self.state: dict[str, Any] = {}
        self.step = 0

    # ------------------------------------------------------------------

    def init_state(self, sample_batch: dict) -> None:
        cfg, mesh = self.cfg, self.mesh
        pshape = jax.eval_shape(
            lambda k: init_params(k, cfg),
            jax.ShapeDtypeStruct((2,), jnp.uint32))
        bshape = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                  for k, v in sample_batch.items()}
        sh = train_shardings(cfg, mesh, pshape, bshape, zero1=self.tc.zero1)
        self.shardings = sh
        step = make_train_step(cfg, mesh, self.tc)
        self.step_fn = jax.jit(
            step,
            in_shardings=(sh["params"], sh["opt"], sh["batch"], None),
            out_shardings=(sh["params"], sh["opt"], None),
            donate_argnums=(0, 1))

        # resume or cold start
        try:
            like = {"params": pshape, "opt": jax.eval_shape(init_opt_state,
                                                            pshape),
                    "step": jax.ShapeDtypeStruct((), jnp.int32)}
            step_no, restored = self.ckpt.restore(
                like, shardings={"params": sh["params"], "opt": sh["opt"],
                                 "step": None})
            self.state = restored
            self.step = int(np.asarray(restored["step"]))
            print(f"[trainer] restored committed checkpoint @ {step_no}")
        except FileNotFoundError:
            with self.mesh:
                params = jax.jit(
                    lambda k: init_params(k, cfg),
                    out_shardings=sh["params"])(
                        jax.random.PRNGKey(self.lc.seed))
                opt = jax.jit(init_opt_state,
                              out_shardings=sh["opt"])(params)
            self.state = {"params": params, "opt": opt,
                          "step": jnp.zeros((), jnp.int32)}
            self.step = 0

    # ------------------------------------------------------------------

    def put_batch(self, batch: dict):
        return {k: jax.device_put(np.asarray(v), self.shardings["batch"][k])
                for k, v in batch.items()}

    def run(self, max_steps: Optional[int] = None) -> dict:
        lc = self.lc
        target = min(self.lc.total_steps,
                     self.step + (max_steps or self.lc.total_steps))
        last_metrics: dict = {}
        t_start = time.monotonic()
        while self.step < target:
            batch = self.put_batch(next(self.data))
            lr = cosine_with_warmup(jnp.asarray(self.step, jnp.float32),
                                    peak_lr=self.tc.peak_lr,
                                    warmup=lc.warmup, total=lc.total_steps)
            with self.mesh:
                params, opt, m = self.step_fn(
                    self.state["params"], self.state["opt"], batch, lr)
            self.state = {"params": params, "opt": opt,
                          "step": jnp.asarray(self.step + 1, jnp.int32)}
            self.step += 1
            if self.step % lc.log_every == 0 or self.step == target:
                m = {k: float(np.asarray(v)) for k, v in m.items()}
                m["steps_per_s"] = self.step / (time.monotonic() - t_start)
                self.metrics.write(self.step, m)
                last_metrics = m
            if self.step % lc.ckpt_every == 0 or self.step == target:
                res = self.ckpt.save(self.step, jax.device_get(self.state))
                self.metrics.write(self.step, {"ckpt_ack_s": res.ack_s})
        self.ckpt.wait_for_save()
        return last_metrics


def run_with_restarts(make_trainer: Callable[[], Trainer], *,
                      max_restarts: int = 2) -> dict:
    """The job harness: run; on failure, roll back and resubmit (restore
    from last committed checkpoint).  Matches the paper's transaction
    retry loop at job granularity."""
    attempt = 0
    while True:
        trainer = make_trainer()
        try:
            sample = next(trainer.data)
            trainer.init_state(sample)
            return trainer.run()
        except Exception:
            attempt += 1
            trainer.fs.engine.reset_poison()
            trainer.fs.ledger.clear()
            if attempt > max_restarts:
                raise
            print(f"[trainer] step failure; restart {attempt}/{max_restarts}"
                  " from last committed checkpoint")
