"""Metrics/log writer — an eager, write-only stream (the paper's best case:
"performs most consistently when a task creates files ... without ever
reading them back")."""
from __future__ import annotations

import json
import time
from typing import Any

from repro.core import CannyFS


class MetricsWriter:
    def __init__(self, fs: CannyFS, path: str = "logs/metrics.jsonl"):
        self.fs = fs
        self.path = path
        parent = path.rsplit("/", 1)[0] if "/" in path else ""
        if parent:
            fs.makedirs(parent)
        self._f = fs.open(path, "wb")

    def write(self, step: int, metrics: dict[str, Any]) -> None:
        rec = {"step": step, "t": time.time()}
        for k, v in metrics.items():
            try:
                rec[k] = float(v)
            except (TypeError, ValueError):
                rec[k] = str(v)
        self._f.write((json.dumps(rec) + "\n").encode())

    def close(self) -> None:
        self._f.close()
