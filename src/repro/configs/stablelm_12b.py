"""StableLM-2-12B [hf:stabilityai/stablelm-2-12b].

40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352; full attention
(long_500k skipped per DESIGN.md §4).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    d_ff=13824,
    vocab_size=100352,
    block_pattern=("attn",),
)

SMOKE = ModelConfig(
    name="stablelm-12b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=128,
    block_pattern=("attn",),
)
