"""Qwen2-7B [arXiv:2407.10671; hf].

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064; QKV bias.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b",
    family="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    block_pattern=("attn",),
    qkv_bias=True,
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="qwen2-7b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=128,
    block_pattern=("attn",),
    qkv_bias=True,
)
