"""Moonshot Moonlight-16B-A3B [hf:moonshotai/Moonlight-16B-A3B].

48L d_model=2048 16H (MHA kv=16) vocab=163840; fine-grained MoE: 64 experts
top-6 with expert d_ff=1408, plus 2 always-on shared experts (DeepSeek-MoE
style, 2*1408=2816 shared hidden).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    block_pattern=("attn",),
    num_experts=64,
    experts_per_token=6,
    moe_dff=1408,
    shared_expert_dff=2816,
    capacity_factor=1.25,
)

SMOKE = ModelConfig(
    name="moonshot-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=64,
    vocab_size=128,
    block_pattern=("attn",),
    num_experts=8,
    experts_per_token=2,
    moe_dff=64,
    shared_expert_dff=64,
)
