"""StableLM-3B class config [hf:stabilityai/stablelm-2-1_6b family].

32L d_model=2560 32H (MHA kv=32) d_ff=6912 vocab=50304.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b",
    family="dense",
    num_layers=32,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=6912,
    vocab_size=50304,
    block_pattern=("attn",),
)

SMOKE = ModelConfig(
    name="stablelm-3b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=128,
    block_pattern=("attn",),
)
