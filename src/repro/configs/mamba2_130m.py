"""Mamba2-130M [arXiv:2405.21060].

24L d_model=768, attention-free SSD (state-space duality), ssm_state=128,
vocab=50280.  Constant-size decode state -> long_500k runs.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=12,            # unused by ssd blocks
    num_kv_heads=12,
    d_ff=0,                  # no FFN: mamba2 backbone is mixer-only...
    vocab_size=50280,
    block_pattern=("ssd",),
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_groups=1,
    ssm_conv=4,
    ssm_chunk=128,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-130m-smoke",
    family="ssm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=128,
    block_pattern=("ssd",),
    ssm_state=16,
    ssm_head_dim=16,
    ssm_expand=2,
    ssm_groups=1,
    ssm_conv=4,
    ssm_chunk=16,
    tie_embeddings=True,
)
