"""RecurrentGemma-9B [arXiv:2402.19427 (Griffin); hf].

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000; hybrid 2:1
RG-LRU : local-attention pattern (window 2048), GeGLU, logit softcap,
tied embeddings.  38 = (rglru, rglru, attn_local) x 12 + (rglru, rglru)
remainder.  Bounded state -> long_500k runs.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    block_pattern=("rglru", "rglru", "attn_local"),
    window=2048,
    act="gelu",
    lru_width=4096,
    logit_softcap=30.0,
    tie_embeddings=True,
    ssm_conv=4,
)

SMOKE = ModelConfig(
    name="recurrentgemma-9b-smoke",
    family="hybrid",
    num_layers=5,              # exercises the remainder path (5 = 3 + 2)
    d_model=64,
    num_heads=4,
    num_kv_heads=1,
    d_ff=128,
    vocab_size=128,
    block_pattern=("rglru", "rglru", "attn_local"),
    window=16,
    act="gelu",
    lru_width=64,
    logit_softcap=30.0,
    tie_embeddings=True,
    ssm_conv=4,
)
