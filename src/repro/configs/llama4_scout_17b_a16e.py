"""Llama-4-Scout-17B-16E [hf:meta-llama/Llama-4-Scout-17B-16E].

48L d_model=5120 40H (GQA kv=8) vocab=202048; MoE 16 experts top-1 with an
always-on shared expert (d_ff=8192 each, A17B active); iRoPE layout —
3 of 4 layers use chunked local attention (chunk 8192, RoPE), every 4th
layer is global attention with NoPE.  The chunked layout is what makes
long_500k feasible (global layers use sequence-sharded decode attention,
DESIGN.md §4).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    block_pattern=("attn_chunked", "attn_chunked", "attn_chunked",
                   "attn_global"),
    chunk_size=8192,
    rope_theta=500_000.0,
    num_experts=16,
    experts_per_token=1,
    moe_dff=8192,
    shared_expert_dff=8192,
)

SMOKE = ModelConfig(
    name="llama4-scout-smoke",
    family="moe",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=128,
    block_pattern=("attn_chunked", "attn_chunked", "attn_chunked",
                   "attn_global"),
    chunk_size=16,
    num_experts=4,
    experts_per_token=1,
    moe_dff=128,
    shared_expert_dff=128,
)
