"""H2O-Danube3-4B [arXiv:2401.16818 family].

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000; llama+mistral mix
with sliding-window attention (window 4096) — sub-quadratic decode, so
long_500k runs (DESIGN.md §4).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,
    d_ff=10240,
    vocab_size=32000,
    block_pattern=("attn_sliding",),
    window=4096,
)

SMOKE = ModelConfig(
    name="h2o-danube-3-4b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=128,
    block_pattern=("attn_sliding",),
    window=16,
)
