"""Architecture registry: the 10 assigned configs + reduced smoke variants.

``get_config(name)`` returns the exact published configuration;
``get_smoke_config(name)`` returns a tiny same-family variant for CPU tests.
"""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "qwen2-vl-2b",
    "hubert-xlarge",
    "stablelm-12b",
    "stablelm-3b",
    "qwen2-7b",
    "h2o-danube-3-4b",
    "mamba2-130m",
    "llama4-scout-17b-a16e",
    "moonshot-v1-16b-a3b",
    "recurrentgemma-9b",
]

_MODULES = {name: "repro.configs." + name.replace("-", "_")
            for name in ARCH_IDS}


def _load(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    return importlib.import_module(_MODULES[name])


def get_config(name: str):
    return _load(name).CONFIG


def get_smoke_config(name: str):
    return _load(name).SMOKE
