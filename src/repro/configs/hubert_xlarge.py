"""HuBERT X-Large [arXiv:2106.07447].

48L d_model=1280 16H d_ff=5120 vocab=504 (k-means cluster targets);
encoder-only (bidirectional), masked-prediction objective.  The conv
waveform frontend is a stub: input_specs provide precomputed 512-d frame
features.  No decode step (DESIGN.md §4).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="encoder",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    block_pattern=("attn_bidir",),
    causal=False,
    act="gelu",
    modality="audio_stub",
)

SMOKE = ModelConfig(
    name="hubert-xlarge-smoke",
    family="encoder",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=32,
    block_pattern=("attn_bidir",),
    causal=False,
    act="gelu",
    modality="audio_stub",
)
