"""Qwen2-VL-2B text backbone [arXiv:2409.12191; hf].

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936; M-RoPE; QKV bias.
The vision tower is a stub: input_specs provide precomputed patch embeddings
merged early-fusion style (DESIGN.md §4).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    block_pattern=("attn",),
    qkv_bias=True,
    rope_theta=1_000_000.0,
    pos_type="mrope",
    mrope_sections=(16, 24, 24),
    tie_embeddings=True,          # qwen2-vl-2b ties embeddings
    modality="vision_stub",
    frontend_tokens=1024,
)

SMOKE = ModelConfig(
    name="qwen2-vl-2b-smoke",
    family="vlm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=128,
    block_pattern=("attn",),
    qkv_bias=True,
    pos_type="mrope",
    mrope_sections=(4, 2, 2),
    tie_embeddings=True,
    modality="vision_stub",
    frontend_tokens=4,
)
