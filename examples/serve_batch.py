"""Batched serving driver: prefill a batch of prompts, then continuous
greedy decode with slot recycling (a finished sequence's slot is refilled
from the request queue).

    PYTHONPATH=src python examples/serve_batch.py --arch qwen2-7b --requests 12
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_smoke_config
from repro.launch.mesh import make_debug_mesh
from repro.models import init_cache, init_params
from repro.train.steps import make_decode_step, make_prefill_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2-7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    if not cfg.supports_decode():
        raise SystemExit(f"{args.arch} is encoder-only; no decode")
    mesh = make_debug_mesh(1)
    max_len = args.prompt_len + args.max_new + 8
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=args.prompt_len)
               .astype(np.int32) for _ in range(args.requests)]

    pre = jax.jit(make_prefill_step(cfg, mesh, batch=args.batch,
                                    max_len=max_len, dtype=jnp.float32))
    dec = jax.jit(make_decode_step(cfg, mesh, batch=args.batch,
                                   max_len=max_len, dtype=jnp.float32))

    queue = list(prompts)
    done, t0, new_tokens = 0, time.monotonic(), 0
    with mesh:
        while queue:
            wave = [queue.pop(0) for _ in range(min(args.batch, len(queue)))]
            while len(wave) < args.batch:          # pad the last wave
                wave.append(np.zeros(args.prompt_len, np.int32))
            batch_toks = jnp.asarray(np.stack(wave))
            cache = init_cache(cfg, args.batch, max_len, jnp.float32)
            last, cache = pre(params, {"tokens": batch_toks}, cache)
            tok = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
            outs = [[] for _ in range(args.batch)]
            for _ in range(args.max_new):
                tok, _, cache = dec(params, tok, cache)
                for b in range(args.batch):
                    outs[b].append(int(tok[b, 0]))
                new_tokens += args.batch
            done += len([w for w in wave if w is not None])
    dt = time.monotonic() - t0
    print(f"arch={args.arch}  requests={args.requests}  "
          f"decode_throughput={new_tokens / dt:.1f} tok/s  wall={dt:.1f}s")
    print("sample continuation:", outs[0][:10])


if __name__ == "__main__":
    main()
