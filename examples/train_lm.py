"""End-to-end training driver: a ~100M-param LM on synthetic data with
transactional checkpointing, metrics streaming and crash recovery.

    PYTHONPATH=src python examples/train_lm.py --preset small --steps 120
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

The '100m' preset is the deliverable configuration (intended pace on
accelerators; it runs — slowly — on this CPU container).  'small' (~10M)
demonstrates the identical pipeline in a few minutes on CPU.
"""
import argparse
import sys
import tempfile

sys.path.insert(0, "src")

import jax.numpy as jnp

from repro.core import CannyFS, LatencyBackend, LatencyModel, LocalBackend
from repro.data import Prefetcher, SyntheticLM
from repro.launch.mesh import make_debug_mesh
from repro.models.config import ModelConfig
from repro.train.loop import LoopConfig, Trainer, run_with_restarts
from repro.train.steps import TrainConfig

PRESETS = {
    "small": dict(num_layers=6, d_model=256, num_heads=8, num_kv_heads=4,
                  d_ff=1024, vocab_size=4096, batch=8, seq=128),
    "100m": dict(num_layers=12, d_model=512, num_heads=8, num_kv_heads=4,
                 d_ff=2048, vocab_size=32768, batch=16, seq=256),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=PRESETS, default="small")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--ckpt-every", type=int, default=40)
    ap.add_argument("--workdir", default=None)
    ap.add_argument("--io-latency-ms", type=float, default=1.0,
                    help="simulated remote-storage latency (0 = local)")
    args = ap.parse_args()

    p = PRESETS[args.preset]
    cfg = ModelConfig(name=f"lm-{args.preset}", family="dense",
                      num_layers=p["num_layers"], d_model=p["d_model"],
                      num_heads=p["num_heads"], num_kv_heads=p["num_kv_heads"],
                      d_ff=p["d_ff"], vocab_size=p["vocab_size"],
                      block_pattern=("attn",))
    print(f"model: {cfg.name}  params≈{cfg.param_count() / 1e6:.1f}M")

    workdir = args.workdir or tempfile.mkdtemp(prefix="repro_train_")
    backend = LocalBackend(workdir)
    if args.io_latency_ms:
        backend = LatencyBackend(backend, LatencyModel(
            meta_ms=args.io_latency_ms, data_ms=args.io_latency_ms,
            jitter_sigma=0.2))
    fs = CannyFS(backend, max_inflight=4000, workers=32)
    print(f"workdir: {workdir} (transactional I/O via CannyFS engine)")

    def factory():
        data = Prefetcher(iter(SyntheticLM(cfg, batch=p["batch"],
                                           seq_len=p["seq"], seed=0)),
                          depth=2)
        return Trainer(
            cfg, make_debug_mesh(1), fs, data,
            tc=TrainConfig(dtype=jnp.float32, remat_policy="none",
                           peak_lr=3e-3, z_loss=1e-4),
            lc=LoopConfig(total_steps=args.steps,
                          ckpt_every=args.ckpt_every, log_every=10,
                          warmup=20))

    metrics = run_with_restarts(factory, max_restarts=1)
    print("final metrics:", {k: round(v, 4) for k, v in metrics.items()})
    fs.drain()
    print("metrics log:")
    for line in fs.read_file("logs/metrics.jsonl").decode().splitlines()[-5:]:
        print("  ", line)
    fs.close()


if __name__ == "__main__":
    main()
