"""Quickstart: the CannyFS idea in 60 seconds.

Runs the paper's two model tasks (archive extraction, rm -rf) against a
simulated NFS-under-load backend, eager vs synchronous, then shows the
transaction failure/rollback/retry loop.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
import time

sys.path.insert(0, "src")

from repro.core import (CannyFS, EagerFlags, InMemoryBackend, LatencyBackend,
                        LatencyModel, Transaction, TransactionFailedError,
                        run_transaction)


def remote():
    """NFS over GbE under moderate cluster load (paper's environment)."""
    return LatencyBackend(InMemoryBackend(),
                          LatencyModel(meta_ms=2.0, data_ms=2.0, load=2.0,
                                       jitter_sigma=0.3, seed=0))


def extract(fs: CannyFS, n=400):
    fs.makedirs("tree/src")
    for i in range(n):
        fs.write_file(f"tree/src/file_{i:04d}.c", b"int main(){}\n" * 40)
        fs.chmod(f"tree/src/file_{i:04d}.c", 0o644)


# 1 ─ latency hiding ---------------------------------------------------------
for name, flags in (("synchronous (plain NFS)", EagerFlags.all_off()),
                    ("CannyFS (eager, budget 4000)", EagerFlags())):
    fs = CannyFS(remote(), flags=flags, max_inflight=4000, workers=64)
    t0 = time.monotonic()
    extract(fs)
    fs.close()          # unmount: drain + report deferred errors
    print(f"{name:32s} {time.monotonic() - t0:6.2f}s")

# 2 ─ the job-as-transaction loop -------------------------------------------
class FlakyBackend(InMemoryBackend):
    """Storage that fails once (quota blip), then recovers."""
    failures = 1

    def write_at(self, path, off, data):
        if path.endswith("result.bin") and FlakyBackend.failures > 0:
            FlakyBackend.failures -= 1
            raise OSError(122, "Disk quota exceeded")
        return super().write_at(path, off, data)


fs = CannyFS(FlakyBackend())


def job(fs: CannyFS):
    fs.makedirs("out")
    fs.write_file("out/result.bin", b"\x42" * 1024)


out = run_transaction(fs, job, retries=2)
print("transaction committed after retry; ledger:", len(fs.ledger))
fs.close()
