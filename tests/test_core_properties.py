"""Property-based tests (hypothesis): the engine's invariants.

The central property is the paper's implicit correctness claim: for a
single-writer batch task, replaying any operation stream through the eager
engine yields EXACTLY the filesystem state (and read values) of a fully
synchronous execution — eagerness may only change *when* things happen,
never *what*.
"""
from __future__ import annotations

import pytest

pytest.importorskip("hypothesis",
                    reason="hypothesis not installed (see requirements-dev.txt)")
import hypothesis.strategies as stx
from hypothesis import HealthCheck, given, settings

from repro.core import CannyFS, EagerFlags, InMemoryBackend

DIRS = ["a", "b", "a/sub"]
FILES = [f"{d}/f{i}" for d in DIRS for i in range(3)]


def op_strategy():
    write = stx.tuples(stx.just("write"), stx.sampled_from(FILES),
                       stx.binary(min_size=0, max_size=24))
    append = stx.tuples(stx.just("append"), stx.sampled_from(FILES),
                        stx.binary(min_size=1, max_size=8))
    read = stx.tuples(stx.just("read"), stx.sampled_from(FILES),
                      stx.just(b""))
    unlink = stx.tuples(stx.just("unlink"), stx.sampled_from(FILES),
                        stx.just(b""))
    rename = stx.tuples(stx.just("rename"), stx.sampled_from(FILES),
                        stx.sampled_from(FILES).map(lambda s: s.encode()))
    statop = stx.tuples(stx.just("stat"), stx.sampled_from(FILES),
                        stx.just(b""))
    readdir = stx.tuples(stx.just("readdir"), stx.sampled_from(DIRS),
                         stx.just(b""))
    chmod = stx.tuples(stx.just("chmod"), stx.sampled_from(FILES),
                       stx.just(b""))
    return stx.lists(stx.one_of(write, append, read, unlink, rename, statop,
                                readdir, chmod),
                     min_size=1, max_size=40)


class Oracle:
    """Synchronous in-memory reference semantics."""

    def __init__(self):
        self.files: dict[str, bytes] = {}

    def apply(self, op, path, arg):
        if op == "write":
            self.files[path] = arg
        elif op == "append":
            self.files[path] = self.files.get(path, b"") + arg
        elif op == "read":
            return self.files.get(path)
        elif op == "unlink":
            self.files.pop(path, None)
        elif op == "rename":
            dst = arg.decode()
            if path in self.files and path != dst:
                self.files[dst] = self.files.pop(path)
        elif op == "stat":
            f = self.files.get(path)
            return None if f is None else len(f)
        elif op == "readdir":
            return sorted({p.split("/")[-1] for p in self.files
                           if p.rsplit("/", 1)[0] == path}
                          | ({"sub"} if path == "a" else set()))
        return None


def drive(fs: CannyFS, ops):
    """Replay ops, checking every read-class result against the oracle
    *inline* (this is the read-barrier property).

    Destructive ops on missing paths are pre-filtered against the oracle —
    the paper's workload model is a valid single-writer task, and an eager
    engine would (correctly) report such mistakes only via the ledger."""
    oracle = Oracle()
    for op, path, arg in ops:
        if op in ("unlink", "chmod") and path not in oracle.files:
            continue
        if op == "rename" and (path not in oracle.files
                               or arg.decode() == path):
            continue
        expect = oracle.apply(op, path, arg)
        if op == "write":
            fs.write_file(path, arg)
        elif op == "append":
            with fs.open(path, "ab") as h:
                h.write(arg)
        elif op == "read":
            try:
                got = fs.read_file(path)
            except FileNotFoundError:
                got = None
            assert got == expect, (op, path, got, expect)
        elif op == "unlink":
            fs.unlink(path)
        elif op == "rename":
            fs.rename(path, arg.decode())
        elif op == "stat":
            st = fs.stat(path)
            got = st.size if st.exists else None
            assert got == expect, (op, path, got, expect)
        elif op == "readdir":
            got = [n for n in fs.readdir(path)]
            assert got == expect, (op, path, got, expect)
        elif op == "chmod":
            fs.chmod(path, 0o600)
    return oracle


@settings(max_examples=60, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ops=op_strategy(), workers=stx.sampled_from([1, 4, 16]))
def test_eager_equals_synchronous(ops, workers):
    """Final state identical to synchronous semantics; reads always
    observe all previously ACKed writes."""
    be = InMemoryBackend()
    fs = CannyFS(be, workers=workers, max_inflight=64)
    for d in DIRS:
        fs.makedirs(d)
    oracle = drive(fs, ops)
    fs.drain()
    # ledger clean: unlink/rename of missing paths were pre-filtered, so
    # any deferred error is a real ordering bug
    errors = [e for e in fs.ledger.entries()]
    assert not errors, errors
    snap = be.snapshot()
    assert snap["files"] == oracle.files
    fs.close()


@settings(max_examples=30, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ops=op_strategy(), budget=stx.sampled_from([1, 2, 8, 300]))
def test_budget_bound_holds(ops, budget):
    be = InMemoryBackend()
    fs = CannyFS(be, workers=4, max_inflight=budget)
    for d in DIRS:
        fs.makedirs(d)
    drive(fs, ops)
    fs.drain()
    assert fs.engine.stats.max_queue_depth <= budget
    fs.close()


@settings(max_examples=30, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ops=op_strategy())
def test_sync_mode_equals_eager_mode(ops):
    """all_off (fully synchronous) and default (fully eager) produce the
    same final filesystem."""
    final = []
    for flags in (EagerFlags(), EagerFlags.all_off()):
        be = InMemoryBackend()
        fs = CannyFS(be, flags=flags, workers=8)
        for d in DIRS:
            fs.makedirs(d)
        drive(fs, ops)
        fs.drain()
        final.append(be.snapshot()["files"])
        fs.close()
    assert final[0] == final[1]


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(fail_at=stx.integers(min_value=0, max_value=19),
       n=stx.integers(min_value=5, max_value=20))
def test_error_always_surfaces_by_commit(fail_at, n):
    """An injected failure on any write is (a) recorded in the ledger and
    (b) fails the transaction commit — never silently swallowed."""
    fail_at = fail_at % n

    class Bad(InMemoryBackend):
        def write_at(self, p, o, d):
            if p.endswith(f"f{fail_at}"):
                raise OSError(5, "injected")
            return super().write_at(p, o, d)

    from repro.core import Transaction, TransactionFailedError
    import pytest
    be = Bad()
    fs = CannyFS(be)
    txn = Transaction(fs)
    try:
        with txn:
            fs.makedirs("out")
            for i in range(n):
                fs.write_file(f"out/f{i}", b"data")
        raise AssertionError("commit should have failed")
    except TransactionFailedError as e:
        assert any(f"f{fail_at}" in str(en) for en in e.entries)
    txn.rollback()
    assert be.snapshot()["files"] == {}
    fs.close()
