"""Fused-vs-unfused oracle property tests (hypothesis): for any op
stream and any seed, fusion on/off leaves the InMemory backend in the
identical final state with identical read results and ledger outcomes."""
import pytest

pytest.importorskip("hypothesis",
                    reason="hypothesis not installed (see requirements-dev.txt)")
import hypothesis.strategies as stx
from hypothesis import HealthCheck, given, settings

from repro.core import (CannyFS, FaultInjectingBackend, FaultPlan, FaultRule,
                        FusionPolicy, InMemoryBackend, LatencyBackend,
                        LatencyModel, VirtualClock)


DIRS = ["a", "b"]
FILES = [f"{d}/f{i}" for d in DIRS for i in range(3)]


def fusion_op_strategy():
    """Chain-heavy streams: chunked writes, metadata bursts, unlinks that
    land inside the pending window, reads as observation points."""
    chunks = stx.tuples(stx.just("chunks"), stx.sampled_from(FILES),
                        stx.lists(stx.binary(min_size=1, max_size=12),
                                  min_size=1, max_size=6))
    meta = stx.tuples(stx.just("chmod"), stx.sampled_from(FILES),
                      stx.sampled_from([0o600, 0o640, 0o644]))
    trunc = stx.tuples(stx.just("truncate"), stx.sampled_from(FILES),
                       stx.integers(min_value=0, max_value=30))
    unlink = stx.tuples(stx.just("unlink"), stx.sampled_from(FILES),
                        stx.none())
    read = stx.tuples(stx.just("read"), stx.sampled_from(FILES), stx.none())
    return stx.lists(stx.one_of(chunks, meta, trunc, unlink, read),
                     min_size=1, max_size=30)


def _drive(fs, ops):
    reads = []
    live = set()
    for op, path, arg in ops:
        if op == "chunks":
            with fs.open(path, "wb") as h:
                for c in arg:
                    h.write(c)
            live.add(path)
        elif op in ("chmod", "truncate") and path in live:
            (fs.chmod if op == "chmod" else fs.truncate)(path, arg)
        elif op == "unlink" and path in live:
            fs.unlink(path)
            live.discard(path)
        elif op == "read" and path in live:
            reads.append(fs.read_file(path))
    return reads


@settings(max_examples=50, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ops=fusion_op_strategy(), workers=stx.sampled_from([1, 4]))
def test_fused_and_unfused_execution_identical(ops, workers):
    """The satellite property: for any op stream, fusion on/off leaves the
    InMemory oracle in the identical final state with identical reads and
    identical (empty) ledgers."""
    results = []
    for fusion in (True, False):
        be = InMemoryBackend()
        fs = CannyFS(be, workers=workers, fusion=fusion, echo_errors=False)
        for d in DIRS:
            fs.makedirs(d)
        reads = _drive(fs, ops)
        fs.drain()
        sig = sorted((e.kind, e.paths,
                      getattr(e.error, "errno", None))
                     for e in fs.ledger.entries())
        results.append((be.snapshot(), reads, sig))
        fs.close()
    assert results[0] == results[1]


@settings(max_examples=50, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ops=fusion_op_strategy(), workers=stx.sampled_from([1, 4, 8]))
def test_work_stealing_on_and_off_execution_identical(ops, workers):
    """PR 4 acceptance property: sharded dispatch with work stealing
    enabled vs disabled is purely a scheduling difference — for any op
    stream and worker count the InMemory oracle ends in the identical
    final state with identical reads and identical (empty) ledgers."""
    results = []
    for stealing in (True, False):
        be = InMemoryBackend()
        fs = CannyFS(be, workers=workers, work_stealing=stealing,
                     echo_errors=False)
        for d in DIRS:
            fs.makedirs(d)
        reads = _drive(fs, ops)
        fs.drain()
        sig = sorted((e.kind, e.paths, getattr(e.error, "errno", None))
                     for e in fs.ledger.entries())
        results.append((be.snapshot(), reads, sig))
        fs.close()
    assert results[0] == results[1]


@settings(max_examples=30, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ops=fusion_op_strategy(), workers=stx.sampled_from([1, 4]),
       seed=stx.integers(0, 3))
def test_adaptive_and_fixed_max_bytes_execution_identical(ops, workers, seed):
    """PR 4 acceptance property: sizing write coalescing from the
    latency backend's measured bandwidth-delay product (adaptive) vs the
    fixed FusionPolicy cap only changes *where* vectors rotate, never
    commit-visible state — identical final backend state, reads and
    ledger, on a latency stack so the BDP source is genuinely live."""
    results = []
    for adaptive in (True, False):
        inner = InMemoryBackend()
        remote = LatencyBackend(
            inner, LatencyModel(meta_ms=1.0, data_ms=1.0, jitter_sigma=0.3,
                                seed=seed), clock=VirtualClock())
        fs = CannyFS(remote, workers=workers, echo_errors=False,
                     fusion=FusionPolicy(adaptive_max_bytes=adaptive,
                                         # tiny floor/cap so the adaptive
                                         # clamp genuinely binds mid-stream
                                         min_adaptive_bytes=8,
                                         max_bytes=64))
        for d in DIRS:
            fs.makedirs(d)
        reads = _drive(fs, ops)
        fs.drain()
        sig = sorted((e.kind, e.paths, getattr(e.error, "errno", None))
                     for e in fs.ledger.entries())
        results.append((inner.snapshot(), reads, sig))
        fs.close()
    assert results[0] == results[1]


@settings(max_examples=20, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ops=fusion_op_strategy(), seed=stx.integers(0, 3))
def test_stealing_and_adaptive_agree_under_fault_plans(ops, seed):
    """Both PR 4 knobs together under a seeded fault plan: the two
    configurations may fail different backend calls (fault matching is
    per fused call and vector rotation points differ), but every
    injected fault surfaces in its run's ledger and a clean run (no
    faults fired in either mode) leaves identical state."""
    outcome = []
    for stealing, adaptive in ((True, True), (False, False)):
        plan = FaultPlan([FaultRule(error="EIO", ops=("write",),
                                    probability=0.25, max_failures=2)],
                         seed=seed)
        inner = InMemoryBackend()
        remote = LatencyBackend(
            inner, LatencyModel(meta_ms=1.0, data_ms=1.0, jitter_sigma=0.3,
                                seed=seed), clock=VirtualClock())
        fs = CannyFS(FaultInjectingBackend(remote, plan), workers=4,
                     work_stealing=stealing, echo_errors=False,
                     fusion=FusionPolicy(adaptive_max_bytes=adaptive,
                                         min_adaptive_bytes=8,
                                         max_bytes=64))
        for d in DIRS:
            fs.makedirs(d)
        _drive(fs, ops)
        fs.drain()
        n_write_errs = sum(e.kind == "write" for e in fs.ledger.entries())
        outcome.append((plan.injected, n_write_errs, inner.snapshot()))
        fs.close()
    for injected, write_errs, _ in outcome:
        assert write_errs == injected   # every fault is ledgered, none lost
    if outcome[0][0] == 0 and outcome[1][0] == 0:
        assert outcome[0][2] == outcome[1][2]


@settings(max_examples=20, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ops=fusion_op_strategy(), seed=stx.integers(0, 3))
def test_fused_and_unfused_ledger_outcomes_match_under_faults(ops, seed):
    """With a seeded fault plan the two modes may fail *different* backend
    calls (fault matching is per fused call, by design) — but a clean run
    (no injected faults in either mode) must produce identical state, and
    every injected fault must surface in its run's ledger."""
    outcome = []
    for fusion in (True, False):
        plan = FaultPlan([FaultRule(error="EIO", ops=("write",),
                                    probability=0.25, max_failures=2)],
                         seed=seed)
        be = InMemoryBackend()
        fs = CannyFS(FaultInjectingBackend(be, plan), workers=2,
                     fusion=fusion, echo_errors=False)
        for d in DIRS:
            fs.makedirs(d)
        _drive(fs, ops)
        fs.drain()
        n_write_errs = sum(e.kind == "write" for e in fs.ledger.entries())
        outcome.append((plan.injected, n_write_errs, be.snapshot()))
        fs.close()
    for injected, write_errs, _ in outcome:
        assert write_errs == injected   # every fault is ledgered, none lost
    if outcome[0][0] == 0 and outcome[1][0] == 0:
        assert outcome[0][2] == outcome[1][2]


