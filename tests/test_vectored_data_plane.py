"""PR 7 unit tests: the vectored data-plane primitives and their two
speculative consumers.

* ``stat_vec`` / ``read_vec`` through the backend decorator stack —
  base loop fallback, Local single-open, Latency ONE-roundtrip-per-
  batch, Quota whole-delegation, FaultInjecting one advisory rule
  match per *fused* batch;
* the ``LocalBackend.read_at`` sized-read accumulation (a single
  ``os.read`` may return short of the request);
* ``ReadAheadManager`` — pipelining, byte identity under racing
  mutations, random-access and EOF teardown, the LRU file bound;
* ``StatVecBatcher`` — batching + journaling correctness across
  rollback, single-shot consumption, the exemption rule;
* ``makedirs`` vectored parent probes.
"""
import pytest

from repro.core import (CannyFS, EagerFlags, FaultInjectingBackend, FaultPlan,
                        FaultRule, InMemoryBackend, LatencyBackend,
                        LatencyModel, LocalBackend, QuotaBackend, ReadPolicy,
                        Transaction, VirtualClock)

PAYLOAD = bytes(range(256)) * 64          # 16 KiB, byte-position-coded


def _mem(files=(), dirs=("d",)):
    be = InMemoryBackend()
    for d in dirs:
        be.mkdir(d)
    for p, data in files:
        be.create(p)
        be.write_at(p, 0, data)
    return be


def _lat(inner, **kw):
    kw.setdefault("meta_ms", 1.0)
    kw.setdefault("data_ms", 1.0)
    kw.setdefault("jitter_sigma", 0.0)
    kw.setdefault("seed", 3)
    return LatencyBackend(inner, LatencyModel(**kw), clock=VirtualClock())


# ---------------------------------------------------------------------------
# backend primitives
# ---------------------------------------------------------------------------


def test_stat_vec_base_loop_normalizes_and_reports_existence():
    be = _mem(files=[("d/f", b"xyz")])
    res = be.stat_vec(["d", "d//f", "missing", "d/f/"])
    assert res["d"].exists and res["d"].is_dir
    assert res["d/f"].exists and res["d/f"].size == 3
    assert not res["missing"].exists


def test_read_vec_matches_read_at_per_span(tmp_path):
    for be in (_mem(), LocalBackend(str(tmp_path))):
        if isinstance(be, LocalBackend):
            be.mkdir("d")
        be.create("d/f")
        be.write_at("d/f", 0, PAYLOAD)
        spans = [(0, 100), (100, 200), (len(PAYLOAD) - 50, 500), (1 << 20, 4)]
        got = be.read_vec("d/f", spans)
        assert got == [be.read_at("d/f", o, s) for o, s in spans]
        assert got[0] == PAYLOAD[:100]
        assert got[2] == PAYLOAD[-50:]     # short at EOF, like read_at
        assert got[3] == b""               # span past EOF


def test_local_read_at_sized_request_accumulates_to_eof(tmp_path):
    be = LocalBackend(str(tmp_path))
    be.create("f")
    be.write_at("f", 0, PAYLOAD)
    assert be.read_at("f", 0, len(PAYLOAD)) == PAYLOAD
    assert be.read_at("f", 0, len(PAYLOAD) + 999) == PAYLOAD
    assert be.read_at("f", 100, 64) == PAYLOAD[100:164]
    assert be.read_at("f", len(PAYLOAD) + 1, 8) == b""


def test_latency_backend_vec_ops_cost_one_roundtrip_each():
    remote = _lat(_mem(files=[("d/f", PAYLOAD)]))
    base = remote.op_count
    remote.stat_vec([f"d/p{i}" for i in range(8)] + ["d/f"])
    assert remote.op_count == base + 1
    remote.read_vec("d/f", [(0, 64), (64, 64), (4096, 64)])
    assert remote.op_count == base + 2


def test_quota_backend_delegates_vec_ops_whole():
    remote = _lat(_mem(files=[("d/f", PAYLOAD)]))
    quota = QuotaBackend(remote, budget_bytes=1 << 20)
    base = remote.op_count
    res = quota.stat_vec(["d", "d/f", "nope"])
    assert remote.op_count == base + 1     # not one inner call per path
    assert res["d/f"].exists and not res["nope"].exists
    assert quota.read_vec("d/f", [(0, 10)]) == [PAYLOAD[:10]]
    assert remote.op_count == base + 2


def test_fault_rules_match_once_per_fused_stat_vec_batch():
    plan = FaultPlan([FaultRule(error="EIO", ops=("stat",),
                                probability=1.0, max_failures=1)], seed=0)
    chaos = FaultInjectingBackend(_mem(files=[("d/f", b"x")]), plan)
    with pytest.raises(OSError):
        chaos.stat_vec([f"d/p{i}" for i in range(5)])
    # ONE fused batch of 5 probes consumed exactly ONE rule match
    assert plan.injected == 1
    res = chaos.stat_vec(["d/f", "d/g"])
    assert plan.injected == 1
    assert res["d/f"].exists and not res["d/g"].exists


def test_fault_rules_match_once_per_fused_read_vec():
    plan = FaultPlan([FaultRule(error="EIO", ops=("read",),
                                probability=1.0, max_failures=1)], seed=0)
    chaos = FaultInjectingBackend(_mem(files=[("d/f", PAYLOAD)]), plan)
    with pytest.raises(OSError):
        chaos.read_vec("d/f", [(0, 64), (64, 64), (128, 64)])
    assert plan.injected == 1
    assert chaos.read_vec("d/f", [(0, 64)]) == [PAYLOAD[:64]]


# ---------------------------------------------------------------------------
# ReadAheadManager
# ---------------------------------------------------------------------------

RA = ReadPolicy(adaptive=False, min_bytes=256, max_bytes=4096)


def test_sequential_stream_pipelines_windows_and_stays_byte_identical():
    fs = CannyFS(_lat(_mem(files=[("d/f", PAYLOAD)])), workers=4,
                 readahead=RA, echo_errors=False)
    assert fs.stat("d/f").size == len(PAYLOAD)   # warms the size
    out = b"".join(fs.pread("d/f", off, 1024)
                   for off in range(0, len(PAYLOAD), 1024))
    fs.close()
    assert out == PAYLOAD
    assert fs.stats.readahead_windows > 0
    assert fs.stats.readahead_hits > 0
    assert len(fs.ledger) == 0


def test_racing_write_cancels_pages_and_reader_sees_new_bytes():
    fs = CannyFS(_lat(_mem(files=[("d/f", PAYLOAD)])), workers=4,
                 readahead=RA, echo_errors=False)
    fs.stat("d/f")                                      # warms the size
    assert fs.pread("d/f", 0, 1024) == PAYLOAD[:1024]   # registers the run
    fs.drain()                                          # windows landed
    new = bytes(reversed(PAYLOAD))
    fs.write_file("d/f", new)                           # admitted mutation
    got = fs.pread("d/f", 1024, 1024)
    fs.close()
    assert got == new[1024:2048]
    assert fs.stats.readahead_cancelled >= 1


def test_random_access_drops_the_pipeline():
    fs = CannyFS(_lat(_mem(files=[("d/f", PAYLOAD)])), workers=4,
                 readahead=RA, echo_errors=False)
    fs.stat("d/f")
    assert fs.pread("d/f", 0, 512) == PAYLOAD[:512]
    assert fs.pread("d/f", 9000, 512) == PAYLOAD[9000:9512]  # non-sequential
    fs.close()
    ra = fs.engine.readahead
    assert "d/f" not in ra._files
    assert fs.stats.readahead_cancelled >= 1


def test_short_sync_read_learns_eof_and_stops_speculating():
    fs = CannyFS(_lat(_mem(files=[("d/f", PAYLOAD[:100])])), workers=4,
                 readahead=RA, echo_errors=False)
    fs.stat("d/f")
    assert fs.pread("d/f", 0, 64) == PAYLOAD[:64]
    assert fs.pread("d/f", 64, 64) == PAYLOAD[64:100]   # short: EOF
    assert fs.pread("d/f", 100, 64) == b""
    fs.close()
    assert "d/f" not in fs.engine.readahead._files
    assert len(fs.ledger) == 0


def test_max_files_lru_evicts_oldest_run():
    files = [(f"d/f{i}", PAYLOAD) for i in range(3)]
    fs = CannyFS(_lat(_mem(files=files)), workers=4,
                 readahead=ReadPolicy(adaptive=False, min_bytes=256,
                                      max_bytes=4096, max_files=1),
                 echo_errors=False)
    for p, _ in files:
        fs.stat(p)
        assert fs.pread(p, 0, 512) == PAYLOAD[:512]
    ra = fs.engine.readahead
    assert len(ra._files) == 1 and "d/f2" in ra._files
    fs.close()
    assert fs.stats.readahead_cancelled >= 2


def test_whole_file_read_bypasses_the_plane():
    fs = CannyFS(_lat(_mem(files=[("d/f", PAYLOAD)])), workers=4,
                 readahead=RA, echo_errors=False)
    assert fs.read_file("d/f") == PAYLOAD        # size=-1: sync path
    assert fs.engine.readahead._files == {}
    fs.close()


# ---------------------------------------------------------------------------
# StatVecBatcher
# ---------------------------------------------------------------------------


def test_txn_journaling_probes_batch_and_rollback_stays_exact():
    be = _mem(files=[("d/old", b"keep-me")])
    fs = CannyFS(be, workers=4,
                 readahead=ReadPolicy(adaptive=False, stat_batch=4),
                 echo_errors=False)
    txn = Transaction(fs)
    with txn:
        for i in range(6):
            fs.write_file(f"d/n{i}", b"fresh-%d" % i)
        fs.write_file("d/old", b"overwritten")
    st = fs.stats
    assert st.stat_probes >= 6
    assert st.stat_batches >= 1
    assert st.stat_probe_hits + st.stat_probe_fallbacks == st.stat_probes
    assert be.read_at("d/old", 0, -1) == b"overwritten"
    # second region: rollback must remove exactly what IT created —
    # the probes decide journal membership (pre-existing vs fresh)
    txn2 = Transaction(fs)
    with txn2:
        fs.write_file("d/n0", b"again")      # pre-existing now
        fs.write_file("d/n9", b"doomed")     # fresh: journaled
        fs.drain()
        txn2.rollback()
    assert be.stat("d/n0").exists            # survived (not re-journaled)
    assert not be.stat("d/n9").exists        # rolled back
    fs.close()


def test_probe_lookup_is_single_shot():
    fs = CannyFS(_mem(), workers=2, readahead=ReadPolicy(adaptive=False),
                 echo_errors=False)
    sb = fs.engine.stat_batcher
    txn = Transaction(fs)
    with txn:
        fs.write_file("d/p", b"x")           # its fn consumed the probe
        fs.drain()
        assert sb.lookup("d/p") is None      # retired: nothing to consume
    fs.close()


def test_probe_exemption_consumed_once_then_foreign_kinds_cancel():
    fs = CannyFS(_mem(), workers=2, readahead=ReadPolicy(adaptive=False),
                 echo_errors=False)
    sb = fs.engine.stat_batcher
    # a foreign admission before the consumer's own kind cancels
    sb.enqueue("d/a", "write")
    sb.on_op("unlink", ("d/a",))
    assert sb.lookup("d/a") is None
    # the probed op's own (single) admission is exempt; later same-path
    # admissions are FIFO-ordered after its execution, hence harmless
    sb.enqueue("d/b", "write")
    sb.on_op("write", ("d/b",))              # the consumer itself
    sb.on_op("unlink", ("d/b",))             # post-exemption: ignored
    sb.flush()
    fs.drain()
    assert sb.lookup("d/b") is not None
    # tree-structural admissions cancel unconditionally, even post-exempt
    sb.enqueue("d/c", "write")
    sb.on_op("write", ("d/c",))
    sb.on_op("remove_tree", ("d",))
    assert sb.lookup("d/c") is None
    fs.close()


def test_batcher_inert_outside_transactions():
    fs = CannyFS(_mem(), workers=2, readahead=ReadPolicy(adaptive=False),
                 echo_errors=False)
    fs.write_file("d/x", b"1")
    fs.create("d/y")
    fs.drain()
    assert fs.stats.stat_probes == 0
    fs.close()


# ---------------------------------------------------------------------------
# makedirs vectored parent probe
# ---------------------------------------------------------------------------


def test_makedirs_probes_cold_ancestry_in_one_roundtrip():
    # the probe's domain: a deep chain that mostly PRE-EXISTS on the
    # backend, unseen by this mount (a fresh chain is already answered
    # by the overlay's own claims) — sync mode pays one existence stat
    # per cold component, the probe folds them into ONE stat_vec
    counts = {}
    for label, readahead in (("vectored", ReadPolicy(adaptive=False)),
                             ("sync", False)):
        inner = InMemoryBackend()
        for d in ("a", "a/b", "a/b/c"):
            inner.mkdir(d)
        remote = _lat(inner)
        fs = CannyFS(remote, flags=EagerFlags(mkdir=False),
                     readahead=readahead, workers=2, echo_errors=False)
        fs.makedirs("a/b/c/d")
        fs.close()
        assert inner.stat("a/b/c/d").is_dir
        counts[label] = remote.op_count
    assert counts["vectored"] < counts["sync"]
