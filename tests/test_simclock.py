"""Unit tests for the measurement clocks: ``VirtualClock`` edge cases
and the ``SimClock`` discrete-event primitives (PR 6), including the
modelled park/steal dispatch costs the engine charges on the virtual
timeline."""
import threading

import pytest

from repro.core import (CannyFS, InMemoryBackend, LatencyBackend,
                        LatencyModel, SimClock, VirtualClock)


# ----------------------------------------------------------------------
# VirtualClock edge cases
# ----------------------------------------------------------------------

def test_virtualclock_no_sleep_threads_absent():
    clock = VirtualClock()

    def noop():
        clock.now()         # touching the clock without sleeping

    t = threading.Thread(target=noop)
    t.start(); t.join()
    assert clock.thread_seconds() == {}
    assert clock.makespan() == 0.0
    assert clock.now() == 0.0


def test_virtualclock_zero_and_negative_dt_are_noops():
    clock = VirtualClock(start=5.0)
    clock.sleep(0.0)
    clock.sleep(-1.0)
    assert clock.now() == 5.0
    assert clock.thread_seconds() == {}
    assert clock.makespan() == 0.0


def test_virtualclock_concurrent_sleepers_accounted_per_thread():
    clock = VirtualClock()
    barrier = threading.Barrier(4)

    def sleeper(dt):
        barrier.wait()
        for _ in range(10):
            clock.sleep(dt)

    threads = [threading.Thread(target=sleeper, args=(dt,))
               for dt in (0.1, 0.2, 0.3)]
    for t in threads:
        t.start()
    barrier.wait()
    for t in threads:
        t.join()
    per = sorted(clock.thread_seconds().values())
    assert per == pytest.approx([1.0, 2.0, 3.0])
    assert clock.makespan() == pytest.approx(3.0)   # busiest thread
    assert clock.now() == pytest.approx(6.0)        # global total


# ----------------------------------------------------------------------
# SimClock primitives
# ----------------------------------------------------------------------

def test_simclock_zero_and_negative_dt_are_noops():
    clock = SimClock(start=2.0)
    clock.sleep(0.0)
    clock.sleep(-0.5)
    assert clock.now() == 2.0
    assert clock.makespan() == 0.0
    assert clock.thread_seconds() == {}
    assert not clock.attached()


def test_simclock_transient_autoattach_single_thread():
    clock = SimClock()
    clock.sleep(1.5)                    # unattached: attach for the call
    clock.sleep(0.25)
    assert clock.now() == pytest.approx(1.75)
    assert clock.makespan() == pytest.approx(1.75)
    assert not clock.attached()         # transient actor is gone
    name = threading.current_thread().name
    assert clock.thread_seconds()[name] == pytest.approx(1.75)


def test_simclock_attach_nesting():
    clock = SimClock()
    clock.attach("me")
    clock.attach("me")                  # nested: counted, not duplicated
    assert clock.attached()
    clock.detach()
    assert clock.attached()             # still one level deep
    clock.detach()
    assert not clock.attached()
    clock.detach()                      # never-attached detach is a no-op


def test_simclock_concurrent_sleepers_overlap_on_one_timeline():
    """Two actors each sleeping 1s in parallel => makespan 1s (the sleeps
    overlap in virtual time), while busy time records 1s apiece."""
    clock = SimClock()
    clock.attach("driver")

    def worker():
        clock.attach("w")
        try:
            clock.sleep(1.0)
        finally:
            clock.detach()

    t = threading.Thread(target=worker)
    t.start()
    clock.wait_attached(2)
    clock.sleep(1.0)
    clock.block_begin()                 # off-timeline: let the join finish
    t.join()
    clock.block_end()
    clock.detach()
    assert clock.makespan() == pytest.approx(1.0)
    busy = clock.thread_seconds()
    assert busy["driver"] == pytest.approx(1.0)
    assert busy["w"] == pytest.approx(1.0)


def test_simclock_virtual_time_jumps_to_earliest_deadline():
    """Sleepers wake in deadline order regardless of start order."""
    clock = SimClock()
    clock.attach("driver")
    order = []

    def sleeper(name, dt):
        clock.attach(name)
        try:
            clock.sleep(dt)
            order.append((name, clock.now()))
        finally:
            clock.detach()

    threads = [threading.Thread(target=sleeper, args=(f"s{i}", dt))
               for i, dt in enumerate((0.3, 0.1, 0.2))]
    for t in threads:
        t.start()
    clock.wait_attached(4)
    clock.block_begin()
    for t in threads:
        t.join()
    clock.block_end()
    clock.detach()
    assert order == [("s1", pytest.approx(0.1)),
                     ("s2", pytest.approx(0.2)),
                     ("s0", pytest.approx(0.3))]
    assert clock.makespan() == pytest.approx(0.3)


def test_simclock_wait_event_blocks_until_token_holder_sets():
    clock = SimClock()
    clock.attach("driver")
    ev = threading.Event()
    seen = []

    def setter():
        clock.attach("setter")
        try:
            clock.sleep(0.5)
            ev.set()
            clock.wake(ev)              # paired with set(): token order
        finally:
            clock.detach()

    t = threading.Thread(target=setter)
    t.start()
    clock.wait_attached(2)
    clock.wait_event(ev)                # yields; time advances to 0.5
    seen.append(clock.now())
    clock.block_begin()
    t.join()
    clock.block_end()
    clock.detach()
    assert seen == [pytest.approx(0.5)]


def test_simclock_wait_event_already_set_returns_immediately():
    clock = SimClock()
    ev = threading.Event()
    ev.set()
    clock.wait_event(ev)                # unattached + set: plain return
    assert clock.now() == 0.0


def test_simclock_wake_is_fifo_per_channel():
    """wake(channel, n) releases the n *oldest* blockers of that channel
    and leaves other channels' blockers alone."""
    clock = SimClock()
    clock.attach("driver")
    chan_a, chan_b = object(), object()
    cv = threading.Condition()
    released = []

    def blocker(name, chan):
        clock.attach(name)
        try:
            with cv:
                clock.block_begin(chan)
                cv.wait()
            clock.block_end()
            released.append(name)
            clock.sleep(0.01)
        finally:
            clock.detach()

    specs = [("b0", chan_a), ("b1", chan_a), ("b2", chan_b)]
    threads = []
    for name, chan in specs:
        t = threading.Thread(target=blocker, args=(name, chan))
        t.start()
        threads.append(t)
        clock.wait_attached(1 + len(threads))
        # let the blocker reach its block_begin before starting the next,
        # so bseq order is b0 < b1 < b2
        while True:
            clock.sleep(0.001)
            with clock._cv:
                blocked = sum(1 for a in clock._actors.values()
                              if a.channel is not None)
            if blocked == len(threads):
                break
    assert clock.wake(chan_a, 1) == 1   # only the oldest chan_a blocker
    with cv:
        cv.notify(1)                    # paired real wakeup: FIFO == bseq
    clock.sleep(0.01)
    assert clock.wake(None, 1) == 0     # nobody blocks on channel None
    assert clock.wake(chan_a) == 1      # the remaining chan_a blocker
    assert clock.wake(chan_b) == 1
    with cv:
        cv.notify_all()                 # both remaining waiters are READY
    clock.block_begin()
    for t in threads:
        t.join()
    clock.block_end()
    clock.detach()
    assert released[0] == "b0"          # FIFO: oldest blocker first
    assert sorted(released) == ["b0", "b1", "b2"]


# ----------------------------------------------------------------------
# engine integration: park/steal charges + determinism
# ----------------------------------------------------------------------

def _run_engine(wake_latency_s, steal_probe_s, workers=4, n=40):
    clock = SimClock(wake_latency_s=wake_latency_s,
                     steal_probe_s=steal_probe_s)
    remote = LatencyBackend(
        InMemoryBackend(),
        LatencyModel(meta_ms=1.0, data_ms=1.0, jitter_sigma=0.0, seed=2),
        clock=clock)
    fs = CannyFS(remote, max_inflight=1000, workers=workers, fusion=False)
    fs.mkdir("d")
    for i in range(n):
        fs.write_file(f"d/f{i:02d}", b"payload")
    fs.close()
    return clock, fs.stats


def test_simclock_park_and_steal_charges_extend_busy_time():
    base_clock, base_stats = _run_engine(0.0, 0.0)
    cost_clock, cost_stats = _run_engine(1e-3, 1e-4)
    assert cost_stats.parks + cost_stats.steals > 0
    base_busy = sum(base_clock.thread_seconds().values())
    cost_busy = sum(cost_clock.thread_seconds().values())
    # the park handoffs / steal probes are charged on the timeline: the
    # modelled-cost run pays strictly more total virtual busy time
    assert cost_busy > base_busy
    extra = cost_busy - base_busy
    floor = cost_stats.parks * 1e-3
    assert extra >= floor or cost_stats.parks == 0


def test_simclock_engine_schedule_is_deterministic():
    runs = []
    for _ in range(2):
        clock, stats = _run_engine(1e-6, 1e-7)
        runs.append((clock.makespan(),
                     sorted(clock.thread_seconds().items()),
                     stats.steals, stats.parks, stats.executed))
    assert runs[0] == runs[1]
