"""PR 10 property tests: tenant-scoped blast-radius isolation.

The core equivalence: a tenant interleaved with N-1 neighbours on ONE
shared engine must be indistinguishable — final backend state under its
prefix, every read-class answer, its ledger signature — from the same op
stream run SOLO on a private engine.  Checked clean, under knob sweeps
(fusion/overlay/prefetch/readahead off), and under deterministic fault
plans confined to one tenant's prefix.

Plus the mechanism units: namespace confinement (PermissionError outside
the prefix, ancestors allowed only for scaffolding kinds), synchronous
TenantQuota EDQUOT/ENOSPC with rollback refunds, tenant-scoped poison
under ``abort_on_error``, weighted fair dispatch bias, saturation
admission without deadlock, prefix-scoped overlay clears, and the
kill -> resume -> rollback convergence chain on a live shared engine.

A seeded ``random.Random`` drives the streams (hypothesis is optional in
this environment and not required here): same seed, same stream.
"""
import errno
import random
import threading

import pytest

from repro.core import (CannyFS, EnginePoisonedError, FaultInjectingBackend,
                        FaultPlan, FaultRule, InMemoryBackend, LatencyBackend,
                        LatencyModel, NamespaceOverlay, ProcessKilled,
                        SimClock, TenantQuota, VirtualClock, run_transaction)

from benchmarks.workloads import run_tenant_jobs, tenant_state_digest

N_TENANTS = 3


def _prefix(i):
    return f"t{i}"


def _gen_stream(seed: int, prefix: str, n_ops: int = 60):
    """One tenant's deterministic op stream (single-writer model inside
    its own prefix): mixed mutations and read-class observations."""
    rng = random.Random(seed)
    dirs = [f"{prefix}/d{i}" for i in range(3)]
    files = [f"{d}/f{j}" for d in dirs for j in range(4)]
    ops = [("makedirs", d, None) for d in dirs]
    live = set()
    for k in range(n_ops):
        kind = rng.choice(("write", "write", "write", "read", "stat",
                           "readdir", "unlink", "rename", "chmod"))
        if kind == "write":
            p = rng.choice(files)
            ops.append(("write", p, bytes([rng.randrange(256)]) * rng.randrange(1, 64)))
            live.add(p)
        elif kind == "read" and live:
            ops.append(("read", rng.choice(sorted(live)), None))
        elif kind == "stat" and live:
            ops.append(("stat", rng.choice(sorted(live)), None))
        elif kind == "readdir":
            ops.append(("readdir", rng.choice(dirs), None))
        elif kind == "unlink" and live:
            p = rng.choice(sorted(live))
            ops.append(("unlink", p, None))
            live.discard(p)
        elif kind == "rename" and live:
            src = rng.choice(sorted(live))
            dst = rng.choice(files)
            if dst not in live and dst != src:
                ops.append(("rename", src, dst))
                live.discard(src)
                live.add(dst)
        elif kind == "chmod" and live:
            ops.append(("chmod", rng.choice(sorted(live)), 0o640))
    return ops


def _ledger_signature(fs, name):
    return sorted((e.kind, e.paths, type(e.error).__name__)
                  for e in fs.ledger.entries_for_tenant(name))


def _stack(plan=None, kill_scope=None, **fs_kw):
    inner = InMemoryBackend()
    backend = LatencyBackend(
        inner, LatencyModel(meta_ms=0.2, data_ms=0.2, jitter_sigma=0.0,
                            seed=3), clock=VirtualClock())
    if plan is not None:
        backend = FaultInjectingBackend(backend, plan,
                                        kill_scope=kill_scope)
    fs = CannyFS(backend, max_inflight=2000, workers=8, echo_errors=False,
                 **fs_kw)
    return fs, inner


def _apply_collect(view, ops):
    observed = []
    gen = _apply_obs(view, ops, observed)
    for _ in gen:
        pass
    return observed


def _apply_obs(view, ops, observed):
    """_apply with an external observations sink (shared by the
    interleaved and solo drivers so the comparison is literal)."""
    for step, obs in _apply_with_obs(view, ops):
        if obs is not None:
            observed.append(obs)
        yield


def _apply_with_obs(view, ops):
    for kind, a, b in ops:
        obs = None
        if kind == "makedirs":
            view.makedirs(a)
        elif kind == "write":
            view.write_file(a, b)
        elif kind == "read":
            try:
                obs = ("read", a, view.read_file(a))
            except OSError as e:
                obs = ("read", a, e.errno)
        elif kind == "stat":
            try:
                st = view.stat(a)
                obs = ("stat", a, st.size, st.is_dir)
            except OSError as e:
                obs = ("stat", a, e.errno)
        elif kind == "readdir":
            try:
                obs = ("readdir", a, tuple(sorted(view.readdir(a))))
            except OSError as e:
                obs = ("readdir", a, e.errno)
        elif kind == "unlink":
            try:
                view.unlink(a)
            except OSError:
                pass
        elif kind == "rename":
            try:
                view.rename(a, b)
            except OSError:
                pass
        elif kind == "chmod":
            view.chmod(a, b)
        yield None, obs


KNOB_SWEEP = [
    {},
    {"fusion": False},
    {"overlay": False},
    {"prefetch": False, "readahead": False},
]


@pytest.mark.parametrize("seed", [0, 7])
@pytest.mark.parametrize("fs_kw", KNOB_SWEEP,
                         ids=["default", "nofusion", "nooverlay", "nospec"])
def test_interleaved_matches_solo_clean(seed, fs_kw):
    """Round-robin interleaving N tenants on one engine leaves every
    tenant's prefix state, read answers, and (empty) ledger identical to
    its solo run — across optimizer knob settings."""
    fs, inner = _stack(**fs_kw)
    tenants = [fs.tenant(_prefix(i), _prefix(i)) for i in range(N_TENANTS)]
    observed = [[] for _ in range(N_TENANTS)]
    gens = [_apply_obs(tenants[i], _gen_stream(seed + i, _prefix(i)),
                       observed[i])
            for i in range(N_TENANTS)]
    live = list(range(N_TENANTS))
    while live:
        for i in list(live):
            try:
                next(gens[i])
            except StopIteration:
                live.remove(i)
    fs.close()
    shared_sigs = [_ledger_signature(fs, _prefix(i))
                   for i in range(N_TENANTS)]
    shared_digests = [tenant_state_digest(inner, _prefix(i))
                      for i in range(N_TENANTS)]
    for i in range(N_TENANTS):
        sfs, sinner = _stack(**fs_kw)
        st = sfs.tenant(_prefix(i), _prefix(i))
        solo_obs = _apply_collect(st, _gen_stream(seed + i, _prefix(i)))
        sfs.close()
        assert shared_sigs[i] == _ledger_signature(sfs, _prefix(i)) == []
        assert shared_digests[i] == tenant_state_digest(sinner, _prefix(i))
        assert observed[i] == solo_obs


def test_interleaved_matches_solo_under_confined_faults():
    """A deterministic fault plan confined to t0's prefix: t0's ledger
    signature matches its own solo run under the SAME plan; neighbours
    match clean solos with empty ledgers."""
    def plan():
        # path-targeted, probability 1.0, no count windows: the matched
        # set is a pure function of the stream, immune to interleaving
        return FaultPlan([FaultRule(error="EIO", ops=("write",),
                                    path_glob="t0/d1/*",
                                    probability=1.0)], seed=5)

    fs, inner = _stack(plan=plan())
    tenants = [fs.tenant(_prefix(i), _prefix(i)) for i in range(N_TENANTS)]
    observed = [[] for _ in range(N_TENANTS)]
    gens = [_apply_obs(tenants[i], _gen_stream(20 + i, _prefix(i)),
                       observed[i])
            for i in range(N_TENANTS)]
    live = list(range(N_TENANTS))
    while live:
        for i in list(live):
            try:
                next(gens[i])
            except StopIteration:
                live.remove(i)
    fs.close()
    t0_sig = _ledger_signature(fs, "t0")
    assert t0_sig, "the confined plan must actually fire"
    t0_digest = tenant_state_digest(inner, "t0")
    # t0 vs solo under the same storm
    sfs, sinner = _stack(plan=plan())
    st = sfs.tenant("t0", "t0")
    _apply_collect(st, _gen_stream(20, "t0"))
    sfs.close()
    assert t0_sig == _ledger_signature(sfs, "t0")
    assert t0_digest == tenant_state_digest(sinner, "t0")
    # neighbours vs clean solos
    for i in range(1, N_TENANTS):
        assert _ledger_signature(fs, _prefix(i)) == []
        nfs, ninner = _stack()
        nt = nfs.tenant(_prefix(i), _prefix(i))
        solo_obs = _apply_collect(nt, _gen_stream(20 + i, _prefix(i)))
        nfs.close()
        assert (tenant_state_digest(inner, _prefix(i))
                == tenant_state_digest(ninner, _prefix(i)))
        assert observed[i] == solo_obs


def test_confinement_outside_prefix_is_eacces():
    fs, _ = _stack()
    t = fs.tenant("a", "ta")
    t.makedirs("ta/x")
    t.write_file("ta/x/f", b"ok")
    for call in (lambda: t.write_file("tb/f", b"no"),
                 lambda: t.mkdir("tb"),
                 lambda: t.unlink("tb/f"),
                 lambda: t.rename("ta/x/f", "tb/f"),
                 lambda: t.rename("tb/f", "ta/x/f"),
                 lambda: t.read_file("tb/f"),
                 lambda: t.rmtree("tb")):
        with pytest.raises(PermissionError):
            call()
    # ancestors: stat/readdir observation is allowed (scaffolding view),
    # mutation is not
    assert t.stat("").is_dir
    assert "ta" in t.readdir("")
    fs.close()


def test_quota_bytes_inodes_and_rollback_refund():
    fs, _ = _stack()
    q = TenantQuota(budget_bytes=1024, max_inodes=8)
    t = fs.tenant("q", "tq", quota=q)
    t.makedirs("tq/d")
    t.write_file("tq/d/a", b"x" * 512)
    t.write_file("tq/d/b", b"y" * 512)   # exactly at budget
    with pytest.raises(OSError) as ei:
        t.write_file("tq/d/c", b"z")
    assert ei.value.errno == errno.EDQUOT
    # idempotent high-water: rewriting a SMALLER payload charges nothing
    t.write_file("tq/d/a", b"x" * 100)
    # release on unlink opens headroom
    t.unlink("tq/d/b")
    t.write_file("tq/d/c", b"z" * 256)
    t.drain()
    u = q.usage()
    assert u["bytes_used"] <= 1024 and u["edquot_count"] == 1
    # inode budget (dir + files): fill to the cap, then ENOSPC
    for i in range(8 - q.inodes_used()):
        t.write_file(f"tq/d/i{i}", b".")
    with pytest.raises(OSError) as ei:
        t.write_file("tq/d/overflow", b".")
    assert ei.value.errno == errno.ENOSPC
    # rollback refunds the window's creations
    used_before = q.usage()["bytes_used"]
    inodes_before = q.inodes_used()
    try:
        def body(v):
            v.write_file("tq/d/txn_f", b"w" * 64)
            raise RuntimeError("abort the window")
        run_transaction(t, body, name="refund", retries=0)
    except Exception:
        pass
    t.drain()
    assert q.usage()["bytes_used"] == used_before
    assert q.inodes_used() == inodes_before
    fs.close()


def test_tenant_scoped_poison_spares_neighbours():
    """abort_on_error + a fault confined to t0: t0's lane poisons and
    fails fast; t1 never notices; t0's rollback lifts only its own gate."""
    plan = FaultPlan([FaultRule(error="EIO", ops=("write",),
                                path_glob="t0/poison*", probability=1.0)],
                     seed=1)
    fs, inner = _stack(plan=plan, abort_on_error=True)
    t0 = fs.tenant("t0", "t0")
    t1 = fs.tenant("t1", "t1")
    t0.mkdir("t0")
    t1.mkdir("t1")
    t0.write_file("t0/poisoned", b"boom")
    fs.engine.barrier("t0/poisoned", tenant=t0._tenant_state)
    assert t0.poisoned
    with pytest.raises(EnginePoisonedError):
        t0.write_file("t0/after", b"rejected")
    # the neighbour's lane stays open throughout
    t1.write_file("t1/fine", b"ok")
    t1.drain()
    assert inner.snapshot()["files"]["t1/fine"] == b"ok"
    assert not t1.poisoned
    # recovery is tenant-scoped too
    t0._reset_poison()
    assert not t0.poisoned
    t0.write_file("t0/recovered", b"ok")
    fs.drain()
    assert inner.snapshot()["files"]["t0/recovered"] == b"ok"
    assert _ledger_signature(fs, "t1") == []
    fs.close()


def test_dwrr_weight_biases_makespan():
    """Equal jobs, weights 4:1 on a sim engine: the heavy tenant must
    not finish after the light one (deficit credit replenishes 4x
    faster), and both tenants spend credits through the DWRR lanes."""
    clock = SimClock()
    inner = InMemoryBackend()
    backend = LatencyBackend(
        inner, LatencyModel(meta_ms=1.0, data_ms=1.0, jitter_sigma=0.0,
                            server_slots=4, seed=2), clock=clock)
    fs = CannyFS(backend, max_inflight=64, workers=4, echo_errors=False)
    heavy = fs.tenant("heavy", "heavy", weight=4.0)
    light = fs.tenant("light", "light", weight=1.0)

    def job(t, prefix):
        t.mkdir(prefix)
        yield
        for i in range(60):
            t.write_file(f"{prefix}/f{i:03d}", b"x" * 256)
            yield

    outcomes = run_tenant_jobs([("heavy", job(heavy, "heavy")),
                                ("light", job(light, "light"))])
    fs.close()
    assert not any(outcomes.values())
    st = fs.stats
    assert st.tenants["heavy"].credits_spent > 0
    assert st.tenants["light"].credits_spent > 0
    assert (st.tenants["heavy"].last_complete_s
            <= st.tenants["light"].last_complete_s)


def test_saturation_admission_no_deadlock_two_threads():
    """Two tenants flooding a tiny in-flight budget from real threads:
    per-tenant backpressure must never mutually deadlock, every op must
    land, and both tenants' books must balance."""
    inner = InMemoryBackend()
    backend = LatencyBackend(
        inner, LatencyModel(meta_ms=0.05, data_ms=0.05, jitter_sigma=0.0,
                            seed=4), clock=VirtualClock())
    fs = CannyFS(backend, max_inflight=8, workers=4, echo_errors=False)
    tenants = [fs.tenant(_prefix(i), _prefix(i)) for i in range(2)]
    n_files = 120
    errs = []

    def flood(i):
        try:
            t = tenants[i]
            t.mkdir(_prefix(i))
            for k in range(n_files):
                t.write_file(f"{_prefix(i)}/f{k:03d}", b"z" * 64)
        except Exception as e:            # noqa: BLE001
            errs.append((i, e))

    threads = [threading.Thread(target=flood, args=(i,)) for i in range(2)]
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=60)
        assert not th.is_alive(), "tenant backpressure deadlocked"
    fs.close()
    assert errs == []
    for i in range(2):
        assert len([p for p in inner.snapshot()["files"]
                    if p.startswith(_prefix(i) + "/")]) == n_files
        assert _ledger_signature(fs, _prefix(i)) == []


def test_overlay_clear_under_is_prefix_scoped():
    ov = NamespaceOverlay()
    for p in ("a", "a/x", "b", "b/y"):
        ov.on_op("mkdir", (p,))
        ov.promote(p)
    ov.on_op("create", ("a/x/f",))
    ov.on_op("create", ("b/y/g",))
    ov.clear_under("a")
    # b's claims survive; a's are gone (fall back to the backend)
    assert ov.lookup("b/y/g") is not None
    assert ov.lookup("a/x/f") is None
    ov.clear_under("")   # empty prefix == full clear
    assert ov.lookup("b/y/g") is None


def test_kill_resume_rollback_converges_on_live_engine():
    """The PR 10 chain: a scoped kill preempts t0 mid-window, the tenant
    resumes from its own spill on the LIVE shared engine, a later
    rollback must invalidate the spill's durable claims (regression for
    the rollback-reads-global-spill bug), and the retried window
    converges to the solo reference while t1 stays byte-identical."""
    files = [f"t0/d/f{i:02d}" for i in range(12)]

    def body(v):
        v.makedirs("t0/d")
        for k, p in enumerate(files):
            v.write_file(p, bytes([65 + k]) * 32)
            v.chmod(p, 0o644)

    # solo reference
    sfs, sinner = _stack()
    st = sfs.tenant("t0", "t0")
    run_transaction(st, body, name="solo", retries=0)
    sfs.close()
    solo_digest = tenant_state_digest(sinner, "t0")

    # storm: kill after 8 matched calls, then one EIO to force a
    # post-resume rollback
    plan = FaultPlan([
        FaultRule(outcome="kill", path_glob="t0/*", probability=1.0,
                  after_count=8, max_failures=1),
        FaultRule(error="EIO", ops=("write",), path_glob="t0/d/f05*",
                  probability=1.0, after_count=1, max_failures=1),
    ], seed=9)
    fs, inner = _stack(plan=plan, kill_scope="t0/*")
    t0 = fs.tenant("t0", "t0")
    t1 = fs.tenant("t1", "t1")
    t0.enable_spill(".spill-t0")
    t1.mkdir("t1")
    t1.write_file("t1/neighbour", b"untouched")
    backend = fs.backend
    kills = 0
    while True:
        try:
            run_transaction(t0, body, name="t0", retries=4)
            break
        except ProcessKilled:
            kills += 1
            assert kills <= 3, "kill->resume loop failed to converge"
            backend.revive()
            rep = t0.resume(".spill-t0")
            assert rep["resumable"]
    fs.drain()
    fs.close()
    assert kills >= 1, "the scoped kill must actually fire"
    assert fs.stats.tenants["t0"].resumes == kills
    assert tenant_state_digest(inner, "t0") == solo_digest
    assert inner.snapshot()["files"]["t1/neighbour"] == b"untouched"
    assert _ledger_signature(fs, "t1") == []
