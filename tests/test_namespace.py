"""Namespace-overlay tests: overlay reads (readdir/stat/exists answered
from pending state without sealing), the cross-path bulk-remove fusion
pass, its fault/region semantics, and the overlay lifecycle.

Determinism technique (as in test_fusion): a ``GateBackend`` wedges the
engine's single worker on a sentinel op so every subsequently submitted
op stays *pending* until released — overlay answers and peephole
decisions become exact, not race-dependent.  ``Boundary`` counts calls at
the engine↔backend boundary only (a delegating wrapper, not a subclass,
so the InMemory default remove_tree/readdir_plus loops' *internal* calls
are not counted)."""
import errno
import threading
from collections import Counter

import pytest

from repro.core import (CannyFS, EagerFlags, FaultInjectingBackend,
                        FaultPlan, FaultRule, FusionPolicy, InMemoryBackend,
                        LatencyBackend, LatencyModel, OverlayPolicy,
                        Transaction, TransactionFailedError, VirtualClock,
                        run_transaction)

GATE = "gate_sentinel"

BOUNDARY_OPS = frozenset({
    "mkdir", "rmdir", "create", "unlink", "rename", "symlink", "link",
    "readlink", "write_at", "write_vec", "read_at", "truncate", "fallocate",
    "fsync", "chmod", "chown", "utimens", "setxattr", "removexattr", "stat",
    "readdir", "readdir_plus", "remove_tree",
})


class Boundary:
    """Counts ops the *engine* issues; inner-loop calls stay invisible."""

    def __init__(self, inner):
        self.inner = inner
        self.counts = Counter()

    def __getattr__(self, name):
        attr = getattr(self.inner, name)
        if name in BOUNDARY_OPS:
            def wrap(*a, **k):
                self.counts[name] += 1
                return attr(*a, **k)
            return wrap
        return attr


class GateBackend(InMemoryBackend):
    def __init__(self):
        super().__init__()
        self.gate = threading.Event()
        self.entered = threading.Event()   # the worker reached the gate

    def fsync(self, path):
        if path == GATE:
            self.entered.set()
            self.gate.wait()


def gated_fs(**kw):
    be = GateBackend()
    fs = CannyFS(be, workers=1, echo_errors=False, **kw)
    fs.create(GATE)
    fs.drain()
    fs.fsync(GATE)        # wedges the single worker until be.gate.set()
    be.entered.wait()     # worker provably wedged: later submissions pend
    return be, fs


def release(be, fs):
    be.gate.set()
    fs.drain()


def prepopulate(backend, n_dirs=3, files_per_dir=4, root="pre"):
    """A tree the mount has never observed (directly on the backend)."""
    dirs = [root] + [f"{root}/d{i}" for i in range(n_dirs - 1)]
    entries = 0
    for d in dirs:
        backend.mkdir(d)
        entries += 1
    for d in dirs:
        for j in range(files_per_dir):
            backend.create(f"{d}/f{j}")
            entries += 1
    return dirs, entries


# ---------------------------------------------------------------------------
# overlay reads: readdir / stat from pending state, no seal, no backend
# ---------------------------------------------------------------------------

def test_readdir_of_in_window_tree_answers_from_overlay():
    """A directory created through the mount is overlay-complete: readdir
    answers from pending state while every op underneath is still queued —
    the worker is wedged, so a backend readdir would deadlock."""
    be, fs = gated_fs()
    fs.mkdir("d")
    fs.write_file("d/a", b"1")
    fs.write_file("d/b", b"2")
    fs.mkdir("d/sub")
    assert fs.readdir("d") == ["a", "b", "sub"]   # would deadlock if sync
    st = fs.stats
    assert st.overlay_readdirs == 1
    assert st.overlay_seals_avoided == 1          # pending ops underneath
    release(be, fs)
    assert fs.readdir("d") == ["a", "b", "sub"]   # still overlay (quiescent)
    assert fs.stats.overlay_readdirs == 2
    assert fs.stats.overlay_seals_avoided == 1    # nothing pending now
    fs.close()


def test_readdir_does_not_seal_chains_elision_still_fires():
    """The tentpole semantics: a readdir answered by the overlay leaves
    the chains beneath it rewritable — the subsequent unlinks still elide
    the whole create+write chains (before PR 3 the readdir sealed them)."""
    be, fs = gated_fs()
    fs.mkdir("t")
    for i in range(4):
        fs.write_file(f"t/f{i}", b"x" * 32)
    names = fs.readdir("t")                       # observation, per-answer
    for name in names:
        fs.unlink(f"t/{name}")
    assert fs.stats.elided_ops >= 8               # create+write per file
    assert fs.stats.bytes_elided == 4 * 32
    release(be, fs)
    assert be.snapshot()["files"] == {GATE: b""}
    assert len(fs.ledger) == 0
    fs.close()


def test_readdir_miss_hits_backend_once_then_overlay():
    inner = InMemoryBackend()
    prepopulate(inner, n_dirs=1, files_per_dir=3)
    be = Boundary(inner)
    fs = CannyFS(be, echo_errors=False)
    assert fs.readdir("pre") == ["f0", "f1", "f2"]
    assert be.counts["readdir_plus"] == 1         # the miss: one fused call
    assert fs.readdir("pre") == ["f0", "f1", "f2"]
    assert be.counts["readdir_plus"] == 1         # the hit: overlay
    assert fs.stats.overlay_readdirs == 1
    # the listing warmed the stat cache: per-entry stats cost no backend op
    assert fs.stat("pre/f1").exists
    assert be.counts["stat"] == 0
    assert fs.stats.prefetched_stats == 3
    fs.close()


def test_stat_negative_answer_from_complete_parent():
    """A complete directory proves absence: stat of a missing name under
    it needs no backend roundtrip (the overlay's negative answer)."""
    inner = InMemoryBackend()
    be = Boundary(inner)
    fs = CannyFS(be, echo_errors=False)
    fs.mkdir("d")
    st = fs.stat("d/never_created")
    assert not st.exists and st.mocked
    assert be.counts["stat"] == 0
    assert not fs.exists("d/never_created")
    fs.close()


def test_overlay_disabled_preserves_pre_overlay_behaviour():
    inner = InMemoryBackend()
    prepopulate(inner, n_dirs=1, files_per_dir=2)
    be = Boundary(inner)
    fs = CannyFS(be, echo_errors=False, overlay=False)
    assert fs.readdir("pre") == ["f0", "f1"]
    assert fs.readdir("pre") == ["f0", "f1"]
    assert be.counts["readdir"] == 2              # every readdir is sync
    assert be.counts["readdir_plus"] == 0
    assert fs.stats.overlay_readdirs == 0
    fs.drain()
    assert fs.stats.prefetched_stats == 2         # legacy advisory prefetch
    fs.close()


def test_all_off_flags_disable_overlay():
    fs = CannyFS(InMemoryBackend(), flags=EagerFlags.all_off(),
                 echo_errors=False, workers=2)
    assert fs.engine.overlay is None
    fs.close()


def test_makedirs_over_preexisting_dir_demotes_completeness():
    """A tolerant mkdir that lands on a pre-existing directory must not
    leave the overlay claiming the dir is (complete and) empty."""
    inner = InMemoryBackend()
    inner.mkdir("pre")
    inner.create("pre/old")
    fs = CannyFS(inner, echo_errors=False)
    fs.makedirs("pre")
    fs.drain()                     # the demote lands at execution
    assert fs.readdir("pre") == ["old"]
    fs.close()


def test_rename_directory_carries_overlay_state():
    be, fs = gated_fs()
    fs.mkdir("d")
    fs.write_file("d/f", b"1")
    fs.rename("d", "e")
    assert fs.readdir("e") == ["f"]               # state moved key-for-key
    assert fs.stat("d").exists is False
    release(be, fs)
    snap = be.snapshot()
    assert "e" in snap["dirs"] and snap["files"]["e/f"] == b"1"
    fs.close()


def test_rename_waits_for_deep_pending_write_chains():
    """Review-caught regression: a rename must order after pending write
    chains arbitrarily deep under it (s/a/f under pending mkdir s/a),
    not just after its direct structural children — else the rename wins
    the race, the deep create fails ENOENT at the old path, and the data
    never lands.  Hammered across a pool, where dispatch order is
    genuinely concurrent."""
    for trial in range(30):
        be = InMemoryBackend()
        fs = CannyFS(be, workers=8, echo_errors=False)
        fs.makedirs(f"s{trial}/a")
        fs.write_file(f"s{trial}/a/f", b"deep")
        fs.rename(f"s{trial}", f"t{trial}")
        fs.drain()
        snap = be.snapshot()
        assert snap["files"].get(f"t{trial}/a/f") == b"deep", \
            (trial, sorted(snap["files"]), fs.ledger.entries())
        assert len(fs.ledger) == 0, fs.ledger.entries()
        fs.close()


def test_failed_op_invalidates_overlay_claims():
    """A deferred failure drops the overlay's membership claims so the
    next read consults the backend instead of repeating the lie."""
    class Bad(InMemoryBackend):
        def __init__(self):
            super().__init__()
            self.release = threading.Event()

        def create(self, p):
            if p.endswith("boom"):
                self.release.wait()   # hold the failure until observed
                raise OSError(errno.EACCES, "injected", p)
            super().create(p)

    be = Bad()
    fs = CannyFS(be, echo_errors=False)
    fs.mkdir("d")
    fs.create("d/ok")
    fs.create("d/boom")
    assert "boom" in fs.readdir("d")              # intended effect, pre-exec
    be.release.set()
    fs.drain()                                    # failure lands
    assert fs.readdir("d") == ["ok"]              # re-listed from backend
    assert len(fs.ledger) == 1
    fs.close()


# ---------------------------------------------------------------------------
# cross-path bulk-remove fusion
# ---------------------------------------------------------------------------

def test_bulk_remove_collapses_preexisting_tree_fewer_ops_than_entries():
    """The acceptance criterion: readdir-driven rmtree of a tree the
    engine has never seen performs fewer backend ops than entries
    removed — listings are fused readdir_plus calls, per-entry stats hit
    the warmed cache, and the removals collapse to remove_tree."""
    inner = InMemoryBackend()
    dirs, entries = prepopulate(inner, n_dirs=4, files_per_dir=6)

    # slow *removals only* (real sleep) behind a 2-worker pool: listings
    # and stats stay fast so the walk races ahead, while at most two
    # claimed removals can execute per claim window — the rest reliably
    # outlive the walk and stay elidable.  (An instant backend lets the
    # eager unlinks race the rmdir out of the optimization window —
    # executed/claimed ops can't be elided — same reasoning as
    # benchmarks.paper_tables.fusion_table.)
    class SlowRemovals:
        def __init__(self, inner, delay_s=0.05):
            self.inner = inner
            self.delay_s = delay_s

        def __getattr__(self, name):
            return getattr(self.inner, name)

        def _slow(self, call, *a):
            import time
            time.sleep(self.delay_s)
            return call(*a)

        def unlink(self, p): return self._slow(self.inner.unlink, p)
        def rmdir(self, p): return self._slow(self.inner.rmdir, p)
        def remove_tree(self, p):
            return self._slow(self.inner.remove_tree, p)

    be = Boundary(SlowRemovals(inner))
    fs = CannyFS(be, workers=2, echo_errors=False)
    fs.rmtree("pre")
    fs.drain()
    total_ops = sum(be.counts.values())
    assert total_ops < entries, (total_ops, entries, dict(be.counts))
    assert fs.stats.bulk_removes >= 1
    assert be.counts["remove_tree"] >= 1
    assert be.counts["unlink"] == 0 and be.counts["rmdir"] == 0
    snap = inner.snapshot()
    assert not [p for p in list(snap["files"]) + list(snap["dirs"])
                if p.startswith("pre")]
    assert len(fs.ledger) == 0
    fs.close()


def test_bulk_remove_rolls_up_to_single_fused_call_in_window():
    """Extract + readdir-driven rmtree inside one unobserved window:
    chains elide, leaf collapses are absorbed by their parents, and
    exactly ONE remove_tree reaches the backend.  The dirs are created
    (and drained) first, so the collapse needs no exec-time
    re-verification (the same-breath variant with still-provisional
    mkdirs is test_same_breath_extract_rmtree_promotes_and_fuses)."""
    gate_inner = GateBackend()
    be = Boundary(gate_inner)
    fs = CannyFS(be, workers=1, echo_errors=False)
    fs.create(GATE)
    fs.makedirs("t/u")
    fs.drain()                    # dirs backend-proven fresh: promoted
    be.counts.clear()
    fs.fsync(GATE)                # wedge: everything below stays pending
    gate_inner.entered.wait()
    for d in ("t", "t/u"):
        for i in range(3):
            fs.write_file(f"{d}/f{i}", b"z" * 16)
    fs.rmtree("t")                # readdir-driven, fully in-window
    gate_inner.gate.set()
    fs.drain()
    assert fs.stats.bulk_removes == 2             # leaf + rolled-up root
    assert be.counts["remove_tree"] == 1          # only the root executed
    assert be.counts["unlink"] == 0 and be.counts["rmdir"] == 0
    assert be.counts["readdir"] == 0              # all walks via overlay
    assert be.counts["readdir_plus"] == 0
    snap = gate_inner.snapshot()
    assert snap["files"] == {GATE: b""} and snap["dirs"] == {""}
    assert len(fs.ledger) == 0
    fs.close()


def test_provisional_mkdir_demotes_fused_remove_at_exec():
    """Exec-time re-verification (PR 4, ROADMAP m): a subtree resting on
    a still-pending (tolerant) mkdir now *fuses* — the fused op's DAG
    edges order it after the mkdir, and when the mkdir lands on a
    pre-existing directory (demoted), the fused removal falls back to the
    byte-identical per-entry path: pre-existing contents are preserved
    behind ENOTEMPTY exactly as an unfused execution would have left
    them."""
    inner = GateBackend()
    inner.mkdir("pre")            # pre-existing, never observed
    inner.create("pre/old")
    inner.write_at("pre/old", 0, b"precious")
    fs = CannyFS(inner, workers=1, echo_errors=False)
    fs.create(GATE)
    fs.drain()
    fs.fsync(GATE)                # wedge: the mkdir below stays pending
    inner.entered.wait()
    fs.makedirs("pre")            # tolerant mkdir over a pre-existing dir
    fs.write_file("pre/x", b"1")
    fs.unlink("pre/x")
    fs.rmdir("pre")               # provisional: fuses, re-verified at exec
    assert fs.stats.bulk_removes == 1
    inner.gate.set()
    fs.drain()
    # the mkdir demoted the overlay claim -> per-entry fallback: data
    # preserved, removal surfaced as ENOTEMPTY in the ledger
    assert fs.stats.bulk_reverify_demoted == 1
    assert fs.stats.bulk_reverify_promoted == 0
    assert inner.snapshot()["files"]["pre/old"] == b"precious"
    sig = [(e.kind, getattr(e.error, "errno", None))
           for e in fs.ledger.entries()]
    assert ("remove_tree", errno.ENOTEMPTY) in sig
    fs.close()


def test_demoted_fallback_still_removes_sibling_subtrees():
    """A demoted subdir's ENOTEMPTY must not abort the per-entry
    fallback: sibling subtrees the unfused rmdirs would have removed are
    still removed, the pre-existing data survives, and the failure
    surfaces on the root exactly as an unfused execution's would."""
    inner = GateBackend()
    inner.mkdir("root")           # pre-existing, never observed
    inner.mkdir("root/a")
    inner.create("root/a/old")
    inner.write_at("root/a/old", 0, b"precious")
    fs = CannyFS(inner, workers=1, echo_errors=False)
    fs.create(GATE)
    fs.drain()
    fs.fsync(GATE)                # wedge: every mkdir below stays pending
    inner.entered.wait()
    fs.makedirs("root")           # demoted at exec (pre-existing)
    fs.makedirs("root/a")         # demoted at exec (pre-existing)
    fs.makedirs("root/b")         # promoted at exec (created fresh)
    fs.write_file("root/b/f", b"1")
    fs.rmtree("root")             # fuses; demotion forces the fallback
    assert fs.stats.bulk_removes >= 1
    inner.gate.set()
    fs.drain()
    assert fs.stats.bulk_reverify_demoted == 1
    snap = inner.snapshot()
    # byte-identical to unfused: b removed, a's pre-existing data kept
    assert "root/b" not in snap["dirs"] and "root/b/f" not in snap["files"]
    assert snap["files"]["root/a/old"] == b"precious"
    assert "root" in snap["dirs"] and "root/a" in snap["dirs"]
    sig = [(e.kind, getattr(e.error, "errno", None))
           for e in fs.ledger.entries()]
    assert ("remove_tree", errno.ENOTEMPTY) in sig
    fs.close()


def test_reverify_policy_off_keeps_provisional_block():
    """FusionPolicy(reverify_provisional=False) restores the PR 3
    semantics: a provisional subtree refuses to fuse outright."""
    inner = GateBackend()
    inner.mkdir("pre")
    inner.create("pre/old")
    inner.write_at("pre/old", 0, b"precious")
    fs = CannyFS(inner, workers=1, echo_errors=False,
                 fusion=FusionPolicy(reverify_provisional=False))
    fs.create(GATE)
    fs.drain()
    fs.fsync(GATE)
    inner.entered.wait()
    fs.makedirs("pre")
    fs.write_file("pre/x", b"1")
    fs.unlink("pre/x")
    fs.rmdir("pre")               # provisional: must NOT fuse
    assert fs.stats.bulk_removes == 0
    inner.gate.set()
    fs.drain()
    assert inner.snapshot()["files"]["pre/old"] == b"precious"
    sig = [(e.kind, getattr(e.error, "errno", None))
           for e in fs.ledger.entries()]
    assert ("rmdir", errno.ENOTEMPTY) in sig
    fs.close()


def test_same_breath_extract_rmtree_promotes_and_fuses_to_one_call():
    """The paper's headline collapse, recovered (ROADMAP m): extract and
    readdir-driven rmtree issued in ONE breath — every mkdir still
    pending at fuse time — now roll up to a single remove_tree backend
    call.  The fused op executes after the mkdirs (DAG edges), each
    mkdir promotes its provisional claim, and the exec-time check
    confirms the overlay proof instead of refusing to fuse."""
    gate_inner = GateBackend()
    be = Boundary(gate_inner)
    fs = CannyFS(be, workers=1, echo_errors=False)
    fs.create(GATE)
    fs.drain()
    be.counts.clear()
    fs.fsync(GATE)                # wedge: mkdirs AND files stay pending
    gate_inner.entered.wait()
    fs.makedirs("t/u")            # same breath: no drain before rmtree
    for d in ("t", "t/u"):
        for i in range(3):
            fs.write_file(f"{d}/f{i}", b"z" * 16)
    fs.rmtree("t")                # readdir-driven, fully in-window
    assert fs.stats.bulk_removes >= 1
    gate_inner.gate.set()
    fs.drain()
    assert fs.stats.bulk_reverify_promoted >= 1
    assert fs.stats.bulk_reverify_demoted == 0
    assert be.counts["remove_tree"] == 1          # ONE fused call
    assert be.counts["unlink"] == 0 and be.counts["rmdir"] == 0
    assert be.counts["readdir"] == 0 and be.counts["readdir_plus"] == 0
    snap = gate_inner.snapshot()
    assert snap["files"] == {GATE: b""} and snap["dirs"] == {""}
    assert len(fs.ledger) == 0
    fs.close()


def test_stale_listing_cannot_resurrect_removed_dir():
    """The review-fix for the install race: a listing taken by a readdir
    in flight while a rmdir (or remove_tree) was admitted behind it must
    not re-install a complete overlay entry for the removed directory —
    the late ``install_listing`` is a no-op once the dir's parent delta
    marks it absent."""
    inner = InMemoryBackend()
    inner.mkdir("d")
    inner.create("d/f")
    fs = CannyFS(inner, echo_errors=False)
    assert fs.readdir("d") == ["f"]
    fs.unlink("d/f")
    fs.rmdir("d")                     # admit pops the dir's overlay state
    ov = fs.engine.overlay
    # the racing readdir's execution lands its (older) listing now
    ov.install_listing("d", [("f", None)])
    assert ov.readdir("d") is None    # not resurrected
    assert ov.lookup("d") is False
    fs.drain()
    with pytest.raises(FileNotFoundError):
        fs.readdir("d")               # backend truth: gone
    assert len(fs.ledger) == 0
    fs.close()


def test_rmdir_of_nonempty_dir_is_not_rewritten():
    """A present entry with no pending removal means the rmdir must fail
    ENOTEMPTY exactly as an unfused execution would — no collapse."""
    be = InMemoryBackend()
    fs = CannyFS(be, echo_errors=False)
    fs.mkdir("d")
    fs.write_file("d/keep", b"1")
    fs.rmdir("d")
    fs.drain()
    assert fs.stats.bulk_removes == 0
    sig = [(e.kind, getattr(e.error, "errno", None))
           for e in fs.ledger.entries()]
    assert sig == [("rmdir", errno.ENOTEMPTY)]
    assert be.snapshot()["files"]["d/keep"] == b"1"
    fs.close()


def test_bulk_remove_requires_overlay_known_subtree():
    """An unlisted pre-existing directory is not overlay-known: rmdir of
    it takes the plain path (and correctly fails while non-empty)."""
    inner = InMemoryBackend()
    inner.mkdir("pre")
    inner.create("pre/f")
    fs = CannyFS(inner, echo_errors=False)
    fs.unlink("pre/f")            # engine knows the unlink...
    fs.rmdir("pre")               # ...but never listed pre: no collapse
    fs.drain()
    assert fs.stats.bulk_removes == 0
    assert "pre" not in inner.snapshot()["dirs"]
    assert len(fs.ledger) == 0
    fs.close()


def test_bulk_remove_same_region_only():
    """Pending removals from another region are never elided: the fused
    call must not adopt work whose failure belongs to a different ledger
    scope.  The rmdir falls back to the plain per-entry path."""
    be, fs = gated_fs()
    fs.mkdir("t")
    fs.write_file("t/f", b"1")
    fs.unlink("t/f")              # region None, pending (gated)
    txn = Transaction(fs)
    txn.__enter__()
    fs.rmdir("t")                 # region txn: must not elide None-region ops
    assert fs.stats.bulk_removes == 0
    release(be, fs)
    txn.__exit__(None, None, None)
    assert "t" not in be.snapshot()["dirs"]
    assert len(fs.ledger) == 0
    fs.close()


def test_bulk_remove_fault_fires_per_fused_call_and_recovers():
    """One fused remove_tree of N collapsed removals is a single matching
    call for the fault plan; its failure invalidates every covered
    overlay claim, so the retried rmtree re-observes the backend and
    converges once the outage ends."""
    inner = InMemoryBackend()
    prepopulate(inner, n_dirs=2, files_per_dir=3)
    plan = FaultPlan([FaultRule(error="EIO", ops=("remove_tree",),
                                max_failures=1)])
    remote = LatencyBackend(
        inner, LatencyModel(meta_ms=1.0, data_ms=1.0, jitter_sigma=0.0))
    fs = CannyFS(FaultInjectingBackend(remote, plan), workers=2,
                 echo_errors=False)

    def body(fs):
        fs.rmtree("pre")

    run_transaction(fs, body, retries=3)
    fs.drain()
    assert plan.injected == 1
    assert fs.stats.retries >= 1
    snap = inner.snapshot()
    assert not [p for p in list(snap["files"]) + list(snap["dirs"])
                if p.startswith("pre")]
    fs.close()


def test_bulk_remove_respects_fusion_policy_off():
    inner = InMemoryBackend()
    prepopulate(inner, n_dirs=2, files_per_dir=2)
    be = Boundary(inner)
    fs = CannyFS(be, echo_errors=False,
                 fusion=FusionPolicy(bulk_remove=False))
    fs.rmtree("pre")
    fs.drain()
    assert fs.stats.bulk_removes == 0
    assert be.counts["remove_tree"] == 0
    assert be.counts["unlink"] == 4 and be.counts["rmdir"] == 2
    assert len(fs.ledger) == 0
    fs.close()


def test_quota_released_by_fused_remove_tree():
    """The Quota decorator's uncharge mirror of the fused call: bytes and
    inodes charged during extract are released by one remove_tree."""
    from repro.core import QuotaBackend
    q = QuotaBackend(
        LatencyBackend(InMemoryBackend(),
                       LatencyModel(meta_ms=1.0, data_ms=1.0,
                                    jitter_sigma=0.0)),
        budget_bytes=1 << 20, max_inodes=64)
    fs = CannyFS(q, workers=2, echo_errors=False)
    fs.makedirs("t")
    for i in range(4):
        fs.write_file(f"t/f{i}", b"q" * 100)
    fs.drain()
    assert q.used == 400 and q.inodes_used == 5
    fs.rmtree("t")
    fs.drain()
    assert fs.stats.bulk_removes >= 1
    assert q.used == 0 and q.inodes_used == 0
    assert len(fs.ledger) == 0
    fs.close()


# ---------------------------------------------------------------------------
# cached-listing LRU bound (OverlayPolicy.max_cached_listings)
# ---------------------------------------------------------------------------

def test_listing_lru_evicts_completeness_only():
    """Wide-namespace bound (ROADMAP l): with N cached listings allowed,
    the N+1th readdir miss evicts the least-recently-used listing —
    demoting that directory's completeness (its next readdir is a miss
    again) while keeping the pending membership delta intact."""
    inner = InMemoryBackend()
    n_dirs = 6
    for i in range(n_dirs):
        inner.mkdir(f"wide{i}")
        inner.create(f"wide{i}/base")
    be = Boundary(inner)
    from repro.core import OverlayPolicy
    fs = CannyFS(be, echo_errors=False,
                 overlay=OverlayPolicy(max_cached_listings=2))
    # a pending delta in wide0 that eviction must NOT drop
    fs.create("wide0/pending")
    for i in range(n_dirs):
        assert sorted(fs.readdir(f"wide{i}"))[-1:] in (["base"], ["pending"])
    assert be.counts["readdir_plus"] == n_dirs      # all misses, LRU churns
    # wide0's listing was evicted long ago: a re-list hits the backend,
    # but the in-window create is still merged into the answer
    assert fs.readdir("wide0") == ["base", "pending"]
    assert be.counts["readdir_plus"] == n_dirs + 1
    # pending membership survived eviction: lookup still proves presence
    assert fs.engine.overlay.lookup("wide0/pending") is True
    # the two most recent listings are still cached (overlay hits)
    before = be.counts["readdir_plus"]
    assert fs.readdir(f"wide{n_dirs - 1}") == ["base"]
    assert be.counts["readdir_plus"] == before
    fs.drain()
    assert len(fs.ledger) == 0
    fs.close()


def test_listing_lru_recency_on_hits():
    """Overlay readdir hits refresh LRU recency: the repeatedly-read
    listing survives while the cold one is evicted."""
    inner = InMemoryBackend()
    for name in ("hot", "cold", "third"):
        inner.mkdir(name)
    be = Boundary(inner)
    from repro.core import OverlayPolicy
    fs = CannyFS(be, echo_errors=False,
                 overlay=OverlayPolicy(max_cached_listings=2))
    fs.readdir("hot")             # miss -> cached
    fs.readdir("cold")            # miss -> cached (hot is now LRU)
    fs.readdir("hot")             # hit refreshes hot's recency
    fs.readdir("third")           # miss -> evicts cold, not hot
    n = be.counts["readdir_plus"]
    fs.readdir("hot")             # still cached
    assert be.counts["readdir_plus"] == n
    fs.readdir("cold")            # evicted: miss again
    assert be.counts["readdir_plus"] == n + 1
    fs.close()


# ---------------------------------------------------------------------------
# overlay-aware walk() fast path
# ---------------------------------------------------------------------------

def test_walk_served_from_overlay_without_sealing():
    """ROADMAP k: a walk over an in-window tree answers entirely from the
    overlay — no backend roundtrips, no seals — while the worker is
    wedged (a sync readdir or stat would deadlock)."""
    be, fs = gated_fs()
    fs.mkdir("w")
    fs.mkdir("w/sub")
    fs.write_file("w/a", b"1")
    fs.write_file("w/sub/b", b"2")
    seen = list(fs.walk("w"))     # would deadlock if any level went sync
    assert seen == [("w", ["sub"], ["a"]), ("w/sub", [], ["b"])]
    st = fs.stats
    assert st.overlay_readdirs == 2
    assert st.overlay_seals_avoided == 2
    # the chains under the walked tree stayed rewritable: unlinks elide
    fs.unlink("w/a")
    fs.unlink("w/sub/b")
    assert st.elided_ops >= 4
    release(be, fs)
    assert len(fs.ledger) == 0
    fs.close()


def test_walk_falls_back_per_directory_on_incomplete_dirs():
    """A never-listed pre-existing subdir forces the sync fallback for
    that directory only; overlay-known levels still fast-path.
    (prefetch=False: with the speculative prefetcher on, mix/old would
    be overlay-complete before the walk reaches it — that pipelined path
    has its own suite in test_prefetch.py; this test pins the fallback.)"""
    inner = InMemoryBackend()
    inner.mkdir("mix")
    inner.mkdir("mix/old")        # pre-existing, never observed
    inner.create("mix/old/f")
    be = Boundary(inner)
    fs = CannyFS(be, echo_errors=False, prefetch=False)
    assert fs.readdir("mix") == ["old"]   # miss: installs mix's listing
    fs.mkdir("mix/fresh")                 # in-window: overlay-complete
    walked = {d: (tuple(sub), tuple(files))
              for d, sub, files in fs.walk("mix")}
    assert walked == {"mix": (("fresh", "old"), ()),
                      "mix/fresh": ((), ()),
                      "mix/old": ((), ("f",))}
    # exactly one backend listing for the unknown dir; the known levels
    # (mix from its cached listing, fresh from its pending mkdir) hit
    assert be.counts["readdir_plus"] == 2          # mix + mix/old
    assert fs.stats.overlay_readdirs >= 2
    fs.drain()
    assert len(fs.ledger) == 0
    fs.close()


# ---------------------------------------------------------------------------
# overlay lifecycle: populated at submit, cleared on rollback/commit
# ---------------------------------------------------------------------------

def test_overlay_dropped_at_commit():
    inner = InMemoryBackend()
    be = Boundary(inner)
    fs = CannyFS(be, echo_errors=False)
    with Transaction(fs):
        fs.mkdir("out")
        fs.write_file("out/x", b"1")
        assert fs.readdir("out") == ["x"]         # overlay answer in-window
    assert fs.engine.overlay.readdir("out") is None   # delta dropped
    assert fs.readdir("out") == ["x"]             # re-listed from backend
    assert be.counts["readdir_plus"] == 1
    fs.close()


def test_overlay_cleared_on_rollback_and_retry_converges():
    """Rollback removes the region's outputs directly against the backend;
    the overlay must forget its claims or the retry would trust them."""
    calls = {"n": 0}

    class FlakyOnce(InMemoryBackend):
        def write_at(self, p, o, d):
            if p == "out/f1" and calls["n"] == 0:
                calls["n"] += 1
                raise OSError(errno.EIO, "transient", p)
            return super().write_at(p, o, d)

    be = FlakyOnce()
    fs = CannyFS(be, echo_errors=False)

    def body(fs):
        fs.makedirs("out")
        for i in range(3):
            fs.write_file(f"out/f{i}", b"v")
        assert sorted(fs.readdir("out")) == ["f0", "f1", "f2"]

    run_transaction(fs, body, retries=2)
    fs.close()
    snap = be.snapshot()
    assert sorted(p for p in snap["files"] if p.startswith("out/")) == \
        ["out/f0", "out/f1", "out/f2"]


# ---------------------------------------------------------------------------
# end-to-end: overlay keeps the removal benchmark inside the window
# ---------------------------------------------------------------------------

def test_readdir_driven_rmtree_beats_overlay_off_on_remote_backend():
    """The paper's removal benchmark, readdir-driven, against the latency
    model: overlay-on must issue strictly fewer remote roundtrips than
    both overlay-off and the number of entries removed."""
    def build(overlay):
        inner = InMemoryBackend()
        dirs, entries = prepopulate(inner, n_dirs=4, files_per_dir=8)
        # real (small) latency so pending removals outlive the walk; a
        # virtual clock sleeps in zero real time and would let the eager
        # unlinks race the rmdir out of the optimization window
        remote = LatencyBackend(
            inner, LatencyModel(meta_ms=1.0, data_ms=1.0, jitter_sigma=0.0))
        fs = CannyFS(remote, workers=2, echo_errors=False, overlay=overlay)
        fs.rmtree("pre")
        fs.close()
        snap = inner.snapshot()
        assert not [p for p in list(snap["files"]) + list(snap["dirs"])
                    if p.startswith("pre")]
        return entries, remote.op_count, fs.stats

    entries, ops_on, st_on = build(overlay=None)
    _, ops_off, st_off = build(overlay=False)
    assert st_on.bulk_removes >= 1 and st_off.bulk_removes == 0
    assert ops_on < entries
    assert ops_on < ops_off
