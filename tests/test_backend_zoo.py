"""PR 8: the backend zoo (ObjectStoreBackend / RemoteStreamBackend), the
CostModel protocol, the cost-gated rename-retarget rule, and the
cold-start-seeded BDP.

Covers the satellites:
* seeded EWMAs — a fresh ``LatencyBackend`` answers ``bdp_bytes`` /
  ``cost_hint`` from the model's nominal figures, so the very first
  fused write of a session is already BDP-sized (no cold-start window
  where the coalescer falls back to the fixed cap);
* ``list_by_prefix`` pagination edge cases — page boundary exactly at
  the page width, the empty final page, keys inserted/deleted between
  pages (S3 continuation semantics), and a racing admitted mutation
  cancelling an in-flight speculative listing;
* decorator composition — fault/quota layers delegate ``cost_hint``
  inward instead of letting the base class shadow ``__getattr__``;
* the retarget rule itself — fires on copy+delete media, never on
  native-rename media, and obeys the forced on/off policy.
"""
import threading

import pytest

from repro.core import (CannyFS, CostHint, FaultInjectingBackend, FaultPlan,
                        FaultRule, FusionPolicy, InMemoryBackend,
                        LatencyBackend, LatencyModel, ObjectStoreBackend,
                        ObjectStoreModel, QuotaBackend, RemoteStreamBackend,
                        RemoteStreamModel, SimClock, VirtualClock)

# ---------------------------------------------------------------------------
# satellite: cold-start BDP seeding
# ---------------------------------------------------------------------------

def _nfs(clock=None, **kw):
    return LatencyBackend(
        InMemoryBackend(),
        LatencyModel(meta_ms=40.0, data_ms=40.0, bandwidth_mb_s=110.0,
                     jitter_sigma=0.0, load=1.0, seed=0, **kw),
        clock=clock or VirtualClock())


def test_fresh_latency_backend_has_seeded_bdp():
    lb = _nfs()
    # nominal figures, zero ops observed: rtt = meta_ms, bw = model rate
    assert lb.bdp_bytes() == pytest.approx(0.040 * 110e6)
    hint = lb.cost_hint("write")
    assert hint is not None
    assert hint.bdp_bytes() == pytest.approx(0.040 * 110e6)


def test_first_cold_fused_write_is_already_bdp_sized():
    """Before any op completes, the fuser's write cap must be the seeded
    2x-BDP clamp, not the fixed max_bytes fallback — and the session's
    very first chunked file must coalesce into ONE vectored write."""
    lb = _nfs(clock=SimClock())
    fs = CannyFS(lb, workers=4, echo_errors=False)
    pol = FusionPolicy()
    expected = int(pol.bdp_multiplier * 0.040 * 110e6)   # 8.8 MB
    assert fs.engine._fuser.effective_max_bytes() == expected
    assert pol.min_adaptive_bytes <= expected < pol.max_bytes
    chunks = 32
    with fs.open("first.bin", "wb") as f:
        for i in range(chunks):
            f.write(bytes([i & 0xFF]) * 8192)
    fs.close()
    assert fs.stats.fused_writes == chunks - 1     # one write_vec total
    assert fs.stats.adaptive_max_bytes == expected
    assert len(fs.ledger) == 0


# ---------------------------------------------------------------------------
# cost hints across the zoo + decorator delegation
# ---------------------------------------------------------------------------

def test_object_store_rename_hint_is_copy_plus_delete():
    store = ObjectStoreBackend()
    rename, create = store.cost_hint("rename"), store.cost_hint("create")
    assert rename.rtt_s == pytest.approx(2 * store.model.rtt_s)
    assert rename.cost_s() >= 1.5 * create.cost_s()


def test_remote_stream_hint_is_uniform():
    remote = RemoteStreamBackend()
    assert remote.cost_hint("rename") == remote.cost_hint("create")


def test_base_backend_hint_is_none():
    assert InMemoryBackend().cost_hint("write") is None


def test_decorators_delegate_cost_hint_inward():
    store = ObjectStoreBackend()
    plan = FaultPlan([FaultRule(error="EIO", ops=("write",),
                                probability=0.0)], seed=0)
    for deco in (FaultInjectingBackend(store, plan),
                 QuotaBackend(store, budget_bytes=1 << 20),
                 QuotaBackend(FaultInjectingBackend(store, plan),
                              budget_bytes=1 << 20)):
        assert deco.cost_hint("rename") == store.cost_hint("rename")
        assert deco.cost_hint("write") == store.cost_hint("write")


def test_latency_decorator_prefers_inner_hint():
    """A shaper stacked over an object store reports the store's cost
    shape, not its own EWMAs — the hint reflects the bottom of the
    stack."""
    store = ObjectStoreBackend()
    lb = LatencyBackend(store, LatencyModel(jitter_sigma=0.0, seed=0),
                        clock=VirtualClock())
    assert lb.cost_hint("rename") == store.cost_hint("rename")


def test_cost_hint_math():
    h = CostHint(rtt_s=0.025, bytes_per_s=200e6,
                 per_request_overhead_s=0.002)
    assert h.cost_s(0) == pytest.approx(0.027)
    assert h.cost_s(200_000_000) == pytest.approx(1.027)
    assert h.bdp_bytes() == pytest.approx(0.027 * 200e6)


# ---------------------------------------------------------------------------
# satellite: list_by_prefix pagination edge cases
# ---------------------------------------------------------------------------

def _store_with_keys(n_files: int, page: int) -> ObjectStoreBackend:
    store = ObjectStoreBackend(model=ObjectStoreModel(list_page_size=page))
    store.inner.mkdir("p")
    for i in range(n_files):
        store.inner.create(f"p/f{i:02d}")
    return store


def _drain(store, prefix, page_size=None):
    keys, token, pages = [], None, 0
    while True:
        got, token = store.list_by_prefix(prefix, token,
                                          page_size=page_size)
        keys.extend(got)
        pages += 1
        if token is None:
            return keys, pages


def test_page_boundary_exactly_at_width_has_no_empty_tail_page():
    # 15 file keys + the "p/" marker = 16 keys = exactly two 8-key pages
    store = _store_with_keys(15, page=8)
    assert len(store._keys_under("p")) == 16
    keys, pages = _drain(store, "p")
    assert pages == 2 and len(keys) == 16
    page1, token = store.list_by_prefix("p")
    assert len(page1) == 8 and token == page1[-1]
    page2, token = store.list_by_prefix("p", token)
    assert len(page2) == 8 and token is None      # no third, empty page


def test_empty_final_page_when_token_is_last_key():
    store = _store_with_keys(15, page=8)
    last = store._keys_under("p")[-1]
    keys, token = store.list_by_prefix("p", last)
    assert keys == [] and token is None


def test_key_inserted_between_pages():
    store = _store_with_keys(16, page=8)          # 17 keys: 8 + 8 + 1
    page1, token = store.list_by_prefix("p")
    # a key sorting BEFORE the token is missed (S3 contract); one AFTER
    # the token appears in a later page exactly once
    store.inner.create("p/f00a")                  # before token "p/f06"
    store.inner.create("p/zzz")                   # after every fXX key
    rest, pages = [], 0
    while token is not None:
        got, token = store.list_by_prefix("p", token)
        rest.extend(got)
        pages += 1
    assert "p/f00a" not in page1 + rest
    assert rest.count("p/zzz") == 1
    assert sorted(page1 + rest) == page1 + rest   # still globally sorted


def test_key_deleted_between_pages_never_appears():
    store = _store_with_keys(16, page=8)
    page1, token = store.list_by_prefix("p")
    store.inner.unlink("p/f10")                   # lives past the token
    rest = []
    while token is not None:
        got, token = store.list_by_prefix("p", token)
        rest.extend(got)
    assert "p/f10" not in rest
    assert set(page1).isdisjoint(rest)            # no duplicates either


def test_pagination_billing_first_page_fresh_rest_pipelined():
    store = _store_with_keys(15, page=8)
    base = store.request_count
    _drain(store, "p")
    assert store.request_count == base + 2
    assert store.requests_by_class["list"] >= 2
    # fresh first page pays rtt; continuation only per-request overhead
    m = store.model
    assert store.busy_s == pytest.approx(m.rtt_s + m.per_request_s)


class _GatedStore(ObjectStoreBackend):
    """Wedges the speculative batch mid-fetch so a racing mutation is
    provably admitted while the listing is in flight."""

    def __init__(self):
        super().__init__()
        self.entered = threading.Event()
        self.gate = threading.Event()

    def readdir_plus_vec(self, paths):
        self.entered.set()
        self.gate.wait(5.0)
        return super().readdir_plus_vec(paths)


def test_racing_mutation_cancels_speculative_listing_on_object_store():
    """A rmdir admitted while a paginated listing's speculative batch is
    mid-flight: the ticket must cancel and nothing stale may install."""
    store = _GatedStore()
    store.inner.mkdir("pre")
    store.inner.mkdir("pre/d0")
    store.inner.mkdir("pre/d1")
    fs = CannyFS(store, workers=4, echo_errors=False)
    fs.readdir("pre")                 # miss -> seeds d0, d1 -> batch
    assert store.entered.wait(5.0)    # batch provably mid-fetch
    fs.rmdir("pre/d0")                # racing admitted mutation
    store.gate.set()
    fs.drain()
    ov = fs.engine.overlay
    assert ov.readdir("pre/d0") is None           # not resurrected
    assert ov.lookup("pre/d0") is False
    st = fs.stats
    assert st.prefetch_cancelled + st.prefetch_wasted >= 1
    assert "pre/d0" not in store.snapshot()["dirs"]
    assert len(fs.ledger) == 0
    fs.close()


# ---------------------------------------------------------------------------
# rule 5: cost-gated rename retarget
# ---------------------------------------------------------------------------

def _build_and_rename(fs):
    fs.makedirs("d")
    with fs.open("d/tmp", "wb") as f:
        f.write(b"hello ")
        f.write(b"world")
    fs.chmod("d/tmp", 0o600)
    fs.rename("d/tmp", "d/final")


def test_object_store_rename_retargets_pending_chain():
    store = ObjectStoreBackend(clock=SimClock())
    fs = CannyFS(store, workers=4, echo_errors=False)
    _build_and_rename(fs)
    fs.close()
    assert fs.stats.renames_retargeted == 1
    # the rename's COPY+DELETE never happened: the only copy is the
    # replayed chmod's metadata self-COPY, and nothing was deleted
    assert store.requests_by_class["copy"] == 1
    assert store.requests_by_class["delete"] == 0
    snap = store.snapshot()
    assert snap["files"] == {"d/final": b"hello world"}
    assert store.stat("d/final").mode == 0o600    # metadata replayed too
    assert len(fs.ledger) == 0


def test_remote_stream_native_rename_never_retargets():
    remote = RemoteStreamBackend(clock=SimClock())
    fs = CannyFS(remote, workers=4, echo_errors=False)
    _build_and_rename(fs)
    fs.close()
    assert fs.stats.renames_retargeted == 0
    snap = remote.snapshot()
    assert snap["files"] == {"d/final": b"hello world"}
    assert len(fs.ledger) == 0


def test_retarget_forced_off_pays_the_copy():
    store = ObjectStoreBackend(clock=SimClock())
    fs = CannyFS(store, workers=4, echo_errors=False,
                 fusion=FusionPolicy(retarget_renames=False))
    _build_and_rename(fs)
    fs.close()
    assert fs.stats.renames_retargeted == 0
    assert store.requests_by_class["copy"] >= 1
    assert store.snapshot()["files"] == {"d/final": b"hello world"}


def test_retarget_forced_on_fires_on_posix_media():
    lb = _nfs(clock=SimClock())
    fs = CannyFS(lb, workers=4, echo_errors=False,
                 fusion=FusionPolicy(retarget_renames=True))
    _build_and_rename(fs)
    fs.close()
    assert fs.stats.renames_retargeted == 1
    assert lb.inner.snapshot()["files"] == {"d/final": b"hello world"}
    assert len(fs.ledger) == 0


def test_auto_retarget_stays_off_on_latency_backend():
    lb = _nfs(clock=SimClock())
    fs = CannyFS(lb, workers=4, echo_errors=False)
    _build_and_rename(fs)
    fs.close()
    assert fs.stats.renames_retargeted == 0      # rename ~ create cost
    assert lb.inner.snapshot()["files"] == {"d/final": b"hello world"}


def test_pre_existing_source_falls_back_to_plain_rename():
    """No pending create anchoring the chain -> capture refuses, the
    backend rename (copy+delete) runs, state stays correct."""
    store = ObjectStoreBackend(clock=SimClock())
    store.inner.mkdir("d")
    store.inner.create("d/old")
    store.inner.write_at("d/old", 0, b"data")
    fs = CannyFS(store, workers=4, echo_errors=False)
    fs.rename("d/old", "d/new")
    fs.close()
    assert fs.stats.renames_retargeted == 0
    assert store.requests_by_class["copy"] >= 1
    assert store.snapshot()["files"] == {"d/new": b"data"}
    assert len(fs.ledger) == 0


# ---------------------------------------------------------------------------
# whole-object PUT semantics
# ---------------------------------------------------------------------------

def test_covering_write_vec_is_one_put_no_rmw():
    store = ObjectStoreBackend()
    store.inner.create("k")
    store.write_vec("k", [(0, b"abcd"), (4, b"efgh")])
    assert store.whole_object_puts == 1 and store.rmw_gets == 0
    assert store.snapshot()["files"]["k"] == b"abcdefgh"


def test_non_covering_write_pays_rmw_get():
    store = ObjectStoreBackend()
    store.inner.create("k")
    store.inner.write_at("k", 0, b"0123456789")
    store.write_at("k", 4, b"XX")                 # splice: GET + PUT
    assert store.rmw_gets == 1 and store.whole_object_puts == 1
    assert store.snapshot()["files"]["k"] == b"0123XX6789"


def test_remote_vectored_ops_are_one_roundtrip():
    remote = RemoteStreamBackend()
    remote.inner.mkdir("d")
    for i in range(6):
        remote.inner.create(f"d/f{i}")
    base = remote.op_count
    remote.stat_vec([f"d/f{i}" for i in range(6)])
    assert remote.op_count == base + 1
    remote.readdir_plus_vec(["d"])
    assert remote.op_count == base + 2
    remote.write_vec("d/f0", [(0, b"a"), (1, b"b"), (2, b"c")])
    assert remote.op_count == base + 3


# ---------------------------------------------------------------------------
# PR 9: crash consistency over the object store + rollback leak reporting
# ---------------------------------------------------------------------------

def _forge_spill_log(store, recs):
    from repro.core.durability import _enc
    store.inner.mkdir(".spill")
    store.inner.create(".spill/journal.log")
    store.inner.write_at(".spill/journal.log", 0,
                         b"".join(_enc(r) for r in recs))


def test_torn_copy_delete_rename_repaired_on_resume():
    """COPY+DELETE rename killed mid-flight on the object store: some
    keys copied to dst (their src side deleted), some still src-only.
    Resume's repair must merge-move (dst wins) and rekey the journal so
    the healed window looks exactly like a completed rename."""
    store = ObjectStoreBackend()
    inner = store.inner
    # the torn state a killed per-key COPY+DELETE leaves behind
    inner.mkdir("dst")
    inner.create("dst/a.bin")
    inner.write_at("dst/a.bin", 0, b"AAAA")      # copied, src side deleted
    inner.mkdir("src")
    inner.create("src/b.bin")
    inner.write_at("src/b.bin", 0, b"BBBB")      # never copied
    _forge_spill_log(store, [
        {"t": "begin", "e": 0},
        {"t": "jrnl", "e": 0, "p": "src", "d": 1},
        {"t": "jrnl", "e": 0, "p": "src/a.bin", "d": 0},
        {"t": "jrnl", "e": 0, "p": "src/b.bin", "d": 0},
        {"t": "admit", "e": 0, "k": "rename", "p": ["src", "dst"]},
    ])

    fs = CannyFS(store, echo_errors=False)
    report = fs.resume(".spill")
    assert report["resumable"]
    assert report["repairs"] >= 1
    snap = store.snapshot()["files"]
    data = {p: bytes(d) for p, d in snap.items()
            if not p.startswith(".spill")}
    assert data == {"dst/a.bin": b"AAAA", "dst/b.bin": b"BBBB"}
    assert "src" not in store.snapshot()["dirs"]
    # journal rekeyed: a rollback of the resumed window would remove the
    # dst-side outputs, never resurrect (or leak) the src side
    journal = fs.engine.spill.image.journal
    assert set(journal) == {"dst", "dst/a.bin", "dst/b.bin"}
    fs.close()


def test_partial_bulk_delete_repaired_on_resume():
    """remove_tree on the object store is LIST + ONE bulk DELETE; a kill
    can apply the delete to only some keys.  Resume must re-issue the
    removal and converge to the fully-removed state."""
    store = ObjectStoreBackend()
    inner = store.inner
    inner.mkdir("tmp")
    inner.create("tmp/x.bin")
    inner.write_at("tmp/x.bin", 0, b"x")         # survived the torn DELETE
    inner.mkdir("tmp/sub")                       # survived
    # (tmp/y.bin already deleted before the kill — simply absent)
    _forge_spill_log(store, [
        {"t": "begin", "e": 0},
        {"t": "jrnl", "e": 0, "p": "tmp", "d": 1},
        {"t": "admit", "e": 0, "k": "remove_tree", "p": ["tmp"]},
    ])

    fs = CannyFS(store, echo_errors=False)
    report = fs.resume(".spill")
    assert report["resumable"]
    assert report["repairs"] >= 1
    snap = store.snapshot()
    assert all(not p.startswith("tmp") for p in snap["files"])
    assert all(not d.startswith("tmp") for d in snap["dirs"] if d)
    assert "tmp" in fs.engine.spill.image.removed
    # the re-executed rmtree in the replayed body is elidable outright
    assert fs.engine.spill.elide_remove_root("tmp")
    fs.close()


def test_rollback_leftovers_reported_on_object_store():
    """A rollback whose unlink keeps failing must *report* the surviving
    path (and its then-unremovable parent), never silently leak it."""
    from repro.core import Transaction

    store = ObjectStoreBackend()
    chaos = FaultInjectingBackend(store, FaultPlan([
        FaultRule(error="EACCES", ops=("unlink",),
                  path_glob="out/locked.bin")], seed=1))
    fs = CannyFS(chaos, echo_errors=False)
    txn = Transaction(fs)
    with txn:
        fs.mkdir("out")
        fs.write_file("out/locked.bin", b"stuck")
        fs.write_file("out/ok.bin", b"fine")
        fs.drain()
        txn.rollback()
    assert txn.rolled_back
    assert "out/locked.bin" in txn.rollback_leftovers
    assert "out" in txn.rollback_leftovers      # rmdir of a non-empty dir
    snap = store.snapshot()["files"]
    assert "out/ok.bin" not in snap             # the healthy path DID go
    assert snap["out/locked.bin"] == b"stuck"
    assert fs.engine.stats.rollback_leftovers >= 2
    fs.close()
