"""Distribution tests that need multiple devices: run in subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count (jax pins device count at
first init, so the main pytest process stays single-device)."""
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow  # multi-device subprocess runs: opt-in

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def run_with_devices(code: str, n: int = 8, timeout: int = 480) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = SRC
    env.pop("REPRO_KERNELS", None)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, f"stdout:{out.stdout}\nstderr:{out.stderr}"
    return out.stdout


def test_flash_decode_matches_ref():
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        from repro.parallel.flash_decode import seq_sharded_decode_attention
        from repro.kernels.flash_attention.ref import mha_ref
        B, Sc, H, K, dh = 2, 64, 8, 1, 32
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, 1, H, dh))
        k = jax.random.normal(ks[1], (B, Sc, K, dh))
        v = jax.random.normal(ks[2], (B, Sc, K, dh))
        # half-filled ring cache
        k_pos = jnp.where(jnp.arange(Sc) < 40, jnp.arange(Sc), -1)
        t = jnp.asarray(39, jnp.int32)
        got = jax.jit(lambda *a: seq_sharded_decode_attention(
            mesh, ("model",), *a, batch_axes=("data",), causal=True))(
            q, k, v, k_pos, t)
        want = mha_ref(q, k, v, causal=True,
                       q_positions=jnp.full((B, 1), 39, jnp.int32),
                       k_positions=jnp.broadcast_to(k_pos[None], (B, Sc)))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
        print("flash_decode ok")
    """)


def test_sharded_train_step_matches_single_device():
    """The 4x2 GSPMD train step computes the same loss/update as 1 device."""
    run_with_devices("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.models import init_params, param_specs
        from repro.optim import init_opt_state
        from repro.train.steps import TrainConfig, make_train_step, train_shardings
        cfg = dataclasses.replace(get_smoke_config("qwen2-7b"),
                                  d_model=128, num_heads=8, num_kv_heads=4,
                                  d_ff=256, vocab_size=256)
        params = init_params(jax.random.PRNGKey(0), cfg)
        opt = init_opt_state(params)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, 256),
                 "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0, 256)}
        tc = TrainConfig(dtype=jnp.float32, remat_policy="none", z_loss=0.0)
        outs = {}
        for name, mesh in [("multi", jax.make_mesh((4, 2), ("data", "model"))),
                           ("single", jax.make_mesh((1, 1), ("data", "model")))]:
            bshape = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()}
            sh = train_shardings(cfg, mesh, jax.eval_shape(lambda: params), bshape)
            step = jax.jit(make_train_step(cfg, mesh, tc),
                           in_shardings=(sh["params"], sh["opt"], sh["batch"], None),
                           out_shardings=(sh["params"], sh["opt"], None))
            with mesh:
                p2, o2, m = step(params, opt, batch, jnp.float32(1e-3))
            outs[name] = (float(m["loss"]), jax.device_get(p2))
        assert abs(outs["multi"][0] - outs["single"][0]) < 1e-4, outs
        for a, b in zip(jax.tree.leaves(outs["multi"][1]),
                        jax.tree.leaves(outs["single"][1])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=5e-4, atol=5e-4)
        print("sharded == single ok")
    """)


def test_pod_grad_compress_close_to_exact():
    """int8-compressed cross-pod DP stays within quantization error of the
    exact GSPMD step, and the compiled HLO carries s16 all-reduces."""
    run_with_devices("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.models import init_params
        from repro.optim import init_opt_state
        from repro.train.steps import TrainConfig, make_train_step, train_shardings
        cfg = dataclasses.replace(get_smoke_config("stablelm-3b"),
                                  d_model=128, num_heads=4, num_kv_heads=4,
                                  d_ff=256, vocab_size=256)
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        params = init_params(jax.random.PRNGKey(0), cfg)
        opt = init_opt_state(params)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, 256),
                 "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0, 256)}
        losses = {}
        for compress in (False, True):
            tc = TrainConfig(dtype=jnp.float32, remat_policy="none",
                             z_loss=0.0, pod_grad_compress=compress)
            step = make_train_step(cfg, mesh, tc)
            bshape = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()}
            sh = train_shardings(cfg, mesh, jax.eval_shape(lambda: params),
                                 bshape, replicate_embed=compress)
            jitted = jax.jit(step, in_shardings=(sh["params"], sh["opt"], sh["batch"], None),
                             out_shardings=(sh["params"], sh["opt"], None))
            with mesh:
                lowered = jitted.lower(params, opt, batch, jnp.float32(1e-3))
                comp = lowered.compile()
                p2, o2, m = jitted(params, opt, batch, jnp.float32(1e-3))
            losses[compress] = (float(m["loss"]), jax.device_get(p2))
            if compress:
                assert "s16" in comp.as_text(), "no int16 wire traffic found"
        assert abs(losses[True][0] - losses[False][0]) < 1e-3
        for a, b in zip(jax.tree.leaves(losses[True][1]),
                        jax.tree.leaves(losses[False][1])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=3e-2, atol=3e-3)
        print("pod compress ok")
    """)


def test_param_spec_rules_cover_all_archs():
    run_with_devices("""
        import jax
        from jax.sharding import PartitionSpec as P
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        from repro.configs import ARCH_IDS, get_config
        from repro.models import param_specs
        from repro.parallel.sharding import param_pspecs, zero1_specs
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            pshape = param_specs(cfg)
            specs = param_pspecs(cfg, pshape, mesh)
            # every spec must divide its dims
            def check(leaf, spec):
                for dim, part in zip(leaf.shape, tuple(spec) + (None,) * 9):
                    if part is None: continue
                    parts = part if isinstance(part, tuple) else (part,)
                    n = 1
                    for p in parts: n *= mesh.shape[p]
                    assert dim % n == 0, (arch, leaf.shape, spec)
            jax.tree.map(check, pshape, specs,
                         is_leaf=lambda x: hasattr(x, "shape"))
            zspecs = zero1_specs(specs, pshape, mesh)
            jax.tree.map(check, pshape, zspecs,
                         is_leaf=lambda x: hasattr(x, "shape"))
        print("specs ok")
    """)


def test_dryrun_cell_mini():
    """Exercise the actual dryrun run_cell machinery on a tiny mesh by
    monkeypatching the production mesh (structure identical, 16 devices)."""
    run_with_devices("""
        import jax
        import repro.launch.mesh as M
        M.make_production_mesh = lambda multi_pod=False: (
            jax.make_mesh((2, 2, 4) if multi_pod else (4, 4),
                          ("pod", "data", "model") if multi_pod
                          else ("data", "model")))
        import repro.launch.dryrun as D
        import repro.configs as C, repro.launch.specs as S
        import dataclasses
        # shrink the shape cells so a 16-device compile is fast
        S.SHAPES = {"train_4k": S.ShapeCell("train_4k", 256, 16, "train"),
                    "decode_32k": S.ShapeCell("decode_32k", 256, 16, "decode")}
        real_get = C.get_config
        C.get_config = lambda name: C.get_smoke_config(name)
        D.get_config = C.get_config
        for mp in (False, True):
            rec = D.run_cell("qwen2-7b", "train_4k", multi_pod=mp, verbose=False)
            assert rec["status"] == "ok", rec
            rec = D.run_cell("recurrentgemma-9b", "decode_32k", multi_pod=mp, verbose=False)
            assert rec["status"] == "ok", rec
        print("mini dryrun ok")
    """, n=16)


def test_pipeline_parallel_forward_matches_sequential():
    """GPipe over the pod axis == the sequential superblock stack."""
    run_with_devices("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.models import init_params
        from repro.models.model import _make_ctx, _run_stack
        from repro.parallel.pipeline import pp_forward, pp_stage_body
        cfg = dataclasses.replace(get_smoke_config("stablelm-3b"),
                                  num_layers=4, d_model=64)
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        params = init_params(jax.random.PRNGKey(0), cfg)
        n_micro, mb, S = 4, 2, 16
        x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, S, cfg.d_model))
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (mb, S))
        ctx = _make_ctx(cfg, pos, None, jnp.float32, jnp.zeros((), jnp.int32), None)
        body = pp_stage_body(cfg, ctx, jnp.float32)
        stacked = tuple(params["blocks"])
        with mesh:
            got = jax.jit(lambda p, xm: pp_forward(mesh, body, p, xm))(stacked, x)
        # sequential reference: run each microbatch through the full stack
        ref = []
        for i in range(n_micro):
            y, _, _ = _run_stack(params, x[i], cfg, ctx, None, dtype=jnp.float32)
            ref.append(y)
        ref = jnp.stack(ref)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)
        print("pipeline ok")
    """)
