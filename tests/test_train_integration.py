"""End-to-end integration: tiny model trains (loss falls), checkpoints
through the transactional engine, survives a mid-run crash, and serves."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import CannyFS, InMemoryBackend
from repro.data import Prefetcher, SyntheticLM
from repro.launch.mesh import make_debug_mesh
from repro.train.loop import LoopConfig, Trainer, run_with_restarts
from repro.train.steps import TrainConfig

pytestmark = pytest.mark.slow  # jax train integration: opt-in (see pytest.ini)


def make_trainer(fs, cfg, total=30, ckpt_every=10):
    mesh = make_debug_mesh(1)
    data = Prefetcher(iter(SyntheticLM(cfg, batch=8, seq_len=32, seed=1)),
                      depth=2)
    return Trainer(cfg, mesh, fs, data,
                   tc=TrainConfig(dtype=jnp.float32, remat_policy="none",
                                  peak_lr=1e-2, z_loss=0.0),
                   lc=LoopConfig(total_steps=total, ckpt_every=ckpt_every,
                                 log_every=5, warmup=5))


def test_loss_decreases_and_checkpoints():
    cfg = get_smoke_config("stablelm-3b")
    fs = CannyFS(InMemoryBackend(), max_inflight=1000, workers=8)
    tr = make_trainer(fs, cfg, total=30)
    tr.init_state(next(tr.data))
    metrics = tr.run()
    assert np.isfinite(metrics["loss"])
    assert tr.ckpt.list_steps(), "no committed checkpoints"
    # metrics stream was written; loss fell monotonically-ish
    fs.drain()
    import json
    log = [json.loads(l) for l in
           fs.read_file("logs/metrics.jsonl").decode().strip().splitlines()]
    losses = [r["loss"] for r in log if "loss" in r]
    assert len(losses) >= 3
    assert losses[-1] < losses[0] - 0.05, losses
    assert losses[-1] < np.log(cfg.vocab_size), losses
    fs.close()


def test_resume_from_committed_checkpoint():
    cfg = get_smoke_config("stablelm-3b")
    fs = CannyFS(InMemoryBackend(), max_inflight=1000, workers=8)
    tr = make_trainer(fs, cfg, total=20, ckpt_every=10)
    tr.init_state(next(tr.data))
    tr.run(max_steps=10)
    assert tr.ckpt.list_steps() == [10]
    # new trainer on the same fs resumes at step 10
    tr2 = make_trainer(fs, cfg, total=20, ckpt_every=10)
    tr2.init_state(next(tr2.data))
    assert tr2.step == 10
    tr2.run()
    assert tr2.step == 20
    fs.close()


def test_run_with_restarts_recovers_from_crash():
    cfg = get_smoke_config("stablelm-3b")
    fs = CannyFS(InMemoryBackend(), max_inflight=1000, workers=8)
    crashed = {"done": False}

    class CrashingTrainer(Trainer):
        def run(self, max_steps=None):
            if not crashed["done"] and self.step >= 0:
                # train a bit, checkpoint, then die mid-job
                super().run(max_steps=10)
                crashed["done"] = True
                raise RuntimeError("simulated node failure")
            return super().run(max_steps=max_steps)

    def factory():
        tr = make_trainer(fs, cfg, total=20, ckpt_every=5)
        tr.__class__ = CrashingTrainer
        return tr

    metrics = run_with_restarts(factory, max_restarts=2)
    assert np.isfinite(metrics["loss"])
    fs.close()


def test_serve_prefill_decode_small():
    from repro.models import init_cache, init_params
    from repro.train.steps import make_decode_step, make_prefill_step
    cfg = get_smoke_config("qwen2-7b")
    mesh = make_debug_mesh(1)
    params = init_params(jax.random.PRNGKey(0), cfg)
    cache = init_cache(cfg, 2, 64, jnp.float32)
    pre = make_prefill_step(cfg, mesh, batch=2, max_len=64,
                            dtype=jnp.float32)
    dec = make_decode_step(cfg, mesh, batch=2, max_len=64, dtype=jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    with mesh:
        last, cache = jax.jit(pre)(params, {"tokens": toks}, cache)
        out = []
        tok = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
        for _ in range(4):
            tok, logits, cache = jax.jit(dec)(params, tok, cache)
            out.append(tok)
    assert all(o.shape == (2, 1) for o in out)
    assert int(cache["t"]) == 20
