"""PR 6 satellite tests: the CI guards under the discrete-event clock.

* determinism regression — two same-seed sim runs of each guard must
  serialize to byte-identical JSON payloads (stats, makespans,
  per-worker loads included); this is the property that lets the guards
  assert exact manifest-derived bounds with zero scheduling slack;
* sim-vs-real cross-validation — at small scale the simulated schedule
  must agree with a genuinely-paced real run: identical total injected
  service (the model is the same), makespan within a loose real-thread
  tolerance band;
* the ``InMemoryBackend`` children index that makes 10k-dir sim sweeps
  O(children) per ``readdir`` must stay in lockstep with the flat
  tables under every mutating op.
"""
import json

import pytest

from benchmarks import (backend_guard, dispatch_guard, overlay_guard,
                        read_guard, resume_guard, sim_sweep, tenant_guard,
                        walk_guard)
from benchmarks.workloads import (PacedVirtualClock, TreeSpec, extract_tree,
                                  synth_tree)
from repro.core import (CannyFS, InMemoryBackend, LatencyBackend,
                        LatencyModel, SimClock)


def _payload(report) -> str:
    return json.dumps(report, sort_keys=True)


@pytest.mark.parametrize("guard", [dispatch_guard, walk_guard,
                                   overlay_guard, read_guard, backend_guard,
                                   resume_guard, tenant_guard],
                         ids=["dispatch", "walk", "overlay", "read",
                              "backend", "resume", "tenant"])
def test_sim_guard_runs_are_byte_identical_and_green(guard):
    first = guard.build_report("sim")
    second = guard.build_report("sim")
    assert guard.check(first) == []
    assert _payload(first) == _payload(second)


def test_sim_sweep_smoke_is_green_and_deterministic(monkeypatch):
    monkeypatch.setenv("REPRO_BENCH_SCALE", "0.1")
    first = sim_sweep.build_report()
    second = sim_sweep.build_report()
    assert sim_sweep.check(first) == []
    assert _payload(first) == _payload(second)


def _cross_validation_run(clock, workers=4):
    remote = LatencyBackend(
        InMemoryBackend(),
        LatencyModel(meta_ms=1.0, data_ms=1.0, jitter_sigma=0.0, seed=6),
        clock=clock)
    fs = CannyFS(remote, max_inflight=4000, workers=workers,
                 fusion=False)      # identical op count on both clocks
    dirs, files = synth_tree(TreeSpec(n_files=120, n_dirs=12))
    extract_tree(fs, dirs, files)
    fs.close()
    return fs.stats.executed


def test_sim_makespan_cross_validates_against_paced_real_run():
    sim = SimClock()
    ops = _cross_validation_run(sim)
    paced = PacedVirtualClock(pace=0.05)
    assert _cross_validation_run(paced) == ops
    # total injected service is a pure function of the op stream at zero
    # jitter, so the two harnesses must agree almost exactly (the sim
    # additionally charges its tiny modelled park/steal overheads)
    sim_service = sum(sim.thread_seconds().values())
    paced_service = paced.now()
    assert sim_service == pytest.approx(paced_service, rel=0.02)
    # the makespan is scheduling-dependent: the simulated critical path
    # must sit inside a loose band around the real-paced schedule's
    # busiest worker (real threads can beat perfect balance by a little
    # or lose to OS scheduling by a lot, hence the asymmetry)
    assert 0.7 * sim.makespan() <= paced.makespan() <= 3.0 * sim.makespan()


def test_inmemory_children_index_tracks_all_mutations():
    be = InMemoryBackend()
    be.mkdir("a")
    be.mkdir("a/b")
    be.create("a/x")
    be.write_at("a/b/y", 0, b"data")          # implicit create
    be.symlink("a/x", "a/lnk")
    be.link("a/x", "a/hard")
    be.rename("a/x", "a/b/x2")
    be.mkdir("c")
    be.rename("a/b", "c/b")                   # dir move: subtree rekeyed
    be.unlink("a/lnk")
    be.unlink("a/hard")
    be.rmdir("a")
    for d in ["", "c", "c/b"]:
        assert be._children.get(d, set()) == be._scan_children(d)
        assert be.readdir(d) == sorted(be._scan_children(d))
    assert "a" not in be._children
    assert be.readdir("c/b") == ["x2", "y"]
