"""Overlay-on-vs-overlay-off oracle property tests (hypothesis): for any
op stream — including namespace reads (readdir/stat) and readdir-driven
rmtree, the overlay's whole purpose — running with the overlay enabled
and disabled leaves the InMemory backend in the identical final state
with identical read results and ledger outcomes, including under seeded
fault plans."""
import pytest

pytest.importorskip("hypothesis",
                    reason="hypothesis not installed (see requirements-dev.txt)")
import hypothesis.strategies as stx
from hypothesis import HealthCheck, given, settings

from repro.core import (CannyFS, FaultInjectingBackend, FaultPlan, FaultRule,
                        InMemoryBackend)

DIRS = ["a", "b", "a/sub"]
FILES = [f"{d}/f{i}" for d in DIRS for i in range(2)]


def overlay_op_strategy():
    """Namespace-heavy streams: writes, unlinks, renames, directory reads
    and subtree removals interleaved — readdir/stat answers are collected
    and compared across modes, so an overlay answer diverging from the
    backend's by even one name fails the property."""
    write = stx.tuples(stx.just("write"), stx.sampled_from(FILES),
                       stx.binary(min_size=0, max_size=16))
    unlink = stx.tuples(stx.just("unlink"), stx.sampled_from(FILES),
                        stx.none())
    rename = stx.tuples(stx.just("rename"), stx.sampled_from(FILES),
                        stx.sampled_from(FILES))
    readdir = stx.tuples(stx.just("readdir"), stx.sampled_from(DIRS),
                         stx.none())
    statop = stx.tuples(stx.just("stat"), stx.sampled_from(FILES + DIRS),
                        stx.none())
    read = stx.tuples(stx.just("read"), stx.sampled_from(FILES), stx.none())
    rmtree = stx.tuples(stx.just("rmtree"), stx.sampled_from(["a", "b"]),
                        stx.none())
    remake = stx.tuples(stx.just("remake"), stx.sampled_from(DIRS),
                        stx.none())
    return stx.lists(stx.one_of(write, unlink, rename, readdir, statop,
                                read, rmtree, remake),
                     min_size=1, max_size=25)


def _drive(fs, ops):
    """Replay ops, collecting every read-class answer.  Destructive ops on
    missing paths are filtered against live-set bookkeeping (the valid
    single-writer task model, as in the sibling property suites)."""
    observed = []
    live = set()
    live_dirs = set(DIRS)
    for op, path, arg in ops:
        if op == "write":
            parent = path.rsplit("/", 1)[0]
            if parent not in live_dirs:
                continue
            fs.write_file(path, arg)
            live.add(path)
        elif op == "unlink" and path in live:
            fs.unlink(path)
            live.discard(path)
        elif op == "rename":
            dst = arg
            if path not in live or dst == path:
                continue
            if dst.rsplit("/", 1)[0] not in live_dirs:
                continue
            fs.rename(path, dst)
            live.discard(path)
            live.add(dst)
        elif op == "readdir" and path in live_dirs:
            observed.append(("readdir", path, fs.readdir(path)))
        elif op == "stat":
            st = fs.stat(path)
            observed.append(("stat", path, st.exists, st.is_dir))
        elif op == "read" and path in live:
            observed.append(("read", path, fs.read_file(path)))
        elif op == "rmtree" and path in live_dirs:
            fs.rmtree(path)
            for d in [d for d in live_dirs if d == path
                      or d.startswith(path + "/")]:
                live_dirs.discard(d)
            for f in [f for f in live if f.startswith(path + "/")]:
                live.discard(f)
        elif op == "remake" and path not in live_dirs:
            parent = path.rsplit("/", 1)[0] if "/" in path else None
            if parent is not None and parent not in live_dirs:
                continue
            fs.makedirs(path)
            live_dirs.add(path)
    return observed


@settings(max_examples=50, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ops=overlay_op_strategy(), workers=stx.sampled_from([1, 4]))
def test_overlay_on_and_off_execution_identical(ops, workers):
    """The acceptance property: for any op stream, overlay on/off leaves
    the InMemory oracle in the identical final state with identical
    readdir/stat/read answers and identical (empty) ledgers."""
    results = []
    for overlay in (None, False):    # None -> default policy (enabled)
        be = InMemoryBackend()
        fs = CannyFS(be, workers=workers, overlay=overlay, echo_errors=False)
        for d in DIRS:
            fs.makedirs(d)
        observed = _drive(fs, ops)
        fs.drain()
        sig = sorted((e.kind, e.paths, getattr(e.error, "errno", None))
                     for e in fs.ledger.entries())
        results.append((be.snapshot(), observed, sig))
        fs.close()
    assert results[0] == results[1]


@settings(max_examples=20, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(ops=overlay_op_strategy(), seed=stx.integers(0, 3))
def test_overlay_modes_agree_under_fault_plans(ops, seed):
    """With a seeded fault plan the two modes may fail *different* backend
    calls (fault matching is per fused call — a collapsed remove_tree is
    one match where the per-entry path offers many), but a clean run (no
    injected faults in either mode) must produce identical state, and
    every injected fault must surface in its run's ledger."""
    outcome = []
    for overlay in (None, False):
        plan = FaultPlan([FaultRule(error="EIO",
                                    ops=("write", "unlink", "rmdir",
                                         "remove_tree"),
                                    probability=0.2, max_failures=2)],
                         seed=seed)
        be = InMemoryBackend()
        fs = CannyFS(FaultInjectingBackend(be, plan), workers=2,
                     overlay=overlay, echo_errors=False)
        for d in DIRS:
            fs.makedirs(d)
        try:
            _drive(fs, ops)
        except OSError:
            pass   # a sync read path may surface an injected fault directly
        fs.drain()
        n_ledgered = sum(getattr(e.error, "injected", False)
                         for e in fs.ledger.entries())
        outcome.append((plan.injected, n_ledgered, be.snapshot()))
        fs.close()
    for injected, ledgered, _ in outcome:
        assert ledgered <= injected   # sync-surfaced faults skip the ledger
    if outcome[0][0] == 0 and outcome[1][0] == 0:
        assert outcome[0][2] == outcome[1][2]
