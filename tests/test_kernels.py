"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs the pure-jnp
oracle in ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

pytestmark = pytest.mark.slow  # jax kernel sweeps: opt-in (see pytest.ini)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(8, 128), (2, 17, 256), (3, 100, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    from repro.kernels.rmsnorm.kernel import rmsnorm_pallas
    from repro.kernels.rmsnorm.ref import rmsnorm_ref
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, shape, dtype)
    s = jax.random.normal(jax.random.PRNGKey(1), shape[-1:], jnp.float32)
    got = rmsnorm_pallas(x, s, interpret=True)
    want = rmsnorm_ref(x, s)
    assert got.dtype == want.dtype
    assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32),
                    **tol(dtype))


def test_rmsnorm_residual_fusion():
    from repro.kernels.rmsnorm.kernel import rmsnorm_pallas
    from repro.kernels.rmsnorm.ref import rmsnorm_ref
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 33, 128))
    r = jax.random.normal(jax.random.PRNGKey(1), (4, 33, 128))
    s = jnp.ones((128,))
    got = rmsnorm_pallas(x, s, residual=r, interpret=True)
    want = rmsnorm_ref(x, s, residual=r)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,K,dh", [
    (1, 256, 4, 4, 128),     # MHA
    (2, 512, 8, 2, 128),     # GQA 4:1
    (1, 256, 4, 1, 128),     # MQA
    (1, 256, 2, 2, 256),     # big head dim (recurrentgemma-like)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(B, S, H, K, dh, dtype):
    from repro.kernels.flash_attention.kernel import flash_attention_pallas
    from repro.kernels.flash_attention.ref import mha_ref
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, S, H, dh), dtype)
    k = jax.random.normal(ks[1], (B, S, K, dh), dtype)
    v = jax.random.normal(ks[2], (B, S, K, dh), dtype)
    got = flash_attention_pallas(q, k, v, causal=True, interpret=True)
    want = mha_ref(q, k, v, causal=True)
    assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32),
                    **tol(dtype))


@pytest.mark.parametrize("kwargs", [
    dict(causal=False),
    dict(causal=True, window=100),
    dict(causal=True, window=512),    # window > S: degenerates to causal
    dict(causal=True, chunk=128),
    dict(causal=True, chunk=256),
])
def test_flash_attention_masks(kwargs):
    from repro.kernels.flash_attention.kernel import flash_attention_pallas
    from repro.kernels.flash_attention.ref import mha_ref
    B, S, H, K, dh = 1, 512, 4, 2, 128
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, S, H, dh))
    k = jax.random.normal(ks[1], (B, S, K, dh))
    v = jax.random.normal(ks[2], (B, S, K, dh))
    got = flash_attention_pallas(q, k, v, interpret=True, **kwargs)
    want = mha_ref(q, k, v, **kwargs)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_blocked_attention_matches_ref():
    from repro.kernels.flash_attention.ref import mha_blocked, mha_ref
    B, S, H, K, dh = 1, 2048, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (B, S, H, dh))
    k = jax.random.normal(ks[1], (B, S, K, dh))
    v = jax.random.normal(ks[2], (B, S, K, dh))
    for kw in (dict(causal=True), dict(causal=True, window=300),
               dict(causal=True, chunk=1024), dict(causal=False)):
        got = mha_blocked(q, k, v, block_q=1024, **kw)
        want = mha_ref(q, k, v, **kw)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5,
                        atol=2e-5)


# ---------------------------------------------------------------------------
# Mamba-2 SSD
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,P,G,N,chunk", [
    (1, 128, 2, 64, 1, 64, 64),
    (2, 256, 3, 64, 1, 128, 128),
    (1, 256, 4, 32, 2, 64, 128),      # grouped B/C
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_sweep(B, S, H, P, G, N, chunk, dtype):
    from repro.kernels.ssd.kernel import ssd_pallas
    from repro.kernels.ssd.ref import ssd_chunked, ssd_sequential
    ks = jax.random.split(jax.random.PRNGKey(5), 6)
    x = jax.random.normal(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = (jax.random.normal(ks[3], (B, S, G, N)) * 0.3).astype(dtype)
    Cm = (jax.random.normal(ks[4], (B, S, G, N)) * 0.3).astype(dtype)
    D = jax.random.normal(ks[5], (H,))
    want = ssd_sequential(x, dt, A, Bm, Cm, D)
    got_chunked = ssd_chunked(x, dt, A, Bm, Cm, D, chunk=chunk)
    got_pallas = ssd_pallas(x, dt, A, Bm, Cm, D, chunk=chunk, interpret=True)
    t = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-3, atol=2e-3)
    assert_allclose(np.asarray(got_chunked, np.float32),
                    np.asarray(want, np.float32), **t)
    assert_allclose(np.asarray(got_pallas, np.float32),
                    np.asarray(want, np.float32), **t)


def test_ssd_decode_step_matches_scan():
    from repro.kernels.ssd.ref import ssd_decode_step, ssd_sequential
    B, S, H, P, G, N = 1, 16, 2, 32, 1, 64
    ks = jax.random.split(jax.random.PRNGKey(6), 6)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.3
    D = jax.random.normal(ks[5], (H,))
    want = ssd_sequential(x, dt, A, Bm, Cm, D)
    state = jnp.zeros((B, H, P, N))
    for t in range(S):
        state, y = ssd_decode_step(state, x[:, t], dt[:, t], A, Bm[:, t],
                                   Cm[:, t], D)
        assert_allclose(np.asarray(y), np.asarray(want[:, t]), rtol=1e-4,
                        atol=1e-4)


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,W,t_blk", [
    (1, 128, 128, 128), (2, 256, 256, 128), (1, 384, 128, 128),
])
@pytest.mark.parametrize("with_h0", [False, True])
def test_rglru_sweep(B, S, W, t_blk, with_h0):
    from repro.kernels.rglru.kernel import rglru_pallas
    from repro.kernels.rglru.ref import rglru_assoc, rglru_sequential
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    la = -jax.nn.softplus(jax.random.normal(ks[0], (B, S, W)))
    gx = jax.random.normal(ks[1], (B, S, W))
    h0 = jax.random.normal(ks[2], (B, W)) if with_h0 else None
    y_seq, h_seq = rglru_sequential(la, gx, h0)
    y_assoc, _ = rglru_assoc(la, gx, h0)
    y_pal, h_pal = rglru_pallas(la, gx, h0, t_blk=t_blk, interpret=True)
    assert_allclose(np.asarray(y_assoc), np.asarray(y_seq), rtol=1e-4,
                    atol=1e-4)
    assert_allclose(np.asarray(y_pal), np.asarray(y_seq), rtol=1e-4,
                    atol=1e-4)
    assert_allclose(np.asarray(h_pal), np.asarray(h_seq), rtol=1e-4,
                    atol=1e-4)


def test_rglru_gates_block_diagonal():
    from repro.kernels.rglru.ref import rglru_gates
    B, S, W, Hb = 1, 8, 64, 4
    bw = W // Hb
    ks = jax.random.split(jax.random.PRNGKey(8), 5)
    p = {"a_gate_w": jax.random.normal(ks[0], (Hb, bw, bw)) * 0.1,
         "a_gate_b": jnp.zeros((Hb, bw)),
         "x_gate_w": jax.random.normal(ks[1], (Hb, bw, bw)) * 0.1,
         "x_gate_b": jnp.zeros((Hb, bw)),
         "a_param": jnp.ones((W,))}
    x = jax.random.normal(ks[2], (B, S, W))
    log_a, gx = rglru_gates(x, p)
    assert log_a.shape == (B, S, W) and gx.shape == (B, S, W)
    assert np.all(np.asarray(log_a) <= 0), "decay must be <= 1"
