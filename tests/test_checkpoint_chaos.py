"""Crash-restart chaos for TransactionalCheckpointManager (ROADMAP item c):
kill the process (simulated by a non-OSError BaseException the manager
cannot catch) between the first shard write and the COMMIT marker — at
EVERY injection point — then start a fresh manager on the same backend
and assert startup recovery discards exactly the uncommitted step dir,
leaving committed checkpoints byte-identical."""
import numpy as np
import pytest

from repro.checkpoint import COMMIT_FILE, TransactionalCheckpointManager
from repro.core import CannyFS, EagerFlags, InMemoryBackend


class _Crash(BaseException):
    """Simulated process death.  Deliberately NOT an OSError/CannyError:
    the manager's own error handling must not see it — the partial step
    dir is left exactly as the dying process would leave it."""


class CrashingBackend(InMemoryBackend):
    """Raises _Crash on the k-th mutating call under the checkpoint root
    once armed.  Counting only ckpt-dir mutations makes injection point k
    deterministic and independent of unrelated traffic."""

    def __init__(self, root="ck"):
        super().__init__()
        self._root = root
        self.countdown = None     # None = disarmed

    def _tick(self, path):
        if self.countdown is None or not str(path).startswith(self._root):
            return
        if self.countdown == 0:
            self.countdown = None
            raise _Crash(path)
        self.countdown -= 1

    def mkdir(self, path):
        self._tick(path)
        super().mkdir(path)

    def create(self, path):
        self._tick(path)
        super().create(path)

    def write_at(self, path, offset, data):
        self._tick(path)
        return super().write_at(path, offset, data)

    def write_vec(self, path, segments):
        self._tick(path)
        return super().write_vec(path, segments)


def _sync_fs(be):
    # fully synchronous mount: every op runs in the caller's thread, so
    # _Crash propagates out of save() like a process dying mid-syscall
    # (nothing self-cleans; the partial dir survives on the backend)
    return CannyFS(be, flags=EagerFlags.all_off(), workers=2,
                   echo_errors=False)


def _ckpt_files(be, step):
    prefix = f"ck/step_{step:010d}/"
    return {p: bytes(d) for p, d in be.snapshot()["files"].items()
            if p.startswith(prefix)}


def test_crash_restart_at_every_injection_point():
    be = CrashingBackend("ck")
    state1 = {"w": np.arange(8, dtype=np.float32),
              "b": np.ones(3, np.float32)}
    state2 = {"w": np.arange(8, dtype=np.float32) * 2.0,
              "b": np.zeros(3, np.float32)}

    # seed one committed checkpoint (no chaos armed)
    fs0 = _sync_fs(be)
    mgr0 = TransactionalCheckpointManager(fs0, "ck")
    assert mgr0.save(1, state1, block=True).ok
    fs0.close()
    committed = _ckpt_files(be, 1)
    assert any(p.endswith(COMMIT_FILE) for p in committed)

    crash_points = 0
    k = 0
    while True:
        be.countdown = k
        fs = _sync_fs(be)
        crashed = False
        try:
            mgr = TransactionalCheckpointManager(fs, "ck")
            res = mgr.save(2, state2, block=True)
        except _Crash:
            crashed = True
        be.countdown = None
        fs.close()

        # restart: a fresh manager on the same backend runs recovery
        fs2 = _sync_fs(be)
        mgr2 = TransactionalCheckpointManager(fs2, "ck")
        if crashed:
            crash_points += 1
            # recovery discarded exactly the uncommitted step dir...
            assert mgr2.list_steps() == [1]
            assert _ckpt_files(be, 2) == {}
            assert all(not p.startswith("ck/step_0000000002")
                       for p in be.snapshot()["files"])
            # ...and the committed checkpoint is untouched and restorable
            assert _ckpt_files(be, 1) == committed
            step, out = mgr2.restore(state1)
            assert step == 1
            np.testing.assert_array_equal(out["w"], state1["w"])
            fs2.close()
            k += 1
            continue
        # chaos exhausted: the uninjected save must have committed
        assert res.ok, res.error
        assert mgr2.list_steps() == [1, 2]
        step, out = mgr2.restore(state2)
        assert step == 2
        np.testing.assert_array_equal(out["w"], state2["w"])
        fs2.close()
        break

    # the sweep covered the full window: root mkdir, manifest, both shard
    # streams and the COMMIT marker itself are all >1 mutating calls
    assert crash_points >= 5


def test_crash_after_commit_marker_is_durable():
    """A crash strictly *after* the COMMIT content landed loses nothing:
    restart sees a committed step (the marker names the step) and
    recovery discards nothing."""
    be = CrashingBackend("ck")
    state = {"w": np.ones(4, np.float32)}
    fs = _sync_fs(be)
    mgr = TransactionalCheckpointManager(fs, "ck")
    assert mgr.save(7, state, block=True).ok
    fs.close()
    before = be.snapshot()["files"]

    fs2 = _sync_fs(be)
    mgr2 = TransactionalCheckpointManager(fs2, "ck")
    assert mgr2.rollback_uncommitted() == []
    assert mgr2.list_steps() == [7]
    assert be.snapshot()["files"] == before
    fs2.close()


def test_partial_commit_marker_is_not_a_commit():
    """Crash between the COMMIT file's create and its content write: the
    empty marker must read as *uncommitted* and recovery must discard the
    step (an empty/garbage marker naming no step is not durable)."""
    be = CrashingBackend("ck")
    state = {"w": np.ones(4, np.float32)}
    fs = _sync_fs(be)
    mgr = TransactionalCheckpointManager(fs, "ck")
    assert mgr.save(1, state, block=True).ok
    fs.close()

    # forge the failure mode directly: step 2 fully written, marker empty
    d = "ck/step_0000000002"
    be.mkdir(d)
    be.create(f"{d}/manifest.json")
    be.write_at(f"{d}/manifest.json", 0, b"{}")
    be.create(f"{d}/{COMMIT_FILE}")          # created, never written

    fs2 = _sync_fs(be)
    mgr2 = TransactionalCheckpointManager(fs2, "ck")
    assert mgr2.list_steps() == [1]
    assert all(not p.startswith(d) for p in be.snapshot()["files"])
    fs2.close()
