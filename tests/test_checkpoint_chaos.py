"""Crash-restart chaos for TransactionalCheckpointManager (ROADMAP item c):
kill the process (simulated by a non-OSError BaseException the manager
cannot catch) between the first shard write and the COMMIT marker — at
EVERY injection point — then start a fresh manager on the same backend
and assert startup recovery discards exactly the uncommitted step dir,
leaving committed checkpoints byte-identical.

PR 9 extends the harness to the durability spill: SIGKILL-equivalent
aborts (``ProcessKilled``, backend stays dead until revived) at EVERY
mutating backend call — including the spill journal's own writes and the
mutations of the resume/repair pass itself — must always converge, after
``CannyFS.resume``, to backend state byte-identical to an uninterrupted
run."""
import numpy as np
import pytest

from repro.checkpoint import COMMIT_FILE, TransactionalCheckpointManager
from repro.core import (CannyFS, EagerFlags, InMemoryBackend, ProcessKilled,
                        run_transaction)


class _Crash(BaseException):
    """Simulated process death.  Deliberately NOT an OSError/CannyError:
    the manager's own error handling must not see it — the partial step
    dir is left exactly as the dying process would leave it."""


class CrashingBackend(InMemoryBackend):
    """Raises _Crash on the k-th mutating call under the checkpoint root
    once armed.  Counting only ckpt-dir mutations makes injection point k
    deterministic and independent of unrelated traffic."""

    def __init__(self, root="ck"):
        super().__init__()
        self._root = root
        self.countdown = None     # None = disarmed

    def _tick(self, path):
        if self.countdown is None or not str(path).startswith(self._root):
            return
        if self.countdown == 0:
            self.countdown = None
            raise _Crash(path)
        self.countdown -= 1

    def mkdir(self, path):
        self._tick(path)
        super().mkdir(path)

    def create(self, path):
        self._tick(path)
        super().create(path)

    def write_at(self, path, offset, data):
        self._tick(path)
        return super().write_at(path, offset, data)

    def write_vec(self, path, segments):
        self._tick(path)
        return super().write_vec(path, segments)


def _sync_fs(be):
    # fully synchronous mount: every op runs in the caller's thread, so
    # _Crash propagates out of save() like a process dying mid-syscall
    # (nothing self-cleans; the partial dir survives on the backend)
    return CannyFS(be, flags=EagerFlags.all_off(), workers=2,
                   echo_errors=False)


def _ckpt_files(be, step):
    prefix = f"ck/step_{step:010d}/"
    return {p: bytes(d) for p, d in be.snapshot()["files"].items()
            if p.startswith(prefix)}


def test_crash_restart_at_every_injection_point():
    be = CrashingBackend("ck")
    state1 = {"w": np.arange(8, dtype=np.float32),
              "b": np.ones(3, np.float32)}
    state2 = {"w": np.arange(8, dtype=np.float32) * 2.0,
              "b": np.zeros(3, np.float32)}

    # seed one committed checkpoint (no chaos armed)
    fs0 = _sync_fs(be)
    mgr0 = TransactionalCheckpointManager(fs0, "ck")
    assert mgr0.save(1, state1, block=True).ok
    fs0.close()
    committed = _ckpt_files(be, 1)
    assert any(p.endswith(COMMIT_FILE) for p in committed)

    crash_points = 0
    k = 0
    while True:
        be.countdown = k
        fs = _sync_fs(be)
        crashed = False
        try:
            mgr = TransactionalCheckpointManager(fs, "ck")
            res = mgr.save(2, state2, block=True)
        except _Crash:
            crashed = True
        be.countdown = None
        fs.close()

        # restart: a fresh manager on the same backend runs recovery
        fs2 = _sync_fs(be)
        mgr2 = TransactionalCheckpointManager(fs2, "ck")
        if crashed:
            crash_points += 1
            # recovery discarded exactly the uncommitted step dir...
            assert mgr2.list_steps() == [1]
            assert _ckpt_files(be, 2) == {}
            assert all(not p.startswith("ck/step_0000000002")
                       for p in be.snapshot()["files"])
            # ...and the committed checkpoint is untouched and restorable
            assert _ckpt_files(be, 1) == committed
            step, out = mgr2.restore(state1)
            assert step == 1
            np.testing.assert_array_equal(out["w"], state1["w"])
            fs2.close()
            k += 1
            continue
        # chaos exhausted: the uninjected save must have committed
        assert res.ok, res.error
        assert mgr2.list_steps() == [1, 2]
        step, out = mgr2.restore(state2)
        assert step == 2
        np.testing.assert_array_equal(out["w"], state2["w"])
        fs2.close()
        break

    # the sweep covered the full window: root mkdir, manifest, both shard
    # streams and the COMMIT marker itself are all >1 mutating calls
    assert crash_points >= 5


def test_crash_after_commit_marker_is_durable():
    """A crash strictly *after* the COMMIT content landed loses nothing:
    restart sees a committed step (the marker names the step) and
    recovery discards nothing."""
    be = CrashingBackend("ck")
    state = {"w": np.ones(4, np.float32)}
    fs = _sync_fs(be)
    mgr = TransactionalCheckpointManager(fs, "ck")
    assert mgr.save(7, state, block=True).ok
    fs.close()
    before = be.snapshot()["files"]

    fs2 = _sync_fs(be)
    mgr2 = TransactionalCheckpointManager(fs2, "ck")
    assert mgr2.rollback_uncommitted() == []
    assert mgr2.list_steps() == [7]
    assert be.snapshot()["files"] == before
    fs2.close()


def test_partial_commit_marker_is_not_a_commit():
    """Crash between the COMMIT file's create and its content write: the
    empty marker must read as *uncommitted* and recovery must discard the
    step (an empty/garbage marker naming no step is not durable)."""
    be = CrashingBackend("ck")
    state = {"w": np.ones(4, np.float32)}
    fs = _sync_fs(be)
    mgr = TransactionalCheckpointManager(fs, "ck")
    assert mgr.save(1, state, block=True).ok
    fs.close()

    # forge the failure mode directly: step 2 fully written, marker empty
    d = "ck/step_0000000002"
    be.mkdir(d)
    be.create(f"{d}/manifest.json")
    be.write_at(f"{d}/manifest.json", 0, b"{}")
    be.create(f"{d}/{COMMIT_FILE}")          # created, never written

    fs2 = _sync_fs(be)
    mgr2 = TransactionalCheckpointManager(fs2, "ck")
    assert mgr2.list_steps() == [1]
    assert all(not p.startswith(d) for p in be.snapshot()["files"])
    fs2.close()


# ---------------------------------------------------------------------------
# PR 9: kill-point sweep over the durability spill (transaction resume)
# ---------------------------------------------------------------------------

_MUTATING = ("mkdir", "create", "write_at", "write_vec", "unlink", "rmdir",
             "rename", "remove_tree", "chmod", "truncate")
_READS = ("stat", "stat_vec", "readdir", "readdir_plus", "readdir_plus_vec",
          "read_at", "read_vec", "readlink")


class KillingBackend(InMemoryBackend):
    """SIGKILL-equivalent chaos: the k-th mutating call (spill-journal
    writes included) raises ``ProcessKilled`` — before applying (``post=
    False``, the op never lands) or after (``post=True``, the op lands
    but nothing downstream of it runs) — and the backend stays dead
    (every later call, reads included, raises) until ``revive``."""

    def __init__(self, post=False):
        super().__init__()
        self.countdown = None      # None = disarmed
        self.post = post
        self.dead = False

    def revive(self):
        self.dead = False
        self.countdown = None

    def _strike(self, name):
        self.countdown = None
        self.dead = True
        raise ProcessKilled(f"kill point at {name}")

    def _gate(self, name):
        if self.dead:
            raise ProcessKilled(f"backend dead at {name}")
        if self.countdown is None:
            return False
        if self.countdown == 0:
            if not self.post:
                self._strike(name)
            return True            # apply the op, then strike
        self.countdown -= 1
        return False


def _wrap_mutating(name):
    base = getattr(InMemoryBackend, name)

    def op(self, *a, **kw):
        post = self._gate(name)
        out = base(self, *a, **kw)
        if post:
            self._strike(name)
        return out
    op.__name__ = name
    return op


def _wrap_read(name):
    base = getattr(InMemoryBackend, name)

    def op(self, *a, **kw):
        if self.dead:
            raise ProcessKilled(f"backend dead at {name}")
        return base(self, *a, **kw)
    op.__name__ = name
    return op


for _name in _MUTATING:
    setattr(KillingBackend, _name, _wrap_mutating(_name))
for _name in _READS:
    setattr(KillingBackend, _name, _wrap_read(_name))


def _spill_job(fs):
    """A small extract-transform-clean job touching every structural op
    class the spill records: mkdir chains, create+write streams,
    metadata, rename, a subtree removal and a file removal."""
    fs.makedirs("data/keep/deep")
    fs.makedirs("data/tmp")
    for i in range(3):
        fs.write_file(f"data/keep/f{i}.bin", bytes([65 + i]) * 64)
        fs.write_file(f"data/tmp/t{i}.bin", bytes([97 + i]) * 32)
    fs.write_file("data/keep/deep/d.bin", b"deep" * 8)
    fs.chmod("data/keep/f0.bin", 0o600)
    fs.rename("data/keep/f2.bin", "data/keep/g2.bin")
    fs.rmtree("data/tmp")
    fs.unlink("data/keep/f1.bin")


def _spill_fs(be):
    fs = CannyFS(be, flags=EagerFlags(flush=False), workers=2,
                 echo_errors=False)
    fs.enable_spill(".spill", flush_records=4)
    return fs


def _state(be):
    """Data-plane state (spill dir excluded): file bytes, dirs, modes."""
    snap = be.snapshot()
    files = {p: bytes(d) for p, d in snap["files"].items()
             if not p.startswith(".spill")}
    dirs = {d for d in snap["dirs"]
            if d and d != ".spill" and not d.startswith(".spill/")}
    modes = {p: be.stat(p).mode for p in files}
    return files, dirs, modes


def _run_to_completion(be, *, max_resumes=8):
    """Restart loop: resume + re-run until the job commits.  Returns the
    number of restarts it took."""
    restarts = 0
    while True:
        be.revive()
        fs = CannyFS(be, flags=EagerFlags(flush=False), workers=2,
                     echo_errors=False)
        try:
            report = fs.resume(".spill", flush_records=4)
            if report.get("committed"):
                fs.close()
                return restarts
            run_transaction(fs, _spill_job, retries=0)
            fs.close()
            return restarts
        except ProcessKilled:
            restarts += 1
            assert restarts <= max_resumes, "resume never converged"
            try:
                fs.close()
            except BaseException:
                pass


def _baseline_state():
    be = KillingBackend()
    fs = _spill_fs(be)
    run_transaction(fs, _spill_job, retries=0)
    fs.close()
    return _state(be)


@pytest.mark.parametrize("post", [False, True],
                         ids=["kill-before-apply", "kill-after-apply"])
def test_spill_kill_point_sweep_converges(post):
    """Kill at EVERY mutating backend call of the transaction (spill
    writes included), resume on a fresh mount, and require byte-identical
    convergence with the uninterrupted run — no leaked journal entries,
    no resurrected removed files, no lost writes."""
    baseline = _baseline_state()
    kill_points = 0
    k = 0
    while True:
        be = KillingBackend(post=post)
        be.countdown = k
        killed = False
        fs = None
        try:
            # the mount's own spill-dir setup is inside the kill window
            fs = _spill_fs(be)
            run_transaction(fs, _spill_job, retries=0)
        except ProcessKilled:
            killed = True
        if fs is not None:
            try:
                fs.close()
            except BaseException:
                pass
        if not killed and not be.dead:
            # chaos exhausted: the armed run outran the countdown
            assert _state(be) == baseline
            break
        kill_points += 1
        _run_to_completion(be)
        assert _state(be) == baseline, f"diverged at kill point {k}"
        k += 1
        assert k < 400, "sweep failed to terminate"
    # the sweep actually covered the window (dirs, writes, renames,
    # removals and the spill's own journal writes are all >10 calls)
    assert kill_points >= 10


def test_spill_kill_mid_resume_sweep_converges():
    """Preempt the job once, then kill at every mutating call of the
    RESUME pass itself (journal truncate, repair ops, re-executed
    suffix, recommit).  A second resume must still converge."""
    baseline = _baseline_state()
    k2 = 0
    covered = 0
    while True:
        be = KillingBackend()
        # first preemption at a fixed point deep in the job
        be.countdown = 12
        fs = _spill_fs(be)
        try:
            run_transaction(fs, _spill_job, retries=0)
            raise AssertionError("first run should have been killed")
        except ProcessKilled:
            pass
        try:
            fs.close()
        except BaseException:
            pass

        # resume pass, chaos re-armed
        be.revive()
        be.countdown = k2
        killed = False
        fs2 = CannyFS(be, flags=EagerFlags(flush=False), workers=2,
                      echo_errors=False)
        try:
            report = fs2.resume(".spill", flush_records=4)
            if not report.get("committed"):
                run_transaction(fs2, _spill_job, retries=0)
            fs2.close()
        except ProcessKilled:
            killed = True
            try:
                fs2.close()
            except BaseException:
                pass
        if not killed and not be.dead:
            assert _state(be) == baseline
            break
        covered += 1
        _run_to_completion(be)
        assert _state(be) == baseline, f"diverged at resume kill point {k2}"
        k2 += 1
        assert k2 < 400, "mid-resume sweep failed to terminate"
    assert covered >= 5


def test_spill_retired_after_converged_resume():
    """After convergence the spill journal is gone and the marker proves
    the committed window — a later mount must see nothing to resume."""
    be = KillingBackend()
    be.countdown = 10
    fs = _spill_fs(be)
    with pytest.raises(ProcessKilled):
        run_transaction(fs, _spill_job, retries=0)
    try:
        fs.close()
    except BaseException:
        pass
    _run_to_completion(be)
    assert not be.stat(".spill/journal.log").exists
    fs3 = CannyFS(be, flags=EagerFlags(flush=False), echo_errors=False)
    report = fs3.resume(".spill")
    assert report["committed"] and not report["resumable"]
    fs3.close()
