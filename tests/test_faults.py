"""Fault-injection tests: the paper's error paths, exercised for real.

Deterministic-seed chaos: injected EACCES/ENOSPC/EDQUOT/EIO/connection-loss
land in the deferred-error ledger, poison the engine under abort_on_error,
fail transactions at commit, and the rollback + resubmit loop converges
once the fault schedule expires.  Same seed => same ledger contents."""
import errno
import threading

import pytest

from repro.core import (CannyFS, EagerFlags, EnginePoisonedError,
                        FaultInjectingBackend, FaultPlan, FaultRule,
                        FusionPolicy, InMemoryBackend, LatencyBackend,
                        LatencyModel, LocalBackend, OpCancelledError,
                        QuotaBackend, Transaction, TransactionFailedError,
                        VirtualClock, make_fault, run_transaction)


def chaos_fs(rules, *, seed=0, workers=1, quota=None, latency=False,
             **fs_kw):
    """FaultInjecting(Quota?(Latency?(InMemory))) with a quiet ledger."""
    inner = InMemoryBackend()
    stack = inner
    clock = None
    if latency:
        clock = VirtualClock()
        stack = LatencyBackend(stack, LatencyModel(meta_ms=2.0, data_ms=2.0,
                                                   jitter_sigma=0.3,
                                                   seed=seed), clock=clock)
    if quota is not None:
        stack = QuotaBackend(stack, quota)
    plan = FaultPlan(rules, seed=seed)
    fs = CannyFS(FaultInjectingBackend(stack, plan), echo_errors=False,
                 **fs_kw)
    return inner, plan, clock, fs


def extract(fs, n=24, root="out"):
    fs.makedirs(f"{root}/deep")
    for i in range(n):
        fs.write_file(f"{root}/deep/f{i:02d}", bytes([i]) * 64)


def ledger_signature(fs):
    return [(e.kind, e.paths, getattr(e.error, "errno", None))
            for e in fs.ledger.entries()]


# ---------------------------------------------------------------------------
# FaultPlan / FaultRule semantics
# ---------------------------------------------------------------------------

def test_rule_matching_by_kind_glob_and_window():
    plan = FaultPlan([FaultRule(error="EACCES", ops=("write",),
                                path_glob="out/*", after_count=2)])
    assert plan.check("create", "out/a") is None          # kind mismatch
    assert plan.check("write", "tmp/a") is None           # glob mismatch
    assert plan.check("write", "out/a") is None           # window: call 1
    assert plan.check("write", "out/b") is None           # window: call 2
    err = plan.check("write", "out/c")                    # call 3 fires
    assert isinstance(err, OSError) and err.errno == errno.EACCES
    assert err.injected


def test_plan_max_failures_and_expire():
    plan = FaultPlan([FaultRule(error="EIO", max_failures=2)])
    fired = [plan.check("write", f"p{i}") for i in range(5)]
    assert [e is not None for e in fired] == [True, True, False, False, False]
    plan.reset()
    assert plan.check("write", "p") is not None
    plan.expire()
    assert plan.check("write", "p") is None
    assert plan.stats()["injected"] == 1  # reset cleared the first two


def test_probability_schedule_is_seeded():
    def fires(seed):
        plan = FaultPlan([FaultRule(error="EIO", probability=0.3)], seed=seed)
        return [plan.check("write", f"p{i}") is not None for i in range(64)]

    assert fires(7) == fires(7)
    assert fires(7) != fires(8)      # astronomically unlikely to collide
    assert 4 < sum(fires(7)) < 40    # rate is in the right ballpark


def test_make_fault_errnos_and_connection_loss():
    for name, eno in (("EACCES", errno.EACCES), ("ENOSPC", errno.ENOSPC),
                      ("EDQUOT", errno.EDQUOT), ("EIO", errno.EIO)):
        e = make_fault(name, "p")
        assert isinstance(e, OSError) and e.errno == eno and e.injected
    e = make_fault("ECONNRESET", "p")
    assert isinstance(e, ConnectionResetError) and e.injected
    with pytest.raises(ValueError):
        make_fault("EBOGUS", "p")


# ---------------------------------------------------------------------------
# ledger / poisoning through the engine
# ---------------------------------------------------------------------------

def test_mid_extract_eio_lands_in_ledger():
    _, plan, _, fs = chaos_fs(
        [FaultRule(error="EIO", ops=("write",), path_glob="*f07*")])
    extract(fs)
    fs.drain()
    sig = ledger_signature(fs)
    assert sig == [("write", ("out/deep/f07",), errno.EIO)]
    assert fs.stats.deferred_errors == 1
    assert fs.stats.injected_faults == 1
    assert fs.stats.error_counts == {"write": 1}
    fs.close()


def test_mid_rmtree_fault_poisons_engine_under_abort():
    # bulk_remove off: this test exercises the per-entry removal path,
    # where each unlink is its own backend call the rule can match (the
    # fused remove_tree path has its own fault tests in test_namespace)
    inner, plan, _, fs = chaos_fs(
        [FaultRule(error="EIO", ops=("unlink",), path_glob="*f03*")],
        abort_on_error=True, fusion=FusionPolicy(bulk_remove=False))
    extract(fs)
    fs.drain()
    assert not fs.poisoned
    try:
        fs.rmtree("out")   # poison can trip while rmtree is still submitting
    except EnginePoisonedError:
        pass
    fs.drain()
    assert fs.poisoned
    with pytest.raises(EnginePoisonedError):
        for i in range(50):
            fs.create(f"later{i}")
    fs.engine.reset_poison()
    fs.close()


def test_cancelled_untagged_ops_are_ledgered():
    """Poison cancels queued eager ops; even untagged ones were ACKed and
    never executed — they must not vanish from the error record."""
    class Gate(InMemoryBackend):
        def __init__(self):
            super().__init__()
            self.ev = threading.Event()
            self.entered = threading.Event()

        def chmod(self, p, m):
            self.entered.set()
            self.ev.wait()              # hold the single worker...
            raise PermissionError(p)    # ...then poison

    be = Gate()
    fs = CannyFS(be, abort_on_error=True, workers=1, echo_errors=False)
    fs.write_file("x", b"1")
    fs.drain()
    fs.chmod("x", 0o600)                # blocks the worker
    be.entered.wait()                   # provably wedged before queueing
    for i in range(5):
        fs.create(f"q{i}")              # queued behind the blocked worker
    be.ev.set()
    fs.drain()
    assert fs.poisoned
    entries = fs.ledger.entries()
    assert len(entries) == 6            # the chmod + 5 cancelled creates
    assert sum(isinstance(e.error, OpCancelledError) for e in entries) == 5
    fs.engine.reset_poison()
    fs.close()


def test_failed_op_cache_invalidation_wins():
    """Instant-failing injected ops race the ACK-time cache write; the
    error-path invalidation must always win or retries see phantoms."""
    plan = FaultPlan([FaultRule(error="EIO", ops=("mkdir",))])
    fs = CannyFS(FaultInjectingBackend(InMemoryBackend(), plan),
                 echo_errors=False)
    for i in range(50):
        fs.mkdir(f"d{i}")
    fs.drain()
    for i in range(50):
        assert fs.engine.stat_cache.get(f"d{i}") is None, f"phantom d{i}"
    fs.close()


def test_connection_loss_is_deferred_like_any_error():
    _, plan, _, fs = chaos_fs(
        [FaultRule(error="ECONNRESET", ops=("write",), max_failures=1)])
    extract(fs, n=4)
    fs.drain()
    assert len(fs.ledger) == 1
    assert isinstance(fs.ledger.entries()[0].error, ConnectionResetError)
    fs.close()


def test_sync_mode_surfaces_fault_directly():
    _, plan, _, fs = chaos_fs(
        [FaultRule(error="EACCES", ops=("create",), path_glob="out/*")],
        flags=EagerFlags.all_off(), workers=2)
    fs.makedirs("out")
    with pytest.raises(PermissionError):
        fs.create("out/x")
    assert len(fs.ledger) == 0   # sync errors are the caller's, not deferred
    fs.close()


def test_engine_keeps_caller_provided_empty_ledger():
    """Regression: an empty ErrorLedger is falsy (__len__ == 0); the engine
    must not swap a caller's ledger for a default echoing one."""
    from repro.core import EagerIOEngine, ErrorLedger
    led = ErrorLedger(echo=False)
    eng = EagerIOEngine(InMemoryBackend(), ledger=led)
    assert eng.ledger is led
    eng.close()


# ---------------------------------------------------------------------------
# quota backend
# ---------------------------------------------------------------------------

def test_quota_edquot_emerges_mid_write_and_unlink_releases():
    inner = InMemoryBackend()
    q = QuotaBackend(inner, 1000)
    q.mkdir("d")
    q.write_at("d/a", 0, b"x" * 600)
    with pytest.raises(OSError) as ei:
        q.write_at("d/b", 0, b"y" * 600)     # 1200 > 1000
    assert ei.value.errno == errno.EDQUOT
    assert not getattr(ei.value, "injected", False)  # organic, not chaos
    assert q.used == 600
    q.unlink("d/a")
    assert q.used == 0
    q.write_at("d/b", 0, b"y" * 900)          # fits after the release
    assert inner.snapshot()["files"]["d/b"] == b"y" * 900


def test_quota_rewrite_truncate_and_rename_accounting():
    q = QuotaBackend(InMemoryBackend(), 1000)
    q.mkdir("d")
    q.write_at("d/a", 0, b"x" * 400)
    q.write_at("d/a", 100, b"y" * 100)    # within the charged range: free
    assert q.used == 400
    q.truncate("d/a", 50)
    assert q.used == 50
    q.rename("d/a", "d/b")
    assert q.used == 50
    q.unlink("d/b")
    assert q.used == 0


def test_quota_uncharges_when_inner_op_fails():
    """A charge whose delegated write never landed must be backed out —
    otherwise failing ops leak budget no rollback can release."""
    q = QuotaBackend(InMemoryBackend(), 1000)
    with pytest.raises(FileNotFoundError):
        q.write_at("missing_dir/f", 0, b"x" * 400)   # parent absent
    assert q.used == 0
    with pytest.raises(FileNotFoundError):
        q.truncate("missing", 400)
    assert q.used == 0
    q.mkdir("d")
    q.write_at("d/f", 0, b"x" * 900)                 # budget still intact
    assert q.used == 900


def test_quota_create_truncates_and_releases_old_charge():
    q = QuotaBackend(InMemoryBackend(), 150)
    q.mkdir("d")
    q.write_at("d/f", 0, b"x" * 100)
    q.create("d/f")                    # O_TRUNC rewrite: bytes are gone
    assert q.used == 0
    q.write_at("d/f", 0, b"y" * 10)
    q.write_at("d/g", 0, b"z" * 100)   # 110 fits: no spurious EDQUOT
    assert q.used == 110


def test_fault_rule_matches_rename_destination():
    plan = FaultPlan([FaultRule(error="EIO", ops=("rename",),
                                path_glob="out/*")])
    be = FaultInjectingBackend(InMemoryBackend(), plan)
    be.mkdir("tmp")
    be.mkdir("out")
    be.create("tmp/x")
    with pytest.raises(OSError):
        be.rename("tmp/x", "out/x")    # dst matches the glob
    be.rename("tmp/x", "tmp/y")        # neither endpoint matches: fine


def test_quota_rename_over_existing_releases_dst_charge():
    q = QuotaBackend(InMemoryBackend(), 1000)
    q.mkdir("d")
    q.write_at("d/a", 0, b"x" * 100)
    q.write_at("d/b", 0, b"y" * 200)
    q.rename("d/a", "d/b")     # overwrite: d/b's old 200 bytes are gone
    assert q.used == 100
    q.unlink("d/b")
    assert q.used == 0


def test_quota_hardlink_cannot_escape_budget():
    """Per-path accounting charges a link like a copy; unlinking one name
    must not free bytes still reachable through the other."""
    q = QuotaBackend(InMemoryBackend(), 1000)
    q.mkdir("d")
    q.write_at("d/a", 0, b"x" * 400)
    q.link("d/a", "d/b")
    assert q.used == 800          # conservative double-count
    q.unlink("d/a")
    assert q.used == 400          # 'd/b' still holds its charge
    with pytest.raises(OSError) as ei:
        q.write_at("d/c", 0, b"y" * 700)   # 400 + 700 > 1000
    assert ei.value.errno == errno.EDQUOT
    q.unlink("d/b")
    assert q.used == 0


def test_quota_exhaustion_fails_transaction_and_rollback_releases():
    inner = InMemoryBackend()
    q = QuotaBackend(inner, 1500)
    fs = CannyFS(q, echo_errors=False)

    def body(fs):
        extract(fs, n=40)   # 40 * 64 = 2560 bytes > 1500 budget

    # eager writes defer the EDQUOT into the ledger; commit surfaces it
    with pytest.raises(TransactionFailedError) as ei:
        run_transaction(fs, body, retries=2)
    assert all(e.error.errno == errno.EDQUOT for e in ei.value.entries)
    assert inner.snapshot()["files"] == {}    # rolled back every attempt
    assert q.used == 0                        # budget fully released
    assert fs.stats.rollbacks == 3
    fs.close()


# ---------------------------------------------------------------------------
# QuotaBackend: inode limits (ROADMAP item e)
# ---------------------------------------------------------------------------

def test_inode_quota_enospc_and_charge_release_symmetry():
    """Every create/mkdir/symlink/link charges one inode, ENOSPC on
    exhaustion; unlink/rmdir release — the charge/release cycle is exactly
    symmetric, so the budget is reusable indefinitely."""
    q = QuotaBackend(InMemoryBackend(), 1 << 20, max_inodes=3)
    q.mkdir("d")
    q.create("d/a")
    q.symlink("t", "d/s")
    assert q.inodes_used == 3 and q.inodes_remaining == 0
    with pytest.raises(OSError) as ei:
        q.create("d/b")
    assert ei.value.errno == errno.ENOSPC
    assert q.enospc_count == 1
    with pytest.raises(OSError):
        q.mkdir("d2")
    with pytest.raises(OSError):
        q.link("d/a", "d/hard")
    # release one, and the budget admits exactly one again
    q.unlink("d/s")
    assert q.inodes_used == 2
    q.create("d/b")
    assert q.inodes_used == 3
    # full teardown returns the budget to zero
    q.unlink("d/a")
    q.unlink("d/b")
    q.rmdir("d")
    assert q.inodes_used == 0 and q.inodes_remaining == 3
    assert q.used == 0


def test_inode_quota_recharge_and_failed_delegate_uncharges():
    inner = InMemoryBackend()
    q = QuotaBackend(inner, 1 << 20, max_inodes=2)
    q.create("a")
    q.create("a")                 # O_TRUNC re-create: no second charge
    assert q.inodes_used == 1
    with pytest.raises(FileNotFoundError):
        q.create("missing_parent/x")   # inner raised: charge backed out
    assert q.inodes_used == 1
    with pytest.raises(FileNotFoundError):
        q.mkdir("nope/deep")
    assert q.inodes_used == 1


def test_inode_quota_rename_moves_charge_and_overwrite_releases():
    q = QuotaBackend(InMemoryBackend(), 1 << 20, max_inodes=2)
    q.create("a")
    q.create("b")
    assert q.inodes_used == 2
    q.rename("a", "b")            # overwrite: b's old inode charge released
    assert q.inodes_used == 1
    q.unlink("b")
    assert q.inodes_used == 0


def test_inode_quota_released_by_remove_tree_and_rollback_converges():
    """The fused bulk removal and transaction rollback both release inode
    charges, so the roll-back-and-resubmit loop converges instead of
    wedging on a phantom-full namespace."""
    inner = InMemoryBackend()
    q = QuotaBackend(inner, 1 << 20, max_inodes=10)
    fs = CannyFS(q, echo_errors=False)

    def body(fs):
        extract(fs, n=20)         # 20 files + 2 dirs > 10 inodes

    with pytest.raises(TransactionFailedError) as ei:
        run_transaction(fs, body, retries=2)
    assert all(e.error.errno == errno.ENOSPC for e in ei.value.entries)
    assert inner.snapshot()["files"] == {}
    assert q.inodes_used == 0     # rollback released every charge
    # a small tree now fits, and a fused remove_tree releases it again
    fs.makedirs("ok")
    for i in range(4):
        fs.write_file(f"ok/f{i}", b"v")
    fs.drain()
    assert q.inodes_used == 5
    fs.rmtree("ok")
    fs.drain()
    assert fs.stats.bulk_removes >= 1
    assert q.inodes_used == 0 and q.used == 0
    fs.close()


# ---------------------------------------------------------------------------
# fault stack over the real-FS backend (ROADMAP item b)
# ---------------------------------------------------------------------------

def test_fault_stack_on_local_backend_extract_rmtree(tmp_path):
    """Integration realism: FaultInjecting(Quota(Local)) against a real
    tmpdir, running the extract+rmtree workload under run_transaction
    with raise, short (torn write) and delay rules — the transactional
    loop must converge to a byte-correct on-disk tree, and the removal
    must leave the directory empty on the real filesystem."""
    import os
    base = LocalBackend(str(tmp_path / "mnt"))
    plan = FaultPlan([
        FaultRule(error="EIO", ops=("write",), path_glob="out/*",
                  after_count=3, max_failures=2),
        FaultRule(outcome="short", ops=("write",), short_fraction=0.5,
                  after_count=8, max_failures=1),
        FaultRule(error="EACCES", ops=("create",), path_glob="*f05*",
                  max_failures=1),
        FaultRule(outcome="delay", ops=("mkdir",), delay_s=0.001),
    ], seed=7)
    stack = FaultInjectingBackend(
        QuotaBackend(base, 1 << 20, max_inodes=256), plan)
    fs = CannyFS(stack, echo_errors=False, workers=4)
    payloads = {f"out/deep/f{i:02d}": bytes([i]) * 200 for i in range(12)}

    def body(fs):
        fs.makedirs("out/deep")
        for path, data in payloads.items():
            with fs.open(path, "wb") as h:
                for lo in range(0, len(data), 64):
                    h.write(data[lo:lo + 64])

    run_transaction(fs, body, retries=6)
    fs.drain()
    assert plan.injected > 0                      # chaos actually fired
    assert fs.stats.retries >= 1
    root = str(tmp_path / "mnt")
    for path, data in payloads.items():           # byte-correct on disk
        with open(os.path.join(root, path), "rb") as f:
            assert f.read() == data
    plan.expire()
    fs.rmtree("out")
    fs.drain()
    assert len(fs.ledger) == 0
    assert os.listdir(root) == []                 # really gone from the FS
    fs.close()


def test_local_backend_readdir_plus_and_remove_tree(tmp_path):
    """The new vectored primitives on the real FS: one-scandir listings
    with attributes, and the one-walk bulk removal."""
    base = LocalBackend(str(tmp_path / "m"))
    base.mkdir("d")
    base.create("d/f")
    base.write_at("d/f", 0, b"xyz")
    base.mkdir("d/sub")
    base.symlink("f", "d/ln")
    listing = base.readdir_plus("d")
    assert [n for n, _ in listing] == ["f", "ln", "sub"]
    attrs = dict(listing)
    assert attrs["sub"].is_dir and attrs["ln"].is_symlink
    assert attrs["f"].size == 3
    assert base.remove_tree("d") == 4             # f, ln, sub, d
    assert base.remove_tree("d") == 0             # absence-tolerant
    assert not base.stat("d").exists


# ---------------------------------------------------------------------------
# transaction rollback / resubmit under faults
# ---------------------------------------------------------------------------

def test_rollback_and_retry_succeeds_after_plan_exhausts():
    inner, plan, _, fs = chaos_fs(
        [FaultRule(error="EIO", ops=("write",), path_glob="out/*",
                   max_failures=1)])
    run_transaction(fs, extract, retries=3)
    fs.drain()
    snap = inner.snapshot()
    assert len(snap["files"]) == 24
    assert fs.stats.retries == 1 and fs.stats.rollbacks == 1
    assert plan.injected == 1
    assert len(fs.ledger) == 0
    fs.close()


def test_retry_succeeds_once_plan_expires():
    inner, plan, _, fs = chaos_fs(
        [FaultRule(error="ENOSPC", ops=("write", "create"))])  # always fails
    attempts = []

    def body(fs):
        attempts.append(1)
        if len(attempts) == 2:
            plan.expire()        # the outage ends between attempts
        extract(fs, n=8)

    run_transaction(fs, body, retries=4)
    assert len(attempts) == 2    # one failed attempt, one clean
    assert len(inner.snapshot()["files"]) == 8
    fs.close()


def test_rollback_clears_only_transaction_scoped_ledger_entries():
    """The satellite fix: a rollback must not wipe deferred errors recorded
    *before* the transaction began."""
    _, plan, _, fs = chaos_fs(
        [FaultRule(error="EACCES", ops=("chmod",), max_failures=1),
         FaultRule(error="EIO", ops=("write",), path_glob="out/*",
                   max_failures=1)])
    fs.create("pre")
    fs.chmod("pre", 0o600)       # rule 1: pre-transaction deferred error
    fs.drain()
    assert len(fs.ledger) == 1
    txn = Transaction(fs)
    with pytest.raises(TransactionFailedError):
        with txn:
            extract(fs, n=6)     # rule 2 fires inside the region
    assert not txn.rolled_back
    txn.rollback()
    sig = ledger_signature(fs)
    assert sig == [("chmod", ("pre",), errno.EACCES)], \
        "pre-transaction ledger entry must survive rollback"
    fs.close()


def test_inflight_pre_txn_error_survives_rollback():
    """An eager op still in flight when the transaction starts must have
    its deferred error recorded *outside* the region's ledger scope."""
    _, plan, _, fs = chaos_fs(
        [FaultRule(error="EACCES", ops=("chmod",), max_failures=1),
         FaultRule(error="EIO", ops=("write",), path_glob="out/*",
                   max_failures=1)],
        latency=True)   # latency keeps the chmod in flight at __enter__
    fs.create("pre")
    fs.chmod("pre", 0o600)       # eager; fails in the background, untagged
    txn = Transaction(fs)
    with pytest.raises(TransactionFailedError):
        with txn:
            extract(fs, n=6)
    if not txn.rolled_back:
        txn.rollback()
    assert ("chmod", ("pre",), errno.EACCES) in ledger_signature(fs)
    fs.close()


def test_non_transient_body_error_is_not_retried():
    """FileNotFoundError is a deterministic body bug: rolled back once,
    propagated immediately — no pointless resubmissions."""
    inner = InMemoryBackend()
    fs = CannyFS(inner, echo_errors=False)
    attempts = []

    def body(fs):
        attempts.append(1)
        fs.makedirs("out")
        fs.read_file("out/misspelled")   # sync read -> ENOENT

    with pytest.raises(FileNotFoundError):
        run_transaction(fs, body, retries=3)
    assert len(attempts) == 1
    assert fs.stats.retries == 0
    assert "out" not in inner.snapshot()["dirs"]   # still rolled back
    fs.close()


def test_deferred_deterministic_bug_is_not_retried():
    """Eager mode must match sync mode: a body bug whose ENOENT is deferred
    into the commit's TransactionFailedError propagates after one attempt —
    and is still rolled back, leaving a clean, usable mount."""
    inner = InMemoryBackend()
    fs = CannyFS(inner, echo_errors=False)
    attempts = []

    def body(fs):
        attempts.append(1)
        fs.mkdir("out")                           # journaled output
        fs.write_file("misspelled_dir/x", b"d")   # eager: ENOENT deferred

    with pytest.raises(TransactionFailedError) as ei:
        run_transaction(fs, body, retries=3)
    assert len(attempts) == 1
    assert all(isinstance(e.error, FileNotFoundError)
               for e in ei.value.entries)
    # the failed region was rolled back despite not being retried
    snap = inner.snapshot()
    assert "out" not in snap["dirs"] and snap["files"] == {}
    assert len(fs.ledger) == 0
    assert not fs.poisoned
    fs.write_file("after", b"ok")                 # mount still usable
    fs.drain()
    assert inner.snapshot()["files"]["after"] == b"ok"
    fs.close()


def test_deterministic_bug_under_abort_on_error_not_retried():
    """A deterministic ENOENT that trips abort_on_error must not buy a
    full retry budget via the poison path — one rollback, then propagate."""
    fs = CannyFS(InMemoryBackend(), abort_on_error=True, workers=1,
                 echo_errors=False)
    attempts = []

    def body(fs):
        attempts.append(1)
        fs.write_file("misspelled_dir/x", b"d")  # deferred ENOENT -> poison
        fs.drain()
        fs.write_file("more", b"y")              # poisoned: raises or cancels

    with pytest.raises((TransactionFailedError, EnginePoisonedError)):
        run_transaction(fs, body, retries=3)
    assert len(attempts) == 1
    assert not fs.poisoned                       # rollback un-poisoned it
    fs.close()


def test_cascade_errors_ride_along_with_transient_root_cause():
    """A faulted mkdir makes every op under it fail with ENOENT; the commit
    failure mixes deterministic-looking cascades with the transient root —
    it must still be retried (and converge once the fault expires)."""
    inner, plan, _, fs = chaos_fs(
        [FaultRule(error="EIO", ops=("mkdir",), path_glob="out*",
                   max_failures=1)])
    run_transaction(fs, lambda f: extract(f, n=6), retries=3)
    assert len(inner.snapshot()["files"]) == 6
    assert fs.stats.retries == 1
    fs.close()


def test_pre_activation_work_is_not_journaled():
    """Work racing the transaction open (slot claimed, _active not yet
    set) is pre-region and must not be rolled back later."""
    inner = InMemoryBackend()
    fs = CannyFS(inner, echo_errors=False)
    txn = Transaction(fs)
    fs._txn = txn                  # slot claimed, not yet activated
    fs.write_file("pre_region", b"1")
    fs._txn = None
    fs.drain()
    assert txn._created == {}, "racing pre-region create was journaled"
    assert inner.snapshot()["files"]["pre_region"] == b"1"
    fs.close()


def test_rollback_keeps_pre_existing_file_opened_for_write():
    """Rewriting a pre-transaction file inside the region must not delete
    it on rollback — the journal records namespace creations only."""
    inner = InMemoryBackend()
    inner.mkdir("keep")
    inner.create("keep/data.bin")
    inner.write_at("keep/data.bin", 0, b"old")
    fs = CannyFS(inner, echo_errors=False)
    txn = Transaction(fs)
    try:
        with txn:
            fs.write_file("keep/data.bin", b"new")   # open('wb') truncates
            raise RuntimeError("job failed")
    except RuntimeError:
        pass
    snap = inner.snapshot()
    assert "keep/data.bin" in snap["files"], \
        "pre-existing file must survive rollback (content not restored)"
    assert txn.rollback_leftovers == []
    fs.close()


def test_transaction_open_does_not_stall_on_background_io():
    """Opening a transaction must not act as a global I/O barrier."""
    import time
    be = InMemoryBackend()
    lat = LatencyBackend(be, LatencyModel(meta_ms=300.0, data_ms=300.0,
                                          jitter_sigma=0.0))
    fs = CannyFS(lat, echo_errors=False)
    fs.write_file("bg", b"x")        # ~0.6s of real background latency
    t0 = time.monotonic()
    with Transaction(fs):
        dt = time.monotonic() - t0
    assert dt < 0.2, f"transaction open stalled {dt:.2f}s on background I/O"
    fs.close()


def test_interleaved_region_rollback_does_not_wipe_other_region():
    """Region tags, not serial ranges: transaction A's late rollback (after
    B already opened) must clear only A's entries — B's deferred error
    still fails B's commit."""
    _, plan, _, fs = chaos_fs([FaultRule(error="EIO", ops=("write",))])
    a = Transaction(fs)
    with pytest.raises(TransactionFailedError):
        with a:
            fs.write_file("a_out", b"1")
    assert not a.rolled_back          # commit failed; rollback still pending
    b = Transaction(fs)               # opens while A is un-rolled-back
    with pytest.raises(TransactionFailedError) as ei:
        with b:
            fs.write_file("b_out", b"2")
            fs.drain()
            a.rollback()              # A's scoped clear runs mid-region-B
            assert [e.paths for e in b.errors()] == [("b_out",)]
    assert [e.paths for e in ei.value.entries] == [("b_out",)]
    b.rollback()
    assert len(fs.ledger) == 0
    fs.close()


def test_leftovers_surface_even_when_retry_succeeds():
    """A leak from a failed attempt must not vanish behind a later success:
    it lands in the ledger as a RollbackLeakError for teardown reporting."""
    from repro.core import RollbackLeakError
    inner, plan, _, fs = chaos_fs(
        [FaultRule(error="EIO", ops=("write",), path_glob="tmp_a",
                   max_failures=1),
         FaultRule(error="EACCES", ops=("unlink",), path_glob="tmp_a")])
    run_transaction(fs, lambda f: f.write_file("tmp_a", b"v"), retries=2)
    leaks = [e for e in fs.ledger.entries()
             if isinstance(e.error, RollbackLeakError)]
    assert len(leaks) == 1 and leaks[0].paths == ("tmp_a",)
    assert inner.snapshot()["files"]["tmp_a"] == b"v"   # job did succeed
    fs.close()


def test_rollback_verification_reports_leftovers():
    """A path whose unlink keeps failing is reported, not silently leaked."""
    _, plan, _, fs = chaos_fs(
        [FaultRule(error="EIO", ops=("write",), path_glob="*f01*",
                   max_failures=1),
         FaultRule(error="EACCES", ops=("unlink",), path_glob="*f00*")])
    txn = Transaction(fs)
    with pytest.raises(TransactionFailedError):
        with txn:
            extract(fs, n=4)
    txn.rollback()
    # the stuck file plus its (hence non-empty) ancestor directories
    assert txn.rollback_leftovers == ["out/deep/f00", "out/deep", "out"]
    assert fs.stats.rollback_leftovers == 3
    fs.close()


def test_run_transaction_attaches_leftovers_to_raised_error():
    """Verified on-backend leakage must survive run_transaction — callers
    only ever see the raised exception."""
    _, plan, _, fs = chaos_fs(
        [FaultRule(error="EIO", ops=("write",), path_glob="*f01*"),
         FaultRule(error="EACCES", ops=("unlink",), path_glob="*f00*")])
    with pytest.raises(TransactionFailedError) as ei:
        run_transaction(fs, lambda f: extract(f, n=4), retries=1)
    # attempt 1's verified leakage is accumulated onto the final error even
    # though attempt 2 (which didn't re-create the stuck file) saw none
    assert ei.value.rollback_leftovers == ["out/deep/f00", "out/deep", "out"]
    assert fs.stats.rollback_leftovers == 3       # all from attempt 1
    fs.close()


def test_rollback_through_full_decorator_stack():
    """Latency (virtual clock) + quota + faults, all at once — and the
    retry converges with an intact namespace."""
    inner, plan, clock, fs = chaos_fs(
        [FaultRule(error="EIO", ops=("write", "create"), probability=0.2,
                   max_failures=4)],
        latency=True, quota=4096, workers=8, seed=3)
    run_transaction(fs, lambda f: extract(f, n=16), retries=6)
    fs.drain()
    assert len(inner.snapshot()["files"]) == 16
    assert clock.now() > 0.0          # latency was simulated, not slept
    assert len(fs.ledger) == 0
    fs.close()


def test_poison_from_untagged_op_cannot_let_commit_succeed():
    """An untagged eager op failing mid-region poisons the engine and
    cancels the region's queued ops; the cancellations are ledgered under
    the region, so commit cannot claim durability."""
    fs = CannyFS(InMemoryBackend(), abort_on_error=True, workers=1,
                 echo_errors=False)
    txn = Transaction(fs)
    started = threading.Event()
    release = threading.Event()

    def boom():
        started.set()       # the single worker is provably inside boom...
        release.wait()      # ...so everything submitted below stays queued
        raise PermissionError("background job")

    with pytest.raises((TransactionFailedError, EnginePoisonedError)):
        with txn:
            # a background op outside any transaction (region=None)
            fs.engine.submit("chmod", ("x",), boom, eager=True)
            started.wait()
            for i in range(20):
                fs.write_file(f"out{i}", b"y")
            release.set()
    assert not txn.committed
    fs.engine.reset_poison()
    fs.close()


def test_checkpoint_failed_step_can_be_resaved():
    """A save that failed once must not poison every future save of the
    same step with its stale ledger entries."""
    np = pytest.importorskip("numpy")
    from repro.checkpoint import COMMIT_FILE, TransactionalCheckpointManager
    inner = InMemoryBackend()
    plan = FaultPlan([FaultRule(error="EIO", ops=("write",),
                                path_glob="*w.bin", max_failures=1)])
    fs = CannyFS(FaultInjectingBackend(inner, plan), echo_errors=False)
    mgr = TransactionalCheckpointManager(fs, "ck")
    state = {"w": np.ones(8, np.float32)}
    res1 = mgr.save(3, state, block=True)
    assert not res1.ok
    res2 = mgr.save(3, state, block=True)   # fault expired: must succeed
    assert res2.ok, res2.error
    assert any(COMMIT_FILE in p for p in inner.snapshot()["files"])
    fs.close()


def test_checkpoint_io_is_detached_from_user_transaction():
    """Checkpoint files have their own commit protocol: a failed save under
    an open user transaction must not fail that transaction's commit, be
    rolled back by it, or poison future saves of the step."""
    np = pytest.importorskip("numpy")
    from repro.checkpoint import COMMIT_FILE, TransactionalCheckpointManager
    inner = InMemoryBackend()
    plan = FaultPlan([FaultRule(error="EIO", ops=("write",),
                                path_glob="*w.bin", max_failures=1)])
    fs = CannyFS(FaultInjectingBackend(inner, plan), echo_errors=False)
    mgr = TransactionalCheckpointManager(fs, "ck")
    state = {"w": np.ones(8, np.float32)}
    with Transaction(fs) as txn:
        fs.write_file("user_out", b"u")
        res1 = mgr.save(3, state, block=True)   # fails: injected EIO
    assert txn.committed, "user txn must not inherit checkpoint errors"
    assert not res1.ok
    res2 = mgr.save(3, state, block=True)       # fault expired
    assert res2.ok, res2.error
    assert any(COMMIT_FILE in p for p in inner.snapshot()["files"])
    assert inner.snapshot()["files"]["user_out"] == b"u"
    fs.close()


def test_prefetch_stat_fault_does_not_fail_transaction():
    """readdir prefetch is advisory cache warm-up: its failures must not
    land in the ledger and condemn an otherwise-successful region."""
    inner = InMemoryBackend()
    inner.mkdir("pre")
    inner.create("pre/a")
    inner.create("pre/b")
    plan = FaultPlan([FaultRule(error="EIO", ops=("stat",),
                                path_glob="pre/*")])
    fs = CannyFS(FaultInjectingBackend(inner, plan), echo_errors=False)
    with Transaction(fs) as txn:
        assert fs.readdir("pre") == ["a", "b"]   # prefetch stats fault
        fs.write_file("out", b"x")
    assert txn.committed
    assert len(fs.ledger) == 0
    fs.close()


def test_save_on_poisoned_engine_reports_failure_not_raise():
    """A poisoned mount must fail the save via SaveResult (and recover),
    not raise EnginePoisonedError into the train loop."""
    np = pytest.importorskip("numpy")
    from repro.checkpoint import TransactionalCheckpointManager

    class Bad(InMemoryBackend):
        def chmod(self, p, m):
            raise PermissionError(p)

    fs = CannyFS(Bad(), abort_on_error=True, workers=2, echo_errors=False)
    mgr = TransactionalCheckpointManager(fs, "ck")
    fs.create("x")
    fs.drain()
    fs.chmod("x", 0o600)
    fs.drain()
    assert fs.poisoned
    res = mgr.save(1, {"w": np.ones(4, np.float32)}, block=True)
    assert not res.ok and "Poisoned" in res.error
    res2 = mgr.save(2, {"w": np.ones(4, np.float32)}, block=True)
    assert res2.ok                       # abort_save un-poisoned the mount
    fs.close()


def test_checkpoint_survives_poison_cancelling_its_writes():
    """Poison from an unrelated op cancels the checkpoint's queued writes:
    no COMMIT may be written, the failure must be reported (not crash the
    finalizer thread), and the result must still be recorded."""
    np = pytest.importorskip("numpy")
    from repro.checkpoint import COMMIT_FILE, TransactionalCheckpointManager

    class Gate(InMemoryBackend):
        def __init__(self):
            super().__init__()
            self.ev = threading.Event()
            self.entered = threading.Event()

        def chmod(self, p, m):
            self.entered.set()
            self.ev.wait()
            raise PermissionError(p)

    be = Gate()
    fs = CannyFS(be, abort_on_error=True, workers=1, echo_errors=False)
    mgr = TransactionalCheckpointManager(fs, "ck")
    fs.write_file("unrelated", b"1")
    fs.drain()
    fs.chmod("unrelated", 0o600)      # wedge the worker, then poison
    be.entered.wait()
    res = mgr.save(1, {"w": np.ones(8, np.float32)})
    be.ev.set()
    mgr.wait_for_save()
    assert not res.ok and res.error
    assert not any(COMMIT_FILE in p for p in be.snapshot()["files"])
    assert len(mgr.results) == 1      # finalizer reported despite poison
    fs.engine.reset_poison()
    fs.close()


def test_checkpoint_commit_write_failure_is_not_reported_ok():
    """A fault on the COMMIT marker write must fail the save: a durable-
    looking checkpoint that restore() will never see is the worst outcome."""
    np = pytest.importorskip("numpy")
    from repro.checkpoint import COMMIT_FILE, TransactionalCheckpointManager
    inner = InMemoryBackend()
    plan = FaultPlan([FaultRule(error="ENOSPC", ops=("write",),
                                path_glob=f"*{COMMIT_FILE}")])
    fs = CannyFS(FaultInjectingBackend(inner, plan), echo_errors=False)
    mgr = TransactionalCheckpointManager(fs, "ck")
    res = mgr.save(1, {"w": np.ones(8, np.float32)}, block=True)
    assert not res.ok
    assert "ENOSPC" in res.error or "injected" in res.error
    assert not any(COMMIT_FILE in p for p in inner.snapshot()["files"])
    fs.close()


# ---------------------------------------------------------------------------
# determinism: same seed -> same ledger, three runs in a row
# ---------------------------------------------------------------------------

def chaos_run(seed):
    """Probabilistic chaos with a per-file drain: execution order equals
    submission order, so the seeded fault schedule — and thus the ledger —
    replays exactly, independent of worker scheduling."""
    inner, plan, _, fs = chaos_fs(
        [FaultRule(error="EIO", ops=("write", "chmod"), probability=0.15)],
        seed=seed, workers=4)
    fs.makedirs("out/deep")
    for i in range(30):
        fs.write_file(f"out/deep/f{i:02d}", bytes([i]) * 32)
        fs.chmod(f"out/deep/f{i:02d}", 0o644)
        fs.drain()
    sig = ledger_signature(fs)
    stats = (fs.stats.deferred_errors, fs.stats.injected_faults,
             plan.injected)
    fs.close()
    return sig, stats


def test_same_seed_same_ledger_three_runs():
    runs = [chaos_run(seed=42) for _ in range(3)]
    assert runs[0][0], "schedule should inject at least one fault"
    assert runs[0] == runs[1] == runs[2]


def test_different_seed_different_schedule():
    assert chaos_run(seed=1)[0] != chaos_run(seed=2)[0]
