"""End-to-end behaviour tests for the paper's system: the three headline
claims, at test scale — (1) eager mode hides latency, (2) results are
byte-identical to synchronous execution, (3) failed jobs roll back and
retry cleanly."""
from repro.core import (CannyFS, EagerFlags, InMemoryBackend, LatencyBackend,
                        LatencyModel, SimClock, run_transaction)


def _extract(fs, n=60):
    fs.makedirs("tree/src")
    for i in range(n):
        fs.write_file(f"tree/src/f{i:03d}", b"x" * 256)
        fs.chmod(f"tree/src/f{i:03d}", 0o644)


def _remote(seed=0, clock=None):
    return LatencyBackend(InMemoryBackend(),
                          LatencyModel(meta_ms=2.0, data_ms=2.0,
                                       jitter_sigma=0.0, seed=seed),
                          **({"clock": clock} if clock is not None else {}))


def test_eager_extraction_is_faster_and_identical():
    # the latency-hiding claim is measured on the discrete-event clock:
    # SimClock.makespan() is the simulated schedule's critical path, a
    # pure function of the op stream and the model seed — the old
    # wall-clock measure flaked whenever a loaded CI box stalled the
    # eager run's real threads
    times, snaps = {}, {}
    for mode, flags in (("canny", EagerFlags()),
                        ("direct", EagerFlags.all_off())):
        clock = SimClock()
        remote = _remote(clock=clock)
        fs = CannyFS(remote, flags=flags, max_inflight=4000, workers=32)
        _extract(fs)
        fs.close()
        times[mode] = clock.makespan()
        snaps[mode] = remote.inner.snapshot()
    assert snaps["canny"] == snaps["direct"]
    # paper: >80% reduction; accept >60% at this tiny scale
    assert times["canny"] < 0.4 * times["direct"], times


def test_rmtree_accelerated_and_complete():
    remote = _remote(1)
    fs = CannyFS(remote, max_inflight=4000, workers=32)
    _extract(fs, n=40)
    fs.drain()
    fs.rmtree("tree")
    fs.close()
    snap = remote.inner.snapshot()
    assert snap["files"] == {} and snap["dirs"] == {""}
    assert len(fs.ledger) == 0


def test_failed_job_rolls_back_and_retries():
    class Flaky(InMemoryBackend):
        fails = 2

        def write_at(self, p, o, d):
            if p.endswith("f005") and Flaky.fails > 0:
                Flaky.fails -= 1
                raise OSError(5, "transient I/O error")
            return super().write_at(p, o, d)

    be = Flaky()
    fs = CannyFS(be)
    run_transaction(fs, lambda fs: _extract(fs, n=10), retries=3)
    fs.close()
    assert len(be.snapshot()["files"]) == 10
