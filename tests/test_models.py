"""Per-architecture smoke tests (reduced configs): one forward/train step on
CPU asserting shapes + no NaNs, plus decode-vs-forward consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import (cross_entropy, decode_step, forward_train,
                          init_cache, init_params, prefill)

pytestmark = pytest.mark.slow  # jax model smoke tests: opt-in (see pytest.ini)

RNG = jax.random.PRNGKey(0)
B, S = 2, 32


def make_batch(cfg, rng=RNG, with_labels=True):
    batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}
    if with_labels:
        batch["labels"] = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    if cfg.modality == "audio_stub":
        batch["features"] = jax.random.normal(rng, (B, S, 512))
        batch["loss_mask"] = jnp.ones((B, S), bool)
    if cfg.modality == "vision_stub":
        n_img = 4
        batch["vision_embeds"] = jax.random.normal(rng, (B, n_img,
                                                         cfg.d_model))
        batch["vision_mask"] = jnp.zeros((B, S), bool).at[:, 2:2 + n_img].set(
            True)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch):
    cfg = get_smoke_config(arch)
    params = init_params(RNG, cfg)
    logits, aux = forward_train(params, make_batch(cfg), cfg,
                                dtype=jnp.float32)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits)).any()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    """One fwd+bwd+update on the single CPU device."""
    from repro.launch.mesh import make_debug_mesh
    from repro.optim import init_opt_state
    from repro.train.steps import TrainConfig, make_train_step
    cfg = get_smoke_config(arch)
    mesh = make_debug_mesh(1)
    params = init_params(RNG, cfg)
    opt = init_opt_state(params)
    step = make_train_step(cfg, mesh, TrainConfig(dtype=jnp.float32,
                                                  remat_policy="none"))
    with mesh:
        new_params, new_opt, metrics = jax.jit(step)(
            params, opt, make_batch(cfg), jnp.float32(1e-3))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # parameters actually moved
    delta = sum(float(jnp.sum(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(new_params),
                                jax.tree.leaves(params)))
    assert delta > 0


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if get_smoke_config(a).supports_decode()])
def test_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    if cfg.num_experts:   # capacity drops are train/serve-asymmetric
        cfg = dataclasses.replace(cfg,
                                  capacity_factor=float(cfg.num_experts))
    params = init_params(jax.random.PRNGKey(1), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, 24), 0,
                              cfg.vocab_size)
    full, _ = forward_train(params, {"tokens": toks}, cfg, dtype=jnp.float32)
    cache = init_cache(cfg, B, 64, jnp.float32)
    pre = 16
    last, cache = prefill(params, {"tokens": toks[:, :pre]}, cache, cfg,
                          dtype=jnp.float32)
    scale = float(jnp.max(jnp.abs(full)))
    errs = [float(jnp.max(jnp.abs(last - full[:, pre - 1])))]
    for t in range(pre, 24):
        lg, cache = decode_step(params, toks[:, t:t + 1], cache, cfg,
                                dtype=jnp.float32)
        errs.append(float(jnp.max(jnp.abs(lg - full[:, t]))))
    assert max(errs) / scale < 2e-4, (arch, max(errs) / scale)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_scan_equals_unrolled(arch):
    """The dry-run's unrolled lowering is mathematically identical to the
    production scanned stack."""
    cfg = get_smoke_config(arch)
    params = init_params(RNG, cfg)
    batch = make_batch(cfg, with_labels=False)
    a, _ = forward_train(params, batch, cfg, dtype=jnp.float32,
                         scan_layers=True)
    b, _ = forward_train(params, batch, cfg, dtype=jnp.float32,
                         scan_layers=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_param_count_sanity(arch):
    """The analytic parameter count matches the real (eval_shape) count on
    the FULL published config — guards both the config transcription and
    the roofline's MODEL_FLOPS."""
    from repro.models import param_specs
    cfg = get_config(arch)
    pshape = param_specs(cfg)
    real = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(pshape))
    analytic = cfg.param_count()
    assert abs(real - analytic) / real < 0.02, (arch, real, analytic)


def test_loss_mask_and_z_loss():
    cfg = get_smoke_config("hubert-xlarge")
    params = init_params(RNG, cfg)
    batch = make_batch(cfg)
    logits, _ = forward_train(params, batch, cfg, dtype=jnp.float32)
    loss_all, _ = cross_entropy(logits, batch["labels"])
    mask = jnp.zeros((B, S), bool).at[:, :4].set(True)
    loss_masked, denom = cross_entropy(logits, batch["labels"], mask)
    assert denom == 8
    assert np.isfinite(float(loss_masked)) and np.isfinite(float(loss_all))


def test_mrope_degenerates_to_rope_on_text():
    """M-RoPE with equal (t,h,w) ids == standard RoPE (arXiv:2409.12191)."""
    from repro.models.rope import (apply_rotary, mrope_cos_sin,
                                   rope_cos_sin, text_positions3)
    pos = jnp.arange(16)[None]
    c1, s1 = rope_cos_sin(pos, 64, 1e4)
    c2, s2 = mrope_cos_sin(text_positions3(pos), 64, 1e4, (16, 8, 8))
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)


@pytest.mark.parametrize("arch", ["mamba2-130m", "recurrentgemma-9b"])
def test_multistep_training_stays_finite(arch):
    """Regression: grads through the SSD/RG-LRU chunked decays must stay
    finite over several optimizer steps (the where-exp NaN trap)."""
    from repro.launch.mesh import make_debug_mesh
    from repro.optim import init_opt_state
    from repro.train.steps import TrainConfig, make_train_step
    cfg = get_smoke_config(arch)
    mesh = make_debug_mesh(1)
    params = init_params(RNG, cfg)
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, mesh, TrainConfig(
        dtype=jnp.float32, remat_policy="none")))
    batch = make_batch(cfg)
    with mesh:
        for _ in range(5):
            params, opt, m = step(params, opt, batch, jnp.float32(3e-3))
    assert np.isfinite(float(m["loss"])), m
    assert np.isfinite(float(m["grad_norm"])), m
