"""Unit tests: CannyFS engine semantics (paper §2–§3)."""
import threading
import time

import pytest

from repro.core import (CannyFS, EagerFlags, EnginePoisonedError,
                        InMemoryBackend, LatencyBackend, LatencyModel,
                        Transaction, TransactionFailedError, run_transaction)


def make_fs(**kw):
    be = InMemoryBackend()
    fs = CannyFS(be, **kw)
    return be, fs


def test_eager_ack_is_fast_and_correct():
    be = InMemoryBackend()
    lat = LatencyBackend(be, LatencyModel(meta_ms=5.0, data_ms=5.0,
                                          jitter_sigma=0.0))
    fs = CannyFS(lat, max_inflight=1000, workers=16)
    t0 = time.monotonic()
    fs.mkdir("d")
    fs.write_file("d/a", b"hello")
    ack = time.monotonic() - t0
    assert ack < 0.05, f"eager ops should ACK instantly, took {ack:.3f}s"
    fs.close()
    assert be.snapshot()["files"]["d/a"] == b"hello"


def test_read_after_write_barrier():
    be, fs = make_fs()
    fs.mkdir("x")
    for i in range(20):
        fs.write_file(f"x/f{i}", bytes([i]) * (i + 1))
    # reads see every previously ACKed write
    for i in range(20):
        assert fs.read_file(f"x/f{i}") == bytes([i]) * (i + 1)
    fs.close()


def test_per_path_write_ordering():
    be, fs = make_fs()
    fs.create("f")
    with fs.open("f", "wb") as h:
        for i in range(50):
            h.write(bytes([i]))
    assert fs.read_file("f") == bytes(range(50))
    fs.close()


def test_rename_and_readdir_order():
    be, fs = make_fs()
    fs.mkdir("d")
    fs.write_file("d/a", b"1")
    fs.rename("d/a", "d/b")
    names = fs.readdir("d")
    assert names == ["b"]
    assert fs.read_file("d/b") == b"1"
    fs.close()


def test_rmtree_waits_children():
    be, fs = make_fs()
    fs.makedirs("t/u/v")
    for i in range(30):
        fs.write_file(f"t/u/v/f{i}", b"x")
    fs.rmtree("t")
    fs.drain()
    snap = be.snapshot()
    assert snap["files"] == {}
    assert snap["dirs"] == {""}
    assert len(fs.ledger) == 0, fs.ledger.entries()
    fs.close()


def test_budget_blocks_submitter():
    be = InMemoryBackend()
    lat = LatencyBackend(be, LatencyModel(meta_ms=3.0, jitter_sigma=0.0))
    fs = CannyFS(lat, max_inflight=4, workers=2)
    for i in range(20):
        fs.create(f"f{i}")
    assert fs.engine.stats.max_queue_depth <= 4
    fs.close()


def test_mock_stat_from_pending_writes():
    be = InMemoryBackend()
    lat = LatencyBackend(be, LatencyModel(meta_ms=10.0, jitter_sigma=0.0))
    fs = CannyFS(lat, workers=4)
    fs.mkdir("m")
    fs.write_file("m/a", b"12345")
    t0 = time.monotonic()
    st = fs.stat("m/a")          # served from write-through cache
    assert time.monotonic() - t0 < 0.01
    assert st.exists and st.size == 5 and st.mocked
    fs.close()


def test_negative_stat_after_unlink():
    be, fs = make_fs()
    fs.write_file("z", b"1")
    fs.unlink("z")
    assert not fs.exists("z")
    fs.close()


def test_deferred_error_lands_in_ledger():
    class Bad(InMemoryBackend):
        def write_at(self, p, o, d):
            if "bad" in p:
                raise OSError(28, "no space")
            return super().write_at(p, o, d)

    fs = CannyFS(Bad())
    fs.write_file("ok", b"1")
    fs.write_file("bad", b"2")
    fs.drain()
    assert len(fs.ledger) == 1
    assert fs.ledger.entries()[0].kind == "write"
    fs.close()


def test_abort_on_error_poisons_engine():
    class Bad(InMemoryBackend):
        def create(self, p):
            if p == "bad":
                raise PermissionError(p)
            super().create(p)

    fs = CannyFS(Bad(), abort_on_error=True)
    fs.create("bad")
    fs.drain()
    with pytest.raises(EnginePoisonedError):
        for i in range(100):
            fs.create(f"later{i}")   # must fail fast once poisoned
    fs.engine.reset_poison()
    fs.close()


def test_transaction_commit_clean():
    be, fs = make_fs()
    with Transaction(fs) as txn:
        fs.mkdir("out")
        fs.write_file("out/r", b"result")
    assert txn.committed
    assert be.snapshot()["files"]["out/r"] == b"result"
    fs.close()


def test_transaction_rollback_removes_outputs():
    class Bad(InMemoryBackend):
        def write_at(self, p, o, d):
            if "bad" in p:
                raise OSError(122, "quota")
            return super().write_at(p, o, d)

    be = Bad()
    fs = CannyFS(be)
    txn = Transaction(fs)
    with pytest.raises(TransactionFailedError):
        with txn:
            fs.makedirs("out/deep")
            fs.write_file("out/deep/ok", b"1")
            fs.write_file("out/bad", b"2")
    txn.rollback()
    snap = be.snapshot()
    assert "out" not in snap["dirs"] and snap["files"] == {}
    fs.close()


def test_run_transaction_retries_until_success():
    attempts = []

    class Flaky(InMemoryBackend):
        def write_at(self, p, o, d):
            if p == "out/flaky" and len(attempts) < 2:
                attempts.append(1)
                raise OSError(5, "io error")
            return super().write_at(p, o, d)

    be = Flaky()
    fs = CannyFS(be)

    def job(fs):
        fs.makedirs("out")
        fs.write_file("out/flaky", b"eventually")

    run_transaction(fs, job, retries=3)
    assert be.snapshot()["files"]["out/flaky"] == b"eventually"
    assert len(attempts) == 2
    fs.close()


def test_thread_per_op_executor_mode():
    be, fs_kw = InMemoryBackend(), {}
    fs = CannyFS(be, executor="thread_per_op", workers=1)
    fs.mkdir("d")
    for i in range(20):
        fs.write_file(f"d/f{i}", b"v")
    fs.close()
    assert len(be.snapshot()["files"]) == 20


def test_concurrent_submitters():
    be, fs = make_fs(workers=8)
    fs.mkdir("c")

    def writer(k):
        for i in range(25):
            fs.write_file(f"c/t{k}_{i}", bytes([k, i]))

    threads = [threading.Thread(target=writer, args=(k,)) for k in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    fs.drain()
    snap = be.snapshot()
    assert len(snap["files"]) == 100
    for k in range(4):
        for i in range(25):
            assert snap["files"][f"c/t{k}_{i}"] == bytes([k, i])
    fs.close()
