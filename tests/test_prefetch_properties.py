"""Prefetch-on-vs-off oracle property tests: for any op stream over a
cold pre-populated tree — walks, readdirs, stats, writes, removals,
whole-subtree rmtrees — running with the speculative metadata prefetcher
enabled and disabled leaves the InMemory backend in the identical final
state with identical read results and ledger outcomes, including under
seeded fault plans.  Mirrors the fusion/overlay equivalence suites.

Where hypothesis is installed the streams are minimised shrinking
examples; where it is absent (the satellite's random-driver fallback)
the same driver runs under seeded ``random`` streams — 150 trials for
the clean property, 60 for the fault-plan property — so the property is
exercised either way instead of silently skipping."""
import random

import pytest

from repro.core import (CannyFS, FaultInjectingBackend, FaultPlan,
                        FaultRule, InMemoryBackend)

try:
    import hypothesis.strategies as stx
    from hypothesis import HealthCheck, given, settings
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

# the cold tree every run starts from (populated directly on the
# backend, so the mount must discover it — prefetch's whole domain)
COLD_DIRS = ["pre", "pre/d0", "pre/d1", "pre/d0/g0"]
COLD_FILES = [f"{d}/c{i}" for d in COLD_DIRS for i in range(2)]
# in-window namespace the driver mutates
DIRS = ["pre", "pre/d0", "pre/d1", "pre/d0/g0", "live"]
FILES = [f"{d}/f{i}" for d in DIRS for i in range(2)] + COLD_FILES

OPS = ("walk", "readdir", "stat", "write", "read", "unlink", "rename",
       "rmtree", "remake")


def _populate(be):
    be.mkdir("live")
    for d in COLD_DIRS:
        be.mkdir(d)
    for f in COLD_FILES:
        be.create(f)
        be.write_at(f, 0, f.encode())


def gen_ops(rng: random.Random, n: int = 22):
    """One random op stream (the fallback driver's generator; the
    hypothesis strategy below mirrors it)."""
    out = []
    for _ in range(n):
        op = rng.choice(OPS)
        if op == "write":
            out.append((op, rng.choice(FILES),
                        bytes(rng.randrange(256) for _ in range(
                            rng.randrange(0, 12)))))
        elif op == "rename":
            out.append((op, rng.choice(FILES), rng.choice(FILES)))
        elif op == "walk":
            out.append((op, rng.choice(["", "pre"]), None))
        elif op in ("readdir", "remake", "rmtree"):
            out.append((op, rng.choice(DIRS), None))
        elif op == "stat":
            out.append((op, rng.choice(FILES + DIRS), None))
        else:   # read / unlink
            out.append((op, rng.choice(FILES), None))
    return out


def _drive(fs, ops):
    """Replay ops, collecting every read-class answer.  Destructive ops
    on missing paths are filtered against live-set bookkeeping (the
    valid single-writer task model, as in the sibling suites); the cold
    tree counts as live from the start."""
    observed = []
    live = set(COLD_FILES)
    live_dirs = set(COLD_DIRS) | {"live"}
    for op, path, arg in ops:
        if op == "write":
            if path.rsplit("/", 1)[0] not in live_dirs:
                continue
            fs.write_file(path, arg)
            live.add(path)
        elif op == "unlink" and path in live:
            fs.unlink(path)
            live.discard(path)
        elif op == "rename":
            dst = arg
            if path not in live or dst == path or dst in live_dirs:
                continue
            if dst.rsplit("/", 1)[0] not in live_dirs:
                continue
            fs.rename(path, dst)
            live.discard(path)
            live.add(dst)
        elif op == "readdir" and path in live_dirs:
            observed.append(("readdir", path, fs.readdir(path)))
        elif op == "walk" and (not path or path in live_dirs):
            observed.append(("walk", path,
                             [(d, list(s), list(f))
                              for d, s, f in fs.walk(path)]))
        elif op == "stat":
            st = fs.stat(path)
            observed.append(("stat", path, st.exists, st.is_dir))
        elif op == "read" and path in live:
            observed.append(("read", path, fs.read_file(path)))
        elif op == "rmtree" and path in live_dirs:
            fs.rmtree(path)
            for d in [d for d in live_dirs if d == path
                      or d.startswith(path + "/")]:
                live_dirs.discard(d)
            for f in [f for f in live if f.startswith(path + "/")]:
                live.discard(f)
        elif op == "remake" and path not in live_dirs:
            parent = path.rsplit("/", 1)[0] if "/" in path else None
            if parent is not None and parent not in live_dirs:
                continue
            fs.makedirs(path)
            live_dirs.add(path)
    return observed


def check_equivalent(ops, workers):
    """The acceptance property: identical final backend state, identical
    readdir/walk/stat/read answers, identical (empty) ledger."""
    results = []
    for prefetch in (None, False):    # None -> default policy (enabled)
        be = InMemoryBackend()
        _populate(be)
        fs = CannyFS(be, workers=workers, prefetch=prefetch,
                     echo_errors=False)
        observed = _drive(fs, ops)
        fs.drain()
        sig = sorted((e.kind, e.paths, getattr(e.error, "errno", None))
                     for e in fs.ledger.entries())
        results.append((be.snapshot(), observed, sig))
        fs.close()
    assert results[0] == results[1]


def check_fault_equivalent(ops, seed):
    """Under a seeded fault plan the two modes may fail *different*
    backend calls (speculative batches consume readdir matches the
    unprefetched run never issues, and batch faults are advisory), but a
    clean run (no injected faults in either mode) must produce identical
    state, and no run may ledger more faults than were injected."""
    outcome = []
    for prefetch in (None, False):
        plan = FaultPlan([FaultRule(error="EIO",
                                    ops=("write", "unlink", "rmdir",
                                         "readdir", "remove_tree"),
                                    probability=0.15, max_failures=3)],
                         seed=seed)
        be = InMemoryBackend()
        _populate(be)
        fs = CannyFS(FaultInjectingBackend(be, plan), workers=2,
                     prefetch=prefetch, echo_errors=False)
        try:
            _drive(fs, ops)
        except OSError:
            pass   # a sync read path may surface an injected fault
        fs.drain()
        n_ledgered = sum(getattr(e.error, "injected", False)
                         for e in fs.ledger.entries())
        outcome.append((plan.injected, n_ledgered, be.snapshot()))
        fs.close()
    for injected, ledgered, _ in outcome:
        # sync-surfaced faults skip the ledger; speculative-batch faults
        # are advisory and must NEVER be ledgered
        assert ledgered <= injected
    if outcome[0][0] == 0 and outcome[1][0] == 0:
        assert outcome[0][2] == outcome[1][2]


if HAVE_HYPOTHESIS:
    def _op_strategy():
        write = stx.tuples(stx.just("write"), stx.sampled_from(FILES),
                           stx.binary(min_size=0, max_size=12))
        rename = stx.tuples(stx.just("rename"), stx.sampled_from(FILES),
                            stx.sampled_from(FILES))
        walk = stx.tuples(stx.just("walk"), stx.sampled_from(["", "pre"]),
                          stx.none())
        readdir = stx.tuples(stx.just("readdir"), stx.sampled_from(DIRS),
                             stx.none())
        statop = stx.tuples(stx.just("stat"),
                            stx.sampled_from(FILES + DIRS), stx.none())
        read = stx.tuples(stx.just("read"), stx.sampled_from(FILES),
                          stx.none())
        unlink = stx.tuples(stx.just("unlink"), stx.sampled_from(FILES),
                            stx.none())
        rmtree = stx.tuples(stx.just("rmtree"), stx.sampled_from(DIRS),
                            stx.none())
        remake = stx.tuples(stx.just("remake"), stx.sampled_from(DIRS),
                            stx.none())
        return stx.lists(stx.one_of(write, rename, walk, readdir, statop,
                                    read, unlink, rmtree, remake),
                         min_size=1, max_size=25)

    @settings(max_examples=50, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(ops=_op_strategy(), workers=stx.sampled_from([1, 4]))
    def test_prefetch_on_and_off_execution_identical(ops, workers):
        check_equivalent(ops, workers)

    @settings(max_examples=20, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(ops=_op_strategy(), seed=stx.integers(0, 3))
    def test_prefetch_modes_agree_under_fault_plans(ops, seed):
        check_fault_equivalent(ops, seed)
else:
    @pytest.mark.parametrize("trial", range(150))
    def test_prefetch_on_and_off_execution_identical_random(trial):
        rng = random.Random(10_000 + trial)
        check_equivalent(gen_ops(rng), workers=rng.choice([1, 4]))

    @pytest.mark.parametrize("trial", range(60))
    def test_prefetch_modes_agree_under_fault_plans_random(trial):
        rng = random.Random(20_000 + trial)
        check_fault_equivalent(gen_ops(rng), seed=trial % 4)
