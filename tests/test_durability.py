"""Durable optimization window (PR 9): spill journal codec + parse
semantics, diverted-stream verification, and end-to-end preempt/resume
convergence of ``CannyFS.enable_spill`` / ``CannyFS.resume``."""
import pytest

from repro.core import (CannyFS, EagerFlags, FaultInjectingBackend,
                        FaultPlan, FaultRule, InMemoryBackend, ProcessKilled,
                        Transaction, TransactionFailedError, commit_marker_ok,
                        run_transaction)
from repro.core.durability import (SpillImage, _assemble, _dec, _enc,
                                   _verify)

# ---------------------------------------------------------------------------
# marker + record codec
# ---------------------------------------------------------------------------

def test_commit_marker_ok():
    assert commit_marker_ok(b"7", 7)
    assert not commit_marker_ok(b"7", 8)
    assert not commit_marker_ok(b"", 0)          # empty marker: not a commit
    assert not commit_marker_ok(b"abc", 0)
    assert not commit_marker_ok(b"\xff\xfe", 0)  # undecodable


def test_codec_roundtrip():
    rec = {"t": "done", "e": 3, "k": "write", "p": ["a/b"],
           "segs": [[0, 4, 123]]}
    line = _enc(rec)
    assert line.endswith(b"\n")
    assert _dec(line.rstrip(b"\n")) == rec


def test_codec_rejects_corruption():
    line = _enc({"t": "admit", "e": 0, "k": "mkdir", "p": ["d"]})
    # flip one payload byte: crc no longer matches
    torn = bytearray(line)
    torn[5] ^= 0x01
    assert _dec(bytes(torn).rstrip(b"\n")) is None
    # truncated line (no crc suffix)
    assert _dec(line[: len(line) // 2]) is None
    assert _dec(b"not json at all|deadbeef") is None
    assert _dec(b"[1,2,3]|" + _enc({}).rsplit(b"|", 1)[1].rstrip(b"\n")) \
        is None  # valid json, but not an object


# ---------------------------------------------------------------------------
# parse: monotone prefix, epoch scoping, uncertainty
# ---------------------------------------------------------------------------

def _log(*recs):
    return b"".join(_enc(r) for r in recs)


def test_parse_stops_at_corruption():
    good = _log({"t": "begin", "e": 0},
                {"t": "done", "e": 0, "k": "mkdir", "p": ["d"]})
    bad = b"garbage line\n" + _enc(
        {"t": "done", "e": 0, "k": "mkdir", "p": ["d2"]})
    img = SpillImage.parse(good + bad)
    assert img.began
    assert img.durable_dirs == {"d"}      # nothing after the gap is trusted
    assert img.end_offset == len(good)
    assert img.nrecords == 2


def test_parse_stops_at_torn_final_line():
    raw = _log({"t": "begin", "e": 0},
               {"t": "done", "e": 0, "k": "mkdir", "p": ["d"]})
    img = SpillImage.parse(raw + b'{"t":"done","e":0')   # no newline
    assert img.durable_dirs == {"d"}
    assert img.end_offset == len(raw)


def test_parse_last_begin_wins():
    """Records of a rolled-back attempt (earlier epoch) must never
    resurrect: a later ``begin`` supersedes everything before it."""
    raw = _log({"t": "begin", "e": 0},
               {"t": "done", "e": 0, "k": "create", "p": ["old.bin"]},
               {"t": "jrnl", "e": 0, "p": "old.bin", "d": 0},
               {"t": "begin", "e": 1},
               {"t": "done", "e": 1, "k": "mkdir", "p": ["new"]})
    img = SpillImage.parse(raw)
    assert img.epoch == 1
    assert img.durable_files == {}
    assert img.journal == {}
    assert img.durable_dirs == {"new"}


def test_parse_epoch_mismatch_stops():
    raw = _log({"t": "begin", "e": 2},
               {"t": "done", "e": 2, "k": "mkdir", "p": ["a"]},
               {"t": "done", "e": 1, "k": "mkdir", "p": ["b"]},  # stale
               {"t": "done", "e": 2, "k": "mkdir", "p": ["c"]})
    img = SpillImage.parse(raw)
    assert img.durable_dirs == {"a"}      # stop at the mismatch, not skip


def test_parse_uncertain_is_admit_minus_settle():
    raw = _log({"t": "begin", "e": 0},
               {"t": "admit", "e": 0, "k": "write", "p": ["f"]},
               {"t": "admit", "e": 0, "k": "write", "p": ["f"]},
               {"t": "done", "e": 0, "k": "write", "p": ["f"],
                "segs": [[0, 1, 0]]},
               {"t": "admit", "e": 0, "k": "remove_tree", "p": ["t"]})
    img = SpillImage.parse(raw)
    assert img.uncertain == {("write", ("f",)): 1,
                             ("remove_tree", ("t",)): 1}
    assert img.removal_uncertain == {"t"}


def test_parse_elided_done_settles_without_claiming():
    raw = _log({"t": "begin", "e": 0},
               {"t": "admit", "e": 0, "k": "mkdir", "p": ["d"]},
               {"t": "done", "e": 0, "k": "mkdir", "p": ["d"], "el": 1})
    img = SpillImage.parse(raw)
    assert img.uncertain == {}
    assert img.durable_dirs == set()      # an elided op proved nothing new


def test_parse_removal_retracts_durable_claims():
    raw = _log({"t": "begin", "e": 0},
               {"t": "done", "e": 0, "k": "mkdir", "p": ["d"]},
               {"t": "done", "e": 0, "k": "create", "p": ["d/f"]},
               {"t": "done", "e": 0, "k": "remove_tree", "p": ["d"]})
    img = SpillImage.parse(raw)
    assert img.durable_dirs == set()
    assert img.durable_files == {}
    assert "d" in img.removed and "d/f" in img.removed


def test_parse_rename_rekeys_journal():
    raw = _log({"t": "begin", "e": 0},
               {"t": "jrnl", "e": 0, "p": "a", "d": 1},
               {"t": "jrnl", "e": 0, "p": "a/f", "d": 0},
               {"t": "jmv", "e": 0, "s": "a", "d": "b"})
    img = SpillImage.parse(raw)
    assert img.journal == {"b": True, "b/f": False}


def test_parse_committed_flag():
    raw = _log({"t": "begin", "e": 0},
               {"t": "committed", "e": 0})
    assert SpillImage.parse(raw).committed


# ---------------------------------------------------------------------------
# diverted-stream assembly + verification
# ---------------------------------------------------------------------------

def _crc(b):
    import zlib
    return zlib.crc32(b) & 0xFFFFFFFF


def test_assemble_later_wins_and_zero_fills():
    assert _assemble([(0, b"abcd"), (2, b"XY")]) == b"abXY"
    assert _assemble([(2, b"zz")]) == b"\x00\x00zz"
    assert _assemble([]) == b""


def test_verify_exact_coverage_required():
    content = b"hello world"
    segs = [[0, 5, _crc(b"hello")], [5, 6, _crc(b" world")]]
    assert _verify(content, segs)
    # a gap in coverage (tail unproven) fails
    assert not _verify(content, segs[:1])
    # crc mismatch (content overwritten since the record) fails
    assert not _verify(b"hellO world", segs)
    # segment overhanging the content fails
    assert not _verify(b"hel", [[0, 5, _crc(b"hello")]])
    # empty content needs no segments
    assert _verify(b"", [])


def test_verify_overlapping_segments_ok_when_crcs_hold():
    content = b"aabb"
    segs = [[0, 4, _crc(b"aabb")], [2, 2, _crc(b"bb")]]
    assert _verify(content, segs)


# ---------------------------------------------------------------------------
# end-to-end: spill lifecycle on a live mount
# ---------------------------------------------------------------------------

def _body(fs):
    fs.mkdir("out")
    fs.write_file("out/a.bin", b"alpha" * 64)
    fs.chmod("out/a.bin", 0o640)
    fs.write_file("out/b.bin", b"beta")
    fs.mkdir("out/sub")
    fs.write_file("out/sub/c.bin", b"gamma" * 16)
    fs.unlink("out/b.bin")


def _data(be):
    snap = be.snapshot()
    return ({p: bytes(d) for p, d in snap["files"].items()
             if not p.startswith(".spill")},
            {d for d in snap["dirs"] if d and not p_spill(d)})


def p_spill(p):
    return p == ".spill" or p.startswith(".spill/")


def _baseline():
    be = InMemoryBackend()
    fs = CannyFS(be, flags=EagerFlags(), echo_errors=False)
    fs.enable_spill(".spill")
    run_transaction(fs, _body)
    fs.close()
    return _data(be)


def test_spill_journal_retired_on_commit():
    be = InMemoryBackend()
    fs = CannyFS(be, flags=EagerFlags(), echo_errors=False)
    fs.enable_spill(".spill")
    with Transaction(fs):
        fs.mkdir("out")
        fs.write_file("out/a.bin", b"x" * 32)
        fs.drain()
        assert be.stat(".spill/journal.log").exists
        assert fs.engine.stats.spill_records > 0
        assert fs.engine.stats.spill_cuts > 0
    # commit retired the log; the marker survives as the committed proof
    assert not be.stat(".spill/journal.log").exists
    assert be.read_at(".spill/CUT", 0, -1).startswith(b"committed:")
    fs.close()


def test_resume_after_full_retirement_reports_committed():
    """Kill after commit retired the journal: the marker proof alone must
    tell a restart the window finished (no doomed from-scratch re-run)."""
    be = InMemoryBackend()
    fs = CannyFS(be, flags=EagerFlags(), echo_errors=False)
    fs.enable_spill(".spill")
    run_transaction(fs, _body)
    fs.close()
    fs2 = CannyFS(be, flags=EagerFlags(), echo_errors=False)
    report = fs2.resume(".spill")
    assert report["committed"]
    assert not report["resumable"]
    fs2.close()


def test_rollback_advances_epoch_no_resurrection():
    """After a rollback, a resume of the same log must see the *new*
    attempt only — the rolled-back epoch's records are dead."""
    be = InMemoryBackend()
    fs = CannyFS(be, flags=EagerFlags(), echo_errors=False)
    fs.enable_spill(".spill")
    txn = Transaction(fs)
    with pytest.raises(RuntimeError):
        with txn:
            fs.mkdir("old")
            fs.write_file("old/x.bin", b"dead")
            fs.drain()
            raise RuntimeError("boom")     # __exit__ rolls back
    assert txn.rolled_back
    raw = be.read_at(".spill/journal.log", 0, -1)
    img = SpillImage.parse(raw)
    assert not img.began          # cut flushed, but no begin in new epoch
    assert img.durable_files == {} and img.journal == {}
    fs.close()


def test_kill_resume_converges_and_elides():
    baseline = _baseline()

    be = InMemoryBackend()
    plan = FaultPlan([FaultRule(ops=("write", "write_vec"),
                                path_glob="out/sub/*", outcome="kill",
                                max_failures=1)], seed=3)
    fb = FaultInjectingBackend(be, plan)
    fs = CannyFS(fb, flags=EagerFlags(flush=False), echo_errors=False)
    fs.enable_spill(".spill")
    with pytest.raises(ProcessKilled):
        run_transaction(fs, _body, retries=3)
    assert plan.kills == 1
    assert fs.engine.stats.rollbacks == 0     # preemption, not failure
    try:
        fs.close()
    except Exception:
        pass

    fb.revive()
    fs2 = CannyFS(fb, flags=EagerFlags(flush=False), echo_errors=False)
    report = fs2.resume(".spill")
    assert report["resumable"]
    assert report["records"] > 0
    run_transaction(fs2, _body)
    fs2.close()
    assert fs2.engine.stats.resumes == 1
    # the proven prefix (out/, a.bin, …) was elided, not redone
    assert fs2.engine.stats.resume_elided_ops > 0
    assert _data(be) == baseline
    # commit retired the spill artifacts
    assert not be.stat(".spill/journal.log").exists


def test_resume_on_empty_spill_is_fresh_start():
    be = InMemoryBackend()
    fs = CannyFS(be, flags=EagerFlags(), echo_errors=False)
    report = fs.resume(".spill")
    assert not report["resumable"]
    run_transaction(fs, _body)
    fs.close()
    assert _data(be) == _baseline()


def _forge_spill(be, *recs):
    """Plant a spill log directly on the backend — the state a killed
    process leaves behind, without racing a live engine to produce it."""
    be.mkdir(".spill")
    be.create(".spill/journal.log")
    raw = _log(*recs)
    be.write_at(".spill/journal.log", 0, raw)
    return raw


def test_diverted_stream_mismatch_falls_back_to_rewrite():
    """Recorded segment checksums that do not prove the re-run's stream
    (the interrupted run wrote different bytes, or only a partial record
    survived the kill) force a real rewrite, never an elision."""
    content = b"alpha" * 64
    stale = b"old-bytes"
    be = InMemoryBackend()
    be.mkdir("out")
    be.create("out/a.bin")
    be.write_at("out/a.bin", 0, stale)
    _forge_spill(
        be,
        {"t": "begin", "e": 0},
        {"t": "done", "e": 0, "k": "mkdir", "p": ["out"]},
        {"t": "jrnl", "e": 0, "p": "out", "d": 1},
        {"t": "done", "e": 0, "k": "create", "p": ["out/a.bin"]},
        {"t": "jrnl", "e": 0, "p": "out/a.bin", "d": 0},
        # the record proves only the stale bytes — not the re-run's stream
        {"t": "done", "e": 0, "k": "write", "p": ["out/a.bin"],
         "segs": [[0, len(stale), _crc(stale)]]})

    fs = CannyFS(be, flags=EagerFlags(flush=False), echo_errors=False)
    report = fs.resume(".spill")
    assert report["resumable"]
    with Transaction(fs):
        fs.mkdir("out")
        fs.write_file("out/a.bin", content)
    fs.close()
    assert be.read_at("out/a.bin", 0, -1) == content


def test_diverted_stream_match_is_elided():
    """The happy twin: backend content matches the recorded checksums, so
    the whole create+write stream is elided."""
    content = b"alpha" * 64
    be = InMemoryBackend()
    be.mkdir("out")
    be.create("out/a.bin")
    be.write_at("out/a.bin", 0, content)
    _forge_spill(
        be,
        {"t": "begin", "e": 0},
        {"t": "done", "e": 0, "k": "mkdir", "p": ["out"]},
        {"t": "jrnl", "e": 0, "p": "out", "d": 1},
        {"t": "done", "e": 0, "k": "create", "p": ["out/a.bin"]},
        {"t": "jrnl", "e": 0, "p": "out/a.bin", "d": 0},
        {"t": "done", "e": 0, "k": "write", "p": ["out/a.bin"],
         "segs": [[0, len(content), _crc(content)]]})

    fs = CannyFS(be, flags=EagerFlags(flush=False), echo_errors=False)
    fs.resume(".spill")
    before = be.snapshot()["files"]["out/a.bin"]
    with Transaction(fs):
        fs.mkdir("out")
        fs.write_file("out/a.bin", content)
    fs.close()
    assert fs.engine.stats.resume_elided_ops >= 3   # mkdir + create + write
    assert be.read_at("out/a.bin", 0, -1) == bytes(before)


def test_stale_tail_truncated_on_load():
    """Bytes past the last parsable record (a torn chunk) are physically
    truncated at load so the resumed epoch appends to a clean prefix."""
    be = InMemoryBackend()
    raw = _forge_spill(be,
                       {"t": "begin", "e": 0},
                       {"t": "done", "e": 0, "k": "mkdir", "p": ["out"]},
                       {"t": "jrnl", "e": 0, "p": "out", "d": 1})
    be.write_at(".spill/journal.log", len(raw), b'{"torn')   # no newline

    fs = CannyFS(be, flags=EagerFlags(), echo_errors=False)
    report = fs.resume(".spill")
    assert report["resumable"]
    assert be.read_at(".spill/journal.log", 0, -1) == raw
    fs.close()


def test_rolledback_tombstone_kills_the_window():
    """A log whose last lifecycle record is the rollback tombstone proves
    no window: resume must not trust any of the epoch's claims."""
    be = InMemoryBackend()
    _forge_spill(be,
                 {"t": "begin", "e": 0},
                 {"t": "done", "e": 0, "k": "mkdir", "p": ["out"]},
                 {"t": "jrnl", "e": 0, "p": "out", "d": 1},
                 {"t": "rolledback", "e": 0})
    fs = CannyFS(be, flags=EagerFlags(), echo_errors=False)
    report = fs.resume(".spill")
    assert not report["resumable"]
    assert report["journal_paths"] == 0
    fs.close()


def test_repair_never_journals_preexisting_file():
    """A write_at to a file that pre-dated the transaction, in flight at
    the kill, looks like a landed-but-unjournaled create — except for the
    probe record proving the path existed before the op.  Repair must
    leave it unjournaled, so a rollback of the resumed attempt can never
    unlink pre-transaction user data."""
    for probe_rec in ([{"t": "pre", "e": 0, "p": "user.dat", "x": 1}],
                      []):       # existence unknown: equally off-limits
        be = InMemoryBackend()
        be.create("user.dat")
        be.write_at("user.dat", 0, b"precious")
        _forge_spill(be,
                     {"t": "begin", "e": 0},
                     *probe_rec,
                     {"t": "admit", "e": 0, "k": "write", "p": ["user.dat"]})
        fs = CannyFS(be, flags=EagerFlags(flush=False), echo_errors=False)
        report = fs.resume(".spill")
        assert report["resumable"]
        txn = Transaction(fs)
        with pytest.raises(RuntimeError):
            with txn:
                raise RuntimeError("abort the resumed attempt")
        assert txn.rolled_back
        assert be.read_at("user.dat", 0, -1) == b"precious"
        fs.close()


def test_repair_journals_landed_create_with_absence_proof():
    """The dual: a surviving probe record proving pre-op absence makes
    the landed-but-unjournaled create this window's own — repair
    journals it, and rollback removes it instead of leaking it."""
    be = InMemoryBackend()
    be.create("out.bin")
    be.write_at("out.bin", 0, b"window output")
    _forge_spill(be,
                 {"t": "begin", "e": 0},
                 {"t": "pre", "e": 0, "p": "out.bin", "x": 0},
                 {"t": "admit", "e": 0, "k": "create", "p": ["out.bin"]})
    fs = CannyFS(be, flags=EagerFlags(flush=False), echo_errors=False)
    report = fs.resume(".spill")
    assert report["resumable"] and report["repairs"] >= 1
    txn = Transaction(fs)
    with pytest.raises(RuntimeError):
        with txn:
            raise RuntimeError("abort the resumed attempt")
    assert txn.rolled_back
    assert not be.stat("out.bin").exists
    fs.close()


def test_resumed_mkdir_on_unvouched_dir_surfaces_eexist():
    """Re-execution tolerance is scoped to paths the spill image vouches
    for: a resumed mkdir of a directory the interrupted run never
    reached (it pre-dates the job) must surface the FileExistsError a
    fresh run would, and must not pull the directory into rollback
    scope."""
    be = InMemoryBackend()
    be.mkdir("legacy")           # pre-dates the job; run 1 never saw it
    _forge_spill(be,
                 {"t": "begin", "e": 0},
                 {"t": "done", "e": 0, "k": "mkdir", "p": ["out"]},
                 {"t": "jrnl", "e": 0, "p": "out", "d": 1})
    fs = CannyFS(be, flags=EagerFlags(flush=False), echo_errors=False)
    fs.resume(".spill")
    with pytest.raises(TransactionFailedError):
        with Transaction(fs):
            fs.mkdir("out")      # vouched (journaled): tolerated
            fs.mkdir("legacy")   # unvouched: the genuine error surfaces
    assert be.stat("legacy").exists
    try:
        fs.close()
    except Exception:
        pass


def test_resumed_mkdir_under_window_dir_tolerated():
    """The tolerated side of the scoping: a recordless mkdir that landed
    under a directory this window provably created is the run's own
    output (nothing pre-existing can live below a window-created dir) —
    the re-run's EEXIST is benign and the job commits."""
    be = InMemoryBackend()
    be.mkdir("out")
    be.mkdir("out/sub")          # landed in run 1, record lost to the kill
    _forge_spill(be,
                 {"t": "begin", "e": 0},
                 {"t": "done", "e": 0, "k": "mkdir", "p": ["out"]},
                 {"t": "jrnl", "e": 0, "p": "out", "d": 1})
    fs = CannyFS(be, flags=EagerFlags(flush=False), echo_errors=False)
    fs.resume(".spill")
    with Transaction(fs):
        fs.mkdir("out")          # elided: provably durable
        fs.mkdir("out/sub")      # EEXIST tolerated via the subtree vouch
    fs.close()
    assert be.stat("out/sub").exists


def test_torn_rename_over_existing_keeps_moved_data():
    """Torn COPY+DELETE where the rename target pre-existed and the COPY
    never started: dst holds the stale old content and src the only copy
    of the moved data.  dst-wins would unlink src outright; repair must
    verify dst against src and re-issue the rename instead."""
    be = InMemoryBackend()
    be.create("a.bin")
    be.write_at("a.bin", 0, b"moved payload")
    be.create("b.bin")
    be.write_at("b.bin", 0, b"stale old target")
    _forge_spill(be,
                 {"t": "begin", "e": 0},
                 {"t": "admit", "e": 0, "k": "rename",
                  "p": ["a.bin", "b.bin"]})
    fs = CannyFS(be, flags=EagerFlags(flush=False), echo_errors=False)
    report = fs.resume(".spill")
    assert report["resumable"] and report["repairs"] >= 1
    assert be.read_at("b.bin", 0, -1) == b"moved payload"
    assert not be.stat("a.bin").exists
    fs.close()


def test_overlay_delta_reinstalled_without_walk():
    """Resume replays the proven delta into the overlay: the re-executed
    body's readdir/exists answers come from the reinstalled membership
    delta, and delta_summary shows the claims."""
    be = InMemoryBackend()
    plan = FaultPlan([FaultRule(ops=("write", "write_vec"),
                                path_glob="out/sub/*", outcome="kill",
                                max_failures=1)], seed=3)
    fb = FaultInjectingBackend(be, plan)
    fs = CannyFS(fb, flags=EagerFlags(flush=False), echo_errors=False)
    fs.enable_spill(".spill")
    with pytest.raises(ProcessKilled):
        run_transaction(fs, _body, retries=0)
    try:
        fs.close()
    except Exception:
        pass

    fb.revive()
    fs2 = CannyFS(fb, flags=EagerFlags(flush=False), echo_errors=False)
    fs2.resume(".spill")
    summary = fs2.engine.overlay.delta_summary()
    assert summary["dirs"] > 0
    assert summary["children"] > 0
    assert fs2.exists("out/a.bin")      # answered from the replayed delta
    fs2.close()
