"""Backend-zoo equivalence property tests (PR 8): for any op stream —
creates, chunked writes, renames (the retarget rule's domain), unlinks,
rmtrees, readdirs, stats, reads — running through ``CannyFS`` over the
S3-shaped ``ObjectStoreBackend`` or the SFTP-shaped
``RemoteStreamBackend`` leaves the identical final state, returns the
identical read-class answers, and ledgers the identical error signature
as the same stream over the plain ``InMemoryBackend`` oracle.  Billing
diverges wildly (that is the whole point of the zoo); semantics may
not — in particular, rename-as-copy+delete plus the cost-gated retarget
rewrite must be observationally indistinguishable from a native rename.

Also composes the fault/quota decorators over both new backends: the
existing property contracts (ledgered <= injected; clean runs byte-
identical) must hold with a cost-modelled backend at the bottom of the
stack.

Mirrors the driver pattern of ``test_prefetch_properties``: hypothesis
streams where available, seeded ``random`` fallback trials where not.
"""
import random

import pytest

from repro.core import (CannyFS, FaultInjectingBackend, FaultPlan,
                        FaultRule, InMemoryBackend, ObjectStoreBackend,
                        ObjectStoreModel, QuotaBackend, RemoteStreamBackend)

try:
    import hypothesis.strategies as stx
    from hypothesis import HealthCheck, given, settings
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

# pre-existing state (populated on the oracle, bypassing billing) — gives
# renames both pre-existing sources (plain copy+delete path) and
# in-window sources (the retarget path)
COLD_DIRS = ["pre", "pre/d0", "pre/d1"]
COLD_FILES = [f"{d}/c{i}" for d in COLD_DIRS for i in range(2)]
DIRS = COLD_DIRS + ["live"]
FILES = [f"{d}/f{i}" for d in DIRS for i in range(2)] + COLD_FILES

OPS = ("write", "append", "rename", "unlink", "readdir", "stat", "read",
       "rmtree", "remake", "chmod")


def _make_backend(kind: str):
    """-> (engine backend, oracle to pre-populate / snapshot)."""
    if kind == "posix":
        be = InMemoryBackend()
        return be, be
    if kind == "object":
        # tiny LIST page: remove_tree/readdir genuinely paginate
        be = ObjectStoreBackend(model=ObjectStoreModel(list_page_size=4))
        return be, be.inner
    be = RemoteStreamBackend()
    return be, be.inner


def _populate(oracle):
    oracle.mkdir("live")
    for d in COLD_DIRS:
        oracle.mkdir(d)
    for f in COLD_FILES:
        oracle.create(f)
        oracle.write_at(f, 0, f.encode())


def gen_ops(rng: random.Random, n: int = 24):
    out = []
    for _ in range(n):
        op = rng.choice(OPS)
        if op in ("write", "append"):
            out.append((op, rng.choice(FILES),
                        bytes(rng.randrange(256)
                              for _ in range(rng.randrange(0, 24)))))
        elif op == "rename":
            out.append((op, rng.choice(FILES), rng.choice(FILES)))
        elif op in ("readdir", "remake", "rmtree"):
            out.append((op, rng.choice(DIRS), None))
        elif op == "stat":
            out.append((op, rng.choice(FILES + DIRS), None))
        elif op == "chmod":
            out.append((op, rng.choice(FILES), 0o600))
        else:   # read / unlink
            out.append((op, rng.choice(FILES), None))
    return out


def _drive(fs, ops):
    """Replay ops, collecting every read-class answer; destructive ops on
    missing paths filtered against live-set bookkeeping (the valid
    single-writer task model, as in the sibling suites)."""
    observed = []
    live = set(COLD_FILES)
    live_dirs = set(DIRS)
    for op, path, arg in ops:
        parent = path.rsplit("/", 1)[0] if "/" in path else ""
        if op in ("write", "append"):
            if parent not in live_dirs:
                continue
            if op == "append" and path in live:
                with fs.open(path, "ab") as f:
                    f.write(arg)
            else:
                with fs.open(path, "wb") as f:   # chunked: exercises fusion
                    f.write(arg[: len(arg) // 2])
                    f.write(arg[len(arg) // 2:])
            live.add(path)
        elif op == "chmod" and path in live:
            fs.chmod(path, arg)
        elif op == "unlink" and path in live:
            fs.unlink(path)
            live.discard(path)
        elif op == "rename":
            dst = arg
            dparent = dst.rsplit("/", 1)[0] if "/" in dst else ""
            if (path not in live or dst == path or dst in live_dirs
                    or dparent not in live_dirs):
                continue
            fs.rename(path, dst)
            live.discard(path)
            live.add(dst)
        elif op == "readdir" and path in live_dirs:
            observed.append(("readdir", path, fs.readdir(path)))
        elif op == "stat":
            st = fs.stat(path)
            observed.append(("stat", path, st.exists, st.is_dir, st.size))
        elif op == "read" and path in live:
            observed.append(("read", path, fs.read_file(path)))
        elif op == "rmtree" and path in live_dirs:
            fs.rmtree(path)
            for d in [d for d in live_dirs
                      if d == path or d.startswith(path + "/")]:
                live_dirs.discard(d)
            for f in [f for f in live if f.startswith(path + "/")]:
                live.discard(f)
        elif op == "remake" and path not in live_dirs:
            if parent and parent not in live_dirs:
                continue
            fs.makedirs(path)
            live_dirs.add(path)
    return observed


def _run(kind, ops, workers, decorate=None):
    be, oracle = _make_backend(kind)
    _populate(oracle)
    engine_be = decorate(be) if decorate is not None else be
    fs = CannyFS(engine_be, workers=workers, echo_errors=False)
    observed = _drive(fs, ops)
    fs.drain()
    sig = sorted((e.kind, e.paths, getattr(e.error, "errno", None))
                 for e in fs.ledger.entries())
    out = (oracle.snapshot(), observed, sig)
    fs.close()
    return out


def check_equivalent(ops, workers):
    """The acceptance property: every zoo member is observationally
    identical to the POSIX oracle for the same stream."""
    baseline = _run("posix", ops, workers)
    for kind in ("object", "remote"):
        assert _run(kind, ops, workers) == baseline, kind


def check_quota_equivalent(ops, workers):
    """A generous quota layer composes over every zoo member without
    changing a byte of semantics."""
    def decorate(be):
        return QuotaBackend(be, budget_bytes=64 << 20)
    baseline = _run("posix", ops, workers, decorate=decorate)
    for kind in ("object", "remote"):
        assert _run(kind, ops, workers, decorate=decorate) == baseline, kind


def check_fault_contract(ops, seed):
    """Under a seeded fault plan the backends may diverge in *which* call
    a fault lands on (the engine sends different call streams to
    different media — that is the optimizer working), but each run must
    honor the ledger contract, and when no fault fired anywhere the
    final states must be identical."""
    outcome = {}
    for kind in ("posix", "object", "remote"):
        plan = FaultPlan([FaultRule(error="EIO",
                                    ops=("write", "unlink", "rmdir",
                                         "rename", "remove_tree"),
                                    probability=0.12, max_failures=3)],
                         seed=seed)
        be, oracle = _make_backend(kind)
        _populate(oracle)
        fs = CannyFS(FaultInjectingBackend(be, plan), workers=2,
                     echo_errors=False)
        try:
            _drive(fs, ops)
        except OSError:
            pass   # a sync path may surface an injected fault
        fs.drain()
        n_ledgered = sum(getattr(e.error, "injected", False)
                         for e in fs.ledger.entries())
        assert n_ledgered <= plan.injected, kind
        outcome[kind] = (plan.injected, oracle.snapshot())
        fs.close()
    if all(injected == 0 for injected, _ in outcome.values()):
        assert (outcome["object"][1] == outcome["posix"][1]
                == outcome["remote"][1])


if HAVE_HYPOTHESIS:
    def _op_strategy():
        payload = stx.binary(min_size=0, max_size=24)
        write = stx.tuples(stx.sampled_from(["write", "append"]),
                           stx.sampled_from(FILES), payload)
        rename = stx.tuples(stx.just("rename"), stx.sampled_from(FILES),
                            stx.sampled_from(FILES))
        chmod = stx.tuples(stx.just("chmod"), stx.sampled_from(FILES),
                           stx.just(0o600))
        readdir = stx.tuples(stx.just("readdir"), stx.sampled_from(DIRS),
                             stx.none())
        statop = stx.tuples(stx.just("stat"),
                            stx.sampled_from(FILES + DIRS), stx.none())
        read = stx.tuples(stx.just("read"), stx.sampled_from(FILES),
                          stx.none())
        unlink = stx.tuples(stx.just("unlink"), stx.sampled_from(FILES),
                            stx.none())
        rmtree = stx.tuples(stx.just("rmtree"), stx.sampled_from(DIRS),
                            stx.none())
        remake = stx.tuples(stx.just("remake"), stx.sampled_from(DIRS),
                            stx.none())
        return stx.lists(stx.one_of(write, rename, chmod, readdir, statop,
                                    read, unlink, rmtree, remake),
                         min_size=1, max_size=26)

    @settings(max_examples=40, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(ops=_op_strategy(), workers=stx.sampled_from([1, 4]))
    def test_zoo_backends_execution_identical_to_oracle(ops, workers):
        check_equivalent(ops, workers)

    @settings(max_examples=15, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(ops=_op_strategy(), workers=stx.sampled_from([1, 4]))
    def test_zoo_backends_identical_under_quota(ops, workers):
        check_quota_equivalent(ops, workers)

    @settings(max_examples=15, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(ops=_op_strategy(), seed=stx.integers(0, 3))
    def test_zoo_backends_honor_fault_contract(ops, seed):
        check_fault_contract(ops, seed)
else:
    @pytest.mark.parametrize("trial", range(120))
    def test_zoo_backends_execution_identical_to_oracle_random(trial):
        rng = random.Random(30_000 + trial)
        check_equivalent(gen_ops(rng), workers=rng.choice([1, 4]))

    @pytest.mark.parametrize("trial", range(40))
    def test_zoo_backends_identical_under_quota_random(trial):
        rng = random.Random(40_000 + trial)
        check_quota_equivalent(gen_ops(rng), workers=rng.choice([1, 4]))

    @pytest.mark.parametrize("trial", range(40))
    def test_zoo_backends_honor_fault_contract_random(trial):
        rng = random.Random(50_000 + trial)
        check_fault_contract(gen_ops(rng), seed=trial % 4)
