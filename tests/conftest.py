import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Tests must see the real single CPU device (the 512-device override is
# exclusively dryrun.py's), and run kernels against their jnp refs unless a
# test opts into interpret mode explicitly.
os.environ.setdefault("REPRO_KERNELS", "jnp")
